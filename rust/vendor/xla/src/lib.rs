//! Compile-anywhere stub of the `xla` (xla-rs) PJRT binding surface that
//! `osdt::runtime` consumes.
//!
//! The real crate links against an XLA/PJRT toolchain that is not present
//! in every build environment. This stub exposes the exact types and
//! signatures the runtime uses so the whole workspace (engine, scheduler,
//! coordinator, server, simulator-backed tests) builds and tests without
//! that toolchain:
//!
//! - Host-side data plumbing ([`Literal`], [`PjRtBuffer`]) is fully
//!   functional — unit tests that only shuttle host arrays pass.
//! - Compilation/execution ([`PjRtClient::compile`],
//!   [`PjRtLoadedExecutable::execute_b`]) returns a descriptive error at
//!   runtime. The artifact-backed integration tests already skip when no
//!   artifacts are built, so this path is never reached under `cargo test`.
//!
//! To run real HLO artifacts, point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs bindings; no osdt source change
//! is required.

use std::fmt;
use std::path::Path;

/// Error type mirroring xla-rs: displayable, `std::error::Error`, and
/// `Send + Sync` so `anyhow::Context` composes over it.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla(stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} requires the real PJRT backend; osdt was built with the \
         vendored stub `xla` crate (see rust/vendor/xla)"
    )))
}

// ---------------------------------------------------------------------------
// Host data
// ---------------------------------------------------------------------------

/// Element types the runtime shuttles to/from device buffers. Public only
/// because it appears in [`NativeType`]'s (doc-hidden) plumbing methods.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn type_name(&self) -> &'static str {
        match self {
            Payload::F32(_) => "f32",
            Payload::I32(_) => "i32",
        }
    }
}

/// Sealed-ish conversion trait for supported element types.
pub trait NativeType: Copy + Sized + 'static {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn as_slice(payload: &Payload) -> Option<&[Self]>;
    #[doc(hidden)]
    fn type_name() -> &'static str;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::F32(data)
    }
    fn as_slice(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::F32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Payload {
        Payload::I32(data)
    }
    fn as_slice(payload: &Payload) -> Option<&[Self]> {
        match payload {
            Payload::I32(v) => Some(v),
            _ => None,
        }
    }
    fn type_name() -> &'static str {
        "i32"
    }
}

/// A host literal: flat payload + dims, or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    payload: Option<Payload>,
    dims: Vec<usize>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len()],
            payload: Some(T::wrap(data.to_vec())),
            tuple: None,
        }
    }

    /// Literal with an explicit shape.
    pub fn from_host<T: NativeType>(data: &[T], dims: &[usize]) -> Literal {
        Literal {
            dims: dims.to_vec(),
            payload: Some(T::wrap(data.to_vec())),
            tuple: None,
        }
    }

    /// Tuple literal.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { payload: None, dims: vec![], tuple: Some(parts) }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Borrow the flat payload; errors on tuples / type mismatch.
    fn payload_slice<T: NativeType>(&self) -> Result<&[T]> {
        match &self.payload {
            Some(p) => T::as_slice(p).ok_or_else(|| {
                Error(format!(
                    "literal holds {}, requested {}",
                    p.type_name(),
                    T::type_name()
                ))
            }),
            None => Err(Error("payload access on a tuple literal".into())),
        }
    }

    /// Borrow the flat payload without copying; errors on tuples / type
    /// mismatch. The zero-copy reader behind `to_vec`/`read_into` —
    /// runtime unpackers use it to fill their own storage directly instead
    /// of going through an intermediate `Vec` (multi-output compact
    /// results make this the hot download path).
    pub fn as_slice<T: NativeType>(&self) -> Result<&[T]> {
        self.payload_slice()
    }

    /// Flat host copy of the payload; errors on tuples / type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        self.payload_slice::<T>().map(<[T]>::to_vec)
    }

    /// Copy the payload straight into a caller-provided vector (cleared
    /// first, capacity retained) — no intermediate allocation, so downloads
    /// can genuinely reuse pooled storage.
    pub fn read_into<T: NativeType>(&self, out: &mut Vec<T>) -> Result<()> {
        let data = self.payload_slice::<T>()?;
        out.clear();
        out.extend_from_slice(data);
        Ok(())
    }

    /// Number of elements the payload holds (0 for tuples).
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>() * usize::from(self.payload.is_some())
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        self.tuple
            .ok_or_else(|| Error("to_tuple on a non-tuple literal".into()))
    }
}

// ---------------------------------------------------------------------------
// PJRT client / buffers / executables
// ---------------------------------------------------------------------------

/// Stub PJRT client ("device" buffers live on the host).
pub struct PjRtClient {
    _private: (),
}

/// Stub device buffer: a host literal.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }

    /// On-device shape of the buffer (empty for tuple buffers).
    pub fn dims(&self) -> &[usize] {
        self.literal.dims()
    }
}

/// Parsed HLO module (text retained verbatim; the stub performs no
/// verification beyond reading the file).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An HLO computation awaiting compilation.
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// Compiled executable handle. Never constructed by the stub.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over device buffers, returning per-device output buffers.
    /// The single output buffer wraps the computation's result tuple; use
    /// [`PjRtLoadedExecutable::execute_b_parts`] to keep the elements
    /// device-resident instead of downloading the tuple.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing an HLO computation")
    }

    /// Execute over device buffers and return the output tuple decomposed
    /// into **per-element device buffers** (no host transfer — the real
    /// binding's `untuple_result` execution mode). `donate` lists argument
    /// indices whose buffers are donated to the execution: their device
    /// memory may be aliased for outputs and the caller must not touch
    /// those buffers again. Pass `&[]` to donate nothing.
    pub fn execute_b_parts(
        &self,
        _args: &[&PjRtBuffer],
        _donate: &[usize],
    ) -> Result<Vec<PjRtBuffer>> {
        unavailable("executing an HLO computation")
    }
}

impl PjRtClient {
    /// CPU client. Succeeds so host-side buffer plumbing works.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Upload a host array as a "device" buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let want: usize = dims.iter().product();
        if want != data.len() {
            return Err(Error(format!(
                "buffer_from_host_buffer: {} elements vs dims {:?}",
                data.len(),
                dims
            )));
        }
        Ok(PjRtBuffer { literal: Literal::from_host(data, dims) })
    }

    /// Compilation needs the real backend.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO computation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.dims(), &[3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_as_slice_borrows_without_copy() {
        let l = Literal::vec1(&[4i32, 5, 6]);
        assert_eq!(l.as_slice::<i32>().unwrap(), &[4, 5, 6]);
        assert!(l.as_slice::<f32>().is_err());
        assert!(Literal::tuple(vec![]).as_slice::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1i32]),
            Literal::vec1(&[2.0f32]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
    }

    #[test]
    fn buffers_check_shape() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None)
            .is_ok());
        assert!(c
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[3], None)
            .is_err());
    }

    #[test]
    fn read_into_reuses_allocation() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        let mut out: Vec<f32> = Vec::with_capacity(8);
        out.push(9.0);
        l.read_into(&mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0]);
        assert!(out.capacity() >= 8, "allocation must be reused");
        assert!(l.read_into(&mut Vec::<i32>::new()).is_err());
    }

    #[test]
    fn buffer_exposes_dims() {
        let c = PjRtClient::cpu().unwrap();
        let b = c
            .buffer_from_host_buffer::<f32>(&[0.0; 6], &[2, 3], None)
            .unwrap();
        assert_eq!(b.dims(), &[2, 3]);
        assert_eq!(b.to_literal_sync().unwrap().element_count(), 6);
    }

    #[test]
    fn compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { _text: String::new() };
        let err = c.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
