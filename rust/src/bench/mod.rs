//! Shared benchmark harness (the offline registry has no criterion).
//!
//! Provides the experiment runners used by every `benches/*.rs` target and
//! by the examples: dataset evaluation under a policy (accuracy + tokens/s,
//! the Table 1 row), trajectory capture (Figures 1–2), plain-text tables,
//! CSV emission, and ASCII plots/heatmaps so results render in a terminal
//! the way the paper's figures render on a page.

use std::time::Instant;

use anyhow::Result;

use crate::cache::CacheConfig;
use crate::config::parse_policy_spec;
use crate::decode::{Engine, ForwardModel};
use crate::eval::EvalStats;
use crate::policy::{
    Calibrator, CalibrationTrace, HostTraced, Osdt, Policy, PolicySpec,
    StaticThreshold,
};
use crate::tokenizer::Tokenizer;
use crate::workload::Dataset;

/// Calibration decode policy for OSDT runs (paper: Fast-dLLM static τ=0.9).
pub const CALIBRATION_TAU: f64 = 0.9;

/// One accuracy/throughput measurement — a row of Table 1 or a sweep point.
#[derive(Clone, Debug)]
pub struct EvalRow {
    pub task: String,
    pub policy: String,
    pub n: usize,
    pub accuracy: f64,
    pub tokens_per_sec: f64,
    pub mean_steps: f64,
    pub mean_latency_ms: f64,
    /// wall-clock excluded calibration (paper reports steady-state)
    pub calibration_ms: f64,
    /// mean argmax-fallback activations per sequence (A2 ablation)
    pub mean_fallback: f64,
}

/// Options for a dataset evaluation run.
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// number of eval examples (clamped to dataset size)
    pub n: usize,
    pub cache: CacheConfig,
    /// index of the calibration sequence within the dataset (Algorithm 1
    /// uses the first; the calib-choice ablation varies this)
    pub calibration_index: usize,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts { n: 64, cache: CacheConfig::disabled(), calibration_index: 0 }
    }
}

/// Evaluate `policy_spec` over a dataset with the real decode loop:
/// calibrates first if the spec is OSDT (on `opts.calibration_index`), then
/// decodes `n` evaluation sequences, scoring accuracy and throughput.
pub fn run_eval<M: ForwardModel>(
    model: &M,
    tok: &Tokenizer,
    ds: &Dataset,
    policy_spec: &str,
    opts: &RunOpts,
) -> Result<EvalRow> {
    let cfg = model.config().clone();
    let engine = Engine::with_cache(model, opts.cache);
    let spec = parse_policy_spec(policy_spec)?;

    // ---- Phase 1 (OSDT only): one-shot calibration --------------------------
    let mut calibration_ms = 0.0;
    let policy: Box<dyn Policy> = match &spec {
        PolicySpec::Osdt { mode, metric, kappa, epsilon } => {
            let idx = opts.calibration_index % ds.len();
            let layout = tok.layout_prompt(&cfg, &ds.examples[idx].prompt)?;
            let t0 = Instant::now();
            // calibration needs the full per-step confidence vectors, which
            // the fused decode path never downloads — force the host path
            let cal = engine.decode(
                layout,
                &HostTraced(StaticThreshold::new(CALIBRATION_TAU)),
            )?;
            calibration_ms = t0.elapsed().as_secs_f64() * 1e3;
            let profile = Calibrator::calibrate(&cal.trace, *mode, *metric);
            Box::new(Osdt::from_profile(profile, *kappa, *epsilon))
        }
        other => other.build()?,
    };

    // ---- Phase 2: timed evaluation ------------------------------------------
    let n = opts.n.min(ds.len());
    let mut stats = EvalStats::default();
    let mut total_steps = 0usize;
    let mut total_fallback = 0usize;
    let mut total_latency = 0.0f64;
    let t_run = Instant::now();
    for ex in ds.examples.iter().take(n) {
        let layout = tok.layout_prompt(&cfg, &ex.prompt)?;
        let t0 = Instant::now();
        let res = engine.decode(layout, policy.as_ref())?;
        total_latency += t0.elapsed().as_secs_f64() * 1e3;
        total_steps += res.steps;
        total_fallback += res.fallback_steps;
        let completion = tok.decode_until_eos(res.gen_tokens(&cfg));
        stats.record(ex, &completion);
    }
    let wall = t_run.elapsed().as_secs_f64();
    Ok(EvalRow {
        task: ds.task.clone(),
        policy: policy_spec.to_string(),
        n,
        accuracy: stats.accuracy(),
        tokens_per_sec: (n * cfg.gen_len) as f64 / wall.max(1e-9),
        mean_steps: total_steps as f64 / n.max(1) as f64,
        mean_latency_ms: total_latency / n.max(1) as f64,
        calibration_ms,
        mean_fallback: total_fallback as f64 / n.max(1) as f64,
    })
}

/// Decode `n` sequences with the static calibration policy and return their
/// traces — the raw material of Figures 1 and 2.
pub fn collect_traces<M: ForwardModel>(
    model: &M,
    tok: &Tokenizer,
    ds: &Dataset,
    n: usize,
    tau: f64,
) -> Result<Vec<CalibrationTrace>> {
    let cfg = model.config().clone();
    let engine = Engine::new(model);
    // trace collection is the one consumer that wants raw per-position
    // confidences (Figures 1–2, calibration inputs) — host path, always
    let p = HostTraced(StaticThreshold::new(tau));
    ds.examples
        .iter()
        .take(n.min(ds.len()))
        .map(|ex| {
            let layout = tok.layout_prompt(&cfg, &ex.prompt)?;
            Ok(engine.decode(layout, &p)?.trace)
        })
        .collect()
}

/// Pad/truncate signatures to a common length (block boundaries differ by a
/// step or two across inputs) then mean-pool: the Figure 1 series.
pub fn mean_signature(traces: &[CalibrationTrace]) -> Vec<f64> {
    let len = traces.iter().map(|t| t.signature().len()).min().unwrap_or(0);
    if len == 0 {
        return vec![];
    }
    let mut acc = vec![0.0; len];
    for t in traces {
        for (a, v) in acc.iter_mut().zip(t.signature()) {
            *a += v;
        }
    }
    for a in &mut acc {
        *a /= traces.len() as f64;
    }
    acc
}

/// All-pairs cosine-similarity matrix of trace signatures (Figure 2).
pub fn cosine_matrix(traces: &[CalibrationTrace]) -> Vec<Vec<f64>> {
    let sigs: Vec<Vec<f64>> = traces.iter().map(|t| t.signature()).collect();
    let len = sigs.iter().map(Vec::len).min().unwrap_or(0);
    let n = sigs.len();
    let mut m = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            m[i][j] = crate::util::stats::cosine(&sigs[i][..len], &sigs[j][..len])
                .unwrap_or(f64::NAN);
        }
    }
    m
}

// ---------------------------------------------------------------------------
// Rendering helpers
// ---------------------------------------------------------------------------

/// Fixed-width text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:width$}", c, width = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = line(headers.iter().map(|s| s.to_string()).collect());
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&line(row.clone()));
        out.push('\n');
    }
    out
}

/// ASCII line plot (rows = resolution, series rendered with `*`).
pub fn ascii_plot(series: &[f64], height: usize, title: &str) -> String {
    if series.is_empty() {
        return format!("{title}: (empty)\n");
    }
    let lo = series.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = series.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-9);
    let mut grid = vec![vec![' '; series.len()]; height];
    for (x, &v) in series.iter().enumerate() {
        let y = ((v - lo) / span * (height - 1) as f64).round() as usize;
        grid[height - 1 - y][x] = '*';
    }
    let mut out = format!("{title}  [min {lo:.3}, max {hi:.3}]\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.push_str(&"-".repeat(series.len()));
    out.push('\n');
    out
}

/// ASCII heatmap with a 5-level ramp (for the Figure 2 cosine matrix).
pub fn ascii_heatmap(m: &[Vec<f64>], lo: f64, hi: f64, title: &str) -> String {
    let ramp = [' ', '.', '+', '#', '@'];
    let mut out = format!("{title}  [{lo:.2}..{hi:.2}] ramp ' .+#@'\n");
    for row in m {
        for &v in row {
            let t = ((v - lo) / (hi - lo)).clamp(0.0, 1.0);
            let idx = (t * (ramp.len() - 1) as f64).round() as usize;
            out.push(ramp[idx]);
        }
        out.push('\n');
    }
    out
}

/// CSV emission (results dumped next to the textual report).
pub fn write_csv(path: &str, headers: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut text = headers.join(",");
    text.push('\n');
    for row in rows {
        text.push_str(&row.join(","));
        text.push('\n');
    }
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;
    use crate::sim::SimModel;
    use crate::workload::Example;

    fn sim_dataset(n: usize) -> Dataset {
        Dataset {
            task: "synth-math".into(),
            examples: (0..n)
                .map(|i| Example {
                    task: "synth-math".into(),
                    prompt: format!("Q: {i}+1=?"),
                    answer: format!("{}", i + 1),
                    code_op: None,
                })
                .collect(),
        }
    }

    fn tok() -> Tokenizer {
        Tokenizer::from_config(&tiny_config()).unwrap()
    }

    #[test]
    fn run_eval_static_vs_osdt_on_sim() {
        let m = SimModel::math_like(2);
        let ds = sim_dataset(12);
        let t = tok();
        let stat = run_eval(&m, &t, &ds, "static:0.9", &RunOpts::default()).unwrap();
        let osdt = run_eval(
            &m,
            &t,
            &ds,
            "osdt:block:q1:0.75:0.2",
            &RunOpts::default(),
        )
        .unwrap();
        assert_eq!(stat.n, 12);
        assert!(stat.tokens_per_sec > 0.0);
        assert!(osdt.calibration_ms > 0.0, "OSDT must calibrate");
        // OSDT's q1*(1-eps) thresholds are laxer than static 0.9 on the
        // simulator -> fewer steps
        assert!(
            osdt.mean_steps <= stat.mean_steps,
            "osdt {} vs static {}",
            osdt.mean_steps,
            stat.mean_steps
        );
    }

    #[test]
    fn traces_and_signature_shapes() {
        let m = SimModel::qa_like(4);
        let ds = sim_dataset(6);
        let traces = collect_traces(&m, &tok(), &ds, 4, 0.9).unwrap();
        assert_eq!(traces.len(), 4);
        let sig = mean_signature(&traces);
        assert!(!sig.is_empty());
        let cm = cosine_matrix(&traces);
        assert_eq!(cm.len(), 4);
        for i in 0..4 {
            assert!((cm[i][i] - 1.0).abs() < 1e-9);
            for j in 0..4 {
                assert!(cm[i][j] > 0.9, "cosine {}", cm[i][j]);
            }
        }
    }

    #[test]
    fn render_table_aligns() {
        let t = render_table(
            &["a", "long-header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["wide-cell".into(), "3".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long-header"));
    }

    #[test]
    fn ascii_plot_and_heatmap_render() {
        let p = ascii_plot(&[0.1, 0.5, 0.9, 0.5, 0.1], 5, "u-shape");
        assert!(p.contains('*'));
        let h = ascii_heatmap(&[vec![1.0, 0.0], vec![0.5, 1.0]], 0.0, 1.0, "hm");
        assert!(h.contains('@'));
    }

    #[test]
    fn csv_written() {
        let path = std::env::temp_dir().join(format!("osdt_csv_{}.csv", std::process::id()));
        write_csv(
            path.to_str().unwrap(),
            &["x", "y"],
            &[vec!["1".into(), "2".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,2\n");
        std::fs::remove_file(path).ok();
    }
}
