//! LLaDA-style sequential baseline: a fixed quota of k positions per step,
//! chosen by highest confidence. k=1 is strictly sequential unmasking; the
//! paper's fixed-step schedules correspond to k = block_len / steps.

use super::{Policy, StepContext};

#[derive(Clone, Debug)]
pub struct SequentialTopK {
    k: usize,
}

impl SequentialTopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be >= 1");
        SequentialTopK { k }
    }
}

impl Policy for SequentialTopK {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        let n = ctx.conf.len();
        if n == 0 {
            return vec![];
        }
        let k = self.k.min(n);
        // indices sorted by confidence descending (stable on ties: lower
        // index wins, keeping decode deterministic)
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            ctx.conf[b]
                .partial_cmp(&ctx.conf[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }

    fn name(&self) -> String {
        format!("sequential-top{}", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn ctx(conf: &[f32]) -> StepContext<'_> {
        StepContext { block: 0, step: 0, conf }
    }

    #[test]
    fn picks_top1() {
        let p = SequentialTopK::new(1);
        assert_eq!(p.select(&ctx(&[0.2, 0.9, 0.5])), vec![1]);
    }

    #[test]
    fn picks_topk_in_confidence_order() {
        let p = SequentialTopK::new(2);
        assert_eq!(p.select(&ctx(&[0.2, 0.9, 0.5, 0.8])), vec![1, 3]);
    }

    #[test]
    fn k_larger_than_remaining() {
        let p = SequentialTopK::new(10);
        let mut got = p.select(&ctx(&[0.2, 0.9]));
        got.sort_unstable();
        assert_eq!(got, vec![0, 1]);
    }

    #[test]
    fn ties_break_toward_lower_index() {
        let p = SequentialTopK::new(1);
        assert_eq!(p.select(&ctx(&[0.5, 0.5, 0.5])), vec![0]);
    }

    #[test]
    fn prop_always_selects_exactly_min_k_n() {
        prop::forall(
            "topk-cardinality",
            200,
            |r: &mut Rng| {
                let k = 1 + r.below(8) as usize;
                let conf = prop::gen_f64_vec(r, 1, 40, 0.0, 1.0)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect::<Vec<_>>();
                (k, conf)
            },
            |(k, conf)| {
                let p = SequentialTopK::new(*k);
                let sel = p.select(&StepContext { block: 0, step: 0, conf });
                if sel.len() != (*k).min(conf.len()) {
                    return Err(format!("|S|={} want {}", sel.len(), k.min(&conf.len())));
                }
                // selected confidences dominate unselected ones
                let min_sel = sel
                    .iter()
                    .map(|&i| conf[i])
                    .fold(f32::INFINITY, f32::min);
                for (i, &c) in conf.iter().enumerate() {
                    if !sel.contains(&i) && c > min_sel {
                        return Err(format!("unselected {i} has conf {c} > {min_sel}"));
                    }
                }
                Ok(())
            },
        );
    }
}
