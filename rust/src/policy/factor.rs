//! Fast-dLLM "factor" baseline: a *relative* cutoff — commit every masked
//! position whose confidence is at least `f · c_max`, where c_max is the
//! step's highest confidence among masked positions of the block.
//!
//! Interpretation note (DESIGN.md §5): the Fast-dLLM paper reports a
//! "factor-based" setting without a formal definition in the text we
//! reproduce; the relative-to-max rule is the standard reading (it adapts
//! to the step's confidence level while remaining task-agnostic), and its
//! measured behaviour matches Table 1's shape: slightly higher accuracy
//! than fixed-τ at lower throughput on code, similar on math/qa.

use super::{argmax, PlanContext, Policy, StepContext, StepPlan};

#[derive(Clone, Debug)]
pub struct FactorThreshold {
    factor: f64,
}

impl FactorThreshold {
    pub fn new(factor: f64) -> Self {
        assert!((0.0..=1.0).contains(&factor), "factor must be in [0,1]");
        FactorThreshold { factor }
    }
}

impl Policy for FactorThreshold {
    /// The cutoff depends on the step's own max confidence, so — unlike the
    /// fixed-τ policies — it cannot be quantised exactly from f64 on the
    /// host. The rule is therefore *defined* in f32 (`f · cmax` and the
    /// compares are f32 IEEE ops), which both this host path and the fused
    /// device kernels implement bit-identically. For f ∈ [0, 1] the argmax
    /// is always selected: round-to-nearest of a real ≤ cmax never exceeds
    /// cmax, so liveness is preserved without the fallback.
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        if ctx.conf.is_empty() {
            return vec![];
        }
        let cmax = ctx.conf[argmax(ctx.conf)];
        let cut = self.factor as f32 * cmax;
        (0..ctx.conf.len())
            .filter(|&i| ctx.conf[i] >= cut)
            .collect()
    }

    fn name(&self) -> String {
        format!("factor-{}", self.factor)
    }

    /// The relative cutoff needs only the step's max — which the device
    /// computes itself — so factor steps fuse too.
    fn plan(&self, _ctx: &PlanContext) -> StepPlan {
        StepPlan::factor_max(self.factor as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn relative_cutoff() {
        let p = FactorThreshold::new(0.9);
        // cmax = 0.8 -> cut = 0.72
        let ctx = StepContext { block: 0, step: 0, conf: &[0.8, 0.75, 0.7, 0.1] };
        assert_eq!(p.select(&ctx), vec![0, 1]);
    }

    #[test]
    fn always_includes_argmax() {
        prop::forall(
            "factor-includes-max",
            200,
            |r: &mut Rng| {
                let f = r.next_f64();
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 50, 0.0, 1.0)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                (f, conf)
            },
            |(f, conf)| {
                let p = FactorThreshold::new(*f);
                let sel = p.select(&StepContext { block: 0, step: 0, conf });
                if sel.is_empty() {
                    return Err("liveness violated".into());
                }
                if !sel.contains(&argmax(conf)) {
                    return Err("argmax not selected".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn factor_zero_selects_everything() {
        let p = FactorThreshold::new(0.0);
        let ctx = StepContext { block: 0, step: 0, conf: &[0.1, 0.2, 0.3] };
        assert_eq!(p.select(&ctx).len(), 3);
    }

    #[test]
    fn factor_one_selects_only_max_class() {
        let p = FactorThreshold::new(1.0);
        let ctx = StepContext { block: 0, step: 0, conf: &[0.3, 0.9, 0.9, 0.2] };
        assert_eq!(p.select(&ctx), vec![1, 2]);
    }
}
