//! Fast-dLLM fixed-threshold baseline: commit every masked position whose
//! confidence exceeds a single static global τ (the paper compares against
//! τ = 0.9).

use super::{f32_below, PlanContext, Policy, StepContext, StepPlan};

#[derive(Clone, Debug)]
pub struct StaticThreshold {
    tau: f64,
}

impl StaticThreshold {
    pub fn new(tau: f64) -> Self {
        assert!((0.0..=1.0).contains(&tau), "tau must be in [0,1]");
        StaticThreshold { tau }
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }
}

impl Policy for StaticThreshold {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        (0..ctx.conf.len())
            .filter(|&i| f64::from(ctx.conf[i]) > self.tau)
            .collect()
    }

    /// A global static τ is trivially known ahead of the pass — fusible.
    fn plan(&self, _ctx: &PlanContext) -> StepPlan {
        StepPlan::threshold(f32_below(self.tau))
    }

    fn name(&self) -> String {
        format!("static-tau{}", self.tau)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn selects_above_threshold() {
        let p = StaticThreshold::new(0.5);
        let ctx = StepContext { block: 0, step: 0, conf: &[0.4, 0.6, 0.5, 0.9] };
        assert_eq!(p.select(&ctx), vec![1, 3]); // 0.5 is NOT > 0.5
    }

    #[test]
    fn fallback_when_none_above() {
        let p = StaticThreshold::new(0.95);
        let ctx = StepContext { block: 0, step: 0, conf: &[0.4, 0.6, 0.5] };
        assert_eq!(p.select(&ctx), vec![1]);
    }

    #[test]
    fn prop_selected_iff_above_tau_or_fallback() {
        prop::forall(
            "static-selection-rule",
            200,
            |r: &mut Rng| {
                let tau = r.next_f64();
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 50, 0.0, 1.0)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                (tau, conf)
            },
            |(tau, conf)| {
                let p = StaticThreshold::new(*tau);
                let sel = p.select(&StepContext { block: 0, step: 0, conf });
                if sel.is_empty() {
                    return Err("liveness violated".into());
                }
                let above: Vec<usize> = (0..conf.len())
                    .filter(|&i| f64::from(conf[i]) > *tau)
                    .collect();
                if above.is_empty() {
                    if sel.len() != 1 || conf[sel[0]] < conf.iter().cloned().fold(f32::MIN, f32::max) {
                        return Err("fallback must pick the max".into());
                    }
                } else if sel != above {
                    return Err(format!("sel {sel:?} != above {above:?}"));
                }
                Ok(())
            },
        );
    }
}
