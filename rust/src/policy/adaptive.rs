//! Beyond one-shot: an online-adaptive extension of OSDT (the direction the
//! paper's conclusion sketches — "reusable task-level confidence signatures
//! for more general-purpose algorithmic and systems innovations").
//!
//! `AdaptiveOsdt` starts from a one-shot profile and keeps refining it with
//! an exponential moving average over the traces of every sequence it
//! decodes:
//!
//! ```text
//! τ_new[u] = (1 − α) · τ_old[u] + α · μ(conf_u of the latest sequence)
//! ```
//!
//! α = 0 reduces exactly to OSDT; α = 1 is "always use the latest sequence"
//! (instance-level, which the paper argues is unnecessary). The A5 ablation
//! compares the three regimes.
//!
//! The EMA rule itself lives in [`Profile::blend`] and is shared with the
//! fleet-wide [`super::ProfileRegistry`], whose `observe` path applies the
//! same refinement at registry level; `AdaptiveOsdt` remains as the
//! self-contained per-policy variant the ablations compare against.

use std::sync::RwLock;

use super::{Calibrator, CalibrationTrace, DynamicMode, Metric, Osdt, Policy, Profile, StepContext};

pub struct AdaptiveOsdt {
    mode: DynamicMode,
    metric: Metric,
    kappa: f64,
    epsilon: f64,
    alpha: f64,
    inner: RwLock<Osdt>,
    observed: RwLock<u64>,
}

impl AdaptiveOsdt {
    pub fn new(
        initial: Profile,
        kappa: f64,
        epsilon: f64,
        alpha: f64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
        let mode = initial.mode;
        let metric = initial.metric;
        AdaptiveOsdt {
            mode,
            metric,
            kappa,
            epsilon,
            alpha,
            inner: RwLock::new(Osdt::from_profile(initial, kappa, epsilon)),
            observed: RwLock::new(0),
        }
    }

    /// Fold a decoded sequence's trace into the profile (EMA per unit).
    /// Units present in only one of (old, new) keep the available value.
    pub fn observe(&self, trace: &CalibrationTrace) {
        if self.alpha == 0.0 {
            *self.observed.write().unwrap() += 1;
            return; // pure one-shot
        }
        let fresh = Calibrator::calibrate(trace, self.mode, self.metric);
        let current = self.inner.read().unwrap().profile().clone();
        let blended = current.blend(&fresh, self.alpha);
        *self.inner.write().unwrap() = Osdt::from_profile(blended, self.kappa, self.epsilon);
        *self.observed.write().unwrap() += 1;
    }

    pub fn observed(&self) -> u64 {
        *self.observed.read().unwrap()
    }

    pub fn snapshot(&self) -> Profile {
        self.inner.read().unwrap().profile().clone()
    }
}

impl Policy for AdaptiveOsdt {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        self.inner.read().unwrap().select_raw(ctx)
    }

    /// Deliberately `HostFull` (the trait default, restated for clarity):
    /// `observe` refines the profile from full per-step confidence
    /// vectors, which a fused decode never downloads — so adaptive decodes
    /// keep the classic path even though each step's τ is known upfront.
    fn plan(&self, _ctx: &super::PlanContext) -> super::StepPlan {
        super::StepPlan::host_full()
    }

    fn name(&self) -> String {
        format!(
            "adaptive-osdt-{}-{}-a{}",
            self.mode.as_str(),
            self.metric.as_str(),
            self.alpha
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace_with_level(level: f32) -> CalibrationTrace {
        let mut t = CalibrationTrace::new(2);
        t.record(0, 0, &[level; 4]);
        t.record(0, 1, &[level; 2]);
        t.record(1, 0, &[level; 3]);
        t
    }

    #[test]
    fn alpha_zero_is_pure_one_shot() {
        let init = Profile::block(vec![0.5, 0.5], Metric::Mean);
        let p = AdaptiveOsdt::new(init.clone(), 1.0, 0.0, 0.0);
        p.observe(&trace_with_level(0.9));
        p.observe(&trace_with_level(0.9));
        assert_eq!(p.snapshot(), init);
        assert_eq!(p.observed(), 2);
    }

    #[test]
    fn ema_moves_toward_observations() {
        let init = Profile::block(vec![0.2, 0.2], Metric::Mean);
        let p = AdaptiveOsdt::new(init, 1.0, 0.0, 0.5);
        p.observe(&trace_with_level(0.8));
        let after1 = p.snapshot().tau(0, 0);
        assert!((after1 - 0.5).abs() < 1e-5, "{after1}"); // 0.5*0.2+0.5*0.8
        p.observe(&trace_with_level(0.8));
        let after2 = p.snapshot().tau(0, 0);
        assert!(after2 > after1, "monotone approach");
        assert!(after2 < 0.81);
    }

    #[test]
    fn alpha_one_tracks_latest() {
        let init = Profile::block(vec![0.1, 0.1], Metric::Mean);
        let p = AdaptiveOsdt::new(init, 1.0, 0.0, 1.0);
        p.observe(&trace_with_level(0.7));
        assert!((p.snapshot().tau(0, 0) - 0.7).abs() < 1e-5);
        p.observe(&trace_with_level(0.3));
        assert!((p.snapshot().tau(0, 0) - 0.3).abs() < 1e-5);
    }

    #[test]
    fn step_block_blending_preserves_depth() {
        let init = Profile::step_block(vec![vec![0.2, 0.4], vec![0.6]], Metric::Q1);
        let p = AdaptiveOsdt::new(init, 1.0, 0.0, 0.5);
        let mut t = CalibrationTrace::new(2);
        t.record(0, 0, &[0.8; 4]);
        t.record(0, 1, &[0.8; 4]);
        t.record(0, 2, &[0.8; 4]); // deeper than the initial profile
        t.record(1, 0, &[0.8; 4]);
        p.observe(&t);
        let snap = p.snapshot();
        assert_eq!(snap.steps_in_block(0), 3);
        // step 2 blends old clamped value (0.4) with new 0.8
        assert!((snap.tau(0, 2) - 0.6).abs() < 1e-5, "{}", snap.tau(0, 2));
    }

    #[test]
    fn selection_uses_blended_threshold() {
        let init = Profile::block(vec![0.95], Metric::Mean);
        let p = AdaptiveOsdt::new(init, 1.0, 0.0, 1.0);
        let conf = [0.5f32, 0.6];
        // initially strict -> fallback picks argmax only
        let s0 = p.select(&StepContext { block: 0, step: 0, conf: &conf });
        assert_eq!(s0, vec![1]);
        // after observing a low-confidence task, both clear the threshold
        p.observe(&trace_with_level(0.3));
        let s1 = p.select(&StepContext { block: 0, step: 0, conf: &conf });
        assert_eq!(s1, vec![0, 1]);
    }

    #[test]
    fn concurrent_observe_and_select() {
        let init = Profile::block(vec![0.5, 0.5], Metric::Mean);
        let p = std::sync::Arc::new(AdaptiveOsdt::new(init, 1.0, 0.0, 0.2));
        let mut handles = vec![];
        for i in 0..4 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..200 {
                    if (i + j) % 2 == 0 {
                        p.observe(&trace_with_level(0.7));
                    } else {
                        let conf = [0.4f32, 0.9];
                        let s = p.select(&StepContext { block: 0, step: 0, conf: &conf });
                        assert!(!s.is_empty());
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.observed(), 400);
    }
}
