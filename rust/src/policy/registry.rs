//! Fleet-wide profile registry: the single home of the OSDT calibration
//! lifecycle (DESIGN.md §9).
//!
//! One `Arc<ProfileRegistry>` is shared by every coordinator replica (and
//! the router in front of them), subsuming what used to be three
//! disconnected layers: the coordinator's in-memory profile map, the
//! on-disk [`ProfileStore`] (now the registry's warm-start + persistence
//! backend), and `AdaptiveOsdt`'s private EMA state (now the registry's
//! observation path).
//!
//! Three mechanisms:
//!
//! - **Single-flight calibration.** The first [`ProfileRegistry::acquire`]
//!   for an uncalibrated `(task, mode, metric)` key receives a
//!   [`CalibrationLease`]; every concurrent peer — same worker, sibling
//!   worker, or another replica — observes `InFlight` and is co-scheduled
//!   around the lease instead of calibrating redundantly. Dropping an
//!   unfulfilled lease (failed or panicked calibration) releases the key so
//!   a peer can retry; a lease outstanding past the caller's patience can
//!   be stolen with [`ProfileRegistry::acquire_stealing`], bounding the
//!   worst-case stall without giving up single-flight in the common case.
//!
//! - **Signature-drift recalibration.** Every completed OSDT decode is
//!   [`ProfileRegistry::observe`]d: the sequence's per-block step-mean
//!   confidence signature is compared (cosine, with the shorter block
//!   clamp-extended — mirroring `Profile::tau` step clamping) against the
//!   profile's drift reference, which is adopted from the first
//!   post-calibration decode so the comparison is policy-matched (an OSDT
//!   decode takes systematically fewer steps than the static calibration
//!   decode, which must not read as drift). Below `drift_floor` the
//!   profile is marked stale; the next `acquire` receives a recalibration
//!   lease while concurrent traffic keeps being served from the stale
//!   profile — drift never stops the fleet, it schedules a recalibration.
//!
//! - **Warm-start persistence.** With a [`ProfileStore`] attached, every
//!   fulfilled calibration is persisted (atomic temp-file + rename) and a
//!   restarted process reloads the whole profile set at construction —
//!   zero calibrations after a restart.
//!
//! The registry keeps its own metrics [`Registry`](MetricsRegistry)
//! (profile hits/misses/stale serves, leases granted/abandoned/stolen,
//! calibrations/recalibrations, drift events, EMA updates, and a
//! `profile_signature_cosine` histogram) so fleet-wide numbers exist in
//! one place no matter how many coordinators share the instance.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Registry as MetricsRegistry;

use super::profile::{ProfileRecord, ProfileStore, StoreLease};
use super::{CalibrationTrace, Calibrator, DynamicMode, Metric, Profile};

/// Identity of a calibrated profile.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ProfileKey {
    pub task: String,
    pub mode: DynamicMode,
    pub metric: Metric,
}

impl ProfileKey {
    pub fn new(task: impl Into<String>, mode: DynamicMode, metric: Metric) -> Self {
        ProfileKey { task: task.into(), mode, metric }
    }
}

impl std::fmt::Display for ProfileKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{}/{}",
            self.task,
            self.mode.as_str(),
            self.metric.as_str()
        )
    }
}

/// A registered profile plus its live bookkeeping.
#[derive(Clone, Debug)]
pub struct ProfileEntry {
    pub profile: Profile,
    /// Flat calibration signature (provenance; persisted). Empty for
    /// schema-1 warm starts until adopted from the first observed decode.
    pub signature: Vec<f64>,
    /// Drift reference: per-block step-mean signature of the first
    /// *post-calibration* decode, so comparisons are policy-matched.
    /// In-memory only; re-adopted after a restart.
    pub drift_ref: Vec<Vec<f64>>,
    /// Increments on every calibration, recalibration, or EMA update.
    pub version: u64,
    /// Calibration generation: bumps only when a lease is fulfilled (not
    /// on EMA updates). Observations carry the epoch they decoded under so
    /// a decode that started before a recalibration cannot poison the new
    /// profile's drift reference.
    pub epoch: u64,
    /// Marked by drift detection or admin invalidation; a stale entry keeps
    /// serving until its recalibration lease is fulfilled.
    pub stale: bool,
    /// Completed OSDT decodes folded into drift/EMA tracking.
    pub observed: u64,
    /// Elision mispredictions accumulated against this calibration epoch;
    /// reaching [`RegistryConfig::misprediction_floor`] marks the entry
    /// stale. Reset by recalibration (a fulfilled lease installs a fresh
    /// entry).
    pub mispredicted: u64,
    /// Loaded from disk rather than calibrated in this process.
    pub warm_started: bool,
}

struct Slot {
    entry: Option<ProfileEntry>,
    /// A calibration lease is outstanding for this key.
    leased: bool,
    /// Sequence number of the most recently granted lease. Fulfill/abandon
    /// only clear `leased` when their lease is still the current one, so a
    /// superseded lease (its holder was stolen from) resolving late cannot
    /// release the thief's outstanding lease and re-open single-flight.
    lease_seq: u64,
}

/// Registry tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct RegistryConfig {
    /// Cosine floor between a decode's signature and the profile's
    /// calibration signature; below it the profile is marked stale.
    pub drift_floor: f64,
    /// EMA refinement rate folded in per observed decode (0 = pure
    /// one-shot, the paper's setting; 1 = always track the latest).
    pub ema_alpha: f64,
    /// Accumulated elision mispredictions (profile predicted an empty run,
    /// the landing step fell back to argmax) at which the profile is marked
    /// stale. Mispredicted elisions are drift the signature path can't see
    /// — the skipped steps were never executed — so they get their own
    /// staleness trigger. The counter resets on recalibration.
    pub misprediction_floor: u64,
    /// Coordinate calibration leases *across processes* through the
    /// attached [`ProfileStore`] (DESIGN.md §16): lease grants are fenced
    /// by an exclusive lease file, fulfilled calibrations bump the store's
    /// generation counter, and peers adopt newer on-disk profile versions
    /// instead of recalibrating. No-op without a store. CLI:
    /// `serve --fleet-locks on`.
    pub cross_process: bool,
    /// Age past which a cross-process lease file whose holder cannot be
    /// confirmed dead is broken anyway (clock-skew-safe upper bound on a
    /// calibration decode).
    pub cross_lease_ttl: Duration,
    /// Minimum spacing between cross-process store-generation checks; the
    /// store is only re-scanned when the generation actually moved.
    pub sync_interval: Duration,
}

impl Default for RegistryConfig {
    fn default() -> Self {
        RegistryConfig {
            drift_floor: 0.95,
            ema_alpha: 0.0,
            misprediction_floor: 8,
            cross_process: false,
            cross_lease_ttl: Duration::from_secs(60),
            sync_interval: Duration::from_millis(250),
        }
    }
}

/// Outcome of an acquire.
pub enum Acquired<'r> {
    /// A usable profile (possibly stale while its recalibration is in
    /// flight — drift never blocks traffic) and the epoch it belongs to
    /// (pass back to [`ProfileRegistry::observe`] after the decode).
    Ready(Profile, u64),
    /// Caller holds the fleet-wide calibration lease for this key: decode
    /// with the static calibration policy and fulfill (or drop to release).
    Lease(CalibrationLease<'r>),
    /// Another caller holds the lease; park the request and retry when the
    /// profile lands.
    InFlight,
}

/// Exclusive right to calibrate one key. Fulfill with the calibrated
/// profile; dropping without fulfilling releases the key for a peer.
pub struct CalibrationLease<'r> {
    registry: &'r ProfileRegistry,
    key: ProfileKey,
    seq: u64,
    fulfilled: bool,
    /// Cross-process lease file fencing peer *processes* while this lease
    /// is outstanding (None when `cross_process` is off). Released on
    /// drop, after fulfill/abandon has resolved the in-memory lease.
    _store_lease: Option<StoreLease>,
}

impl CalibrationLease<'_> {
    pub fn key(&self) -> &ProfileKey {
        &self.key
    }

    /// Install the calibrated profile (version bump + persistence + wakeup
    /// of parked peers).
    pub fn fulfill(mut self, profile: Profile, signature: Vec<f64>) {
        self.fulfilled = true;
        self.registry.fulfill(&self.key, self.seq, profile, signature);
    }
}

impl Drop for CalibrationLease<'_> {
    fn drop(&mut self) {
        if !self.fulfilled {
            self.registry.abandon(&self.key, self.seq);
        }
    }
}

/// Snapshot row for admin listings.
#[derive(Clone, Debug)]
pub struct ProfileSummary {
    pub key: ProfileKey,
    pub version: u64,
    pub stale: bool,
    pub leased: bool,
    pub observed: u64,
    pub warm_started: bool,
    pub num_blocks: usize,
}

pub struct ProfileRegistry {
    slots: Mutex<HashMap<ProfileKey, Slot>>,
    cv: Condvar,
    store: Option<ProfileStore>,
    cfg: RegistryConfig,
    metrics: Arc<MetricsRegistry>,
    /// Bumps whenever a lease resolves (fulfilled or abandoned) — the only
    /// registry events that can change a parked request's admission class.
    /// Coordinators snapshot it to skip re-classifying their parked queues
    /// on iterations where no lease resolved.
    release_gen: AtomicU64,
    /// Cross-process sync throttle (see [`ProfileRegistry::maybe_sync`]).
    sync: Mutex<SyncState>,
}

struct SyncState {
    last_check: Instant,
    last_gen: u64,
}

impl ProfileRegistry {
    /// Ephemeral registry (no persistence) with default tuning.
    pub fn in_memory() -> Self {
        Self::with_config(RegistryConfig::default())
    }

    pub fn with_config(cfg: RegistryConfig) -> Self {
        ProfileRegistry {
            slots: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            store: None,
            cfg,
            metrics: Arc::new(MetricsRegistry::new()),
            release_gen: AtomicU64::new(0),
            sync: Mutex::new(SyncState {
                last_check: Instant::now(),
                last_gen: 0,
            }),
        }
    }

    /// Registry backed by `store`: warm-starts from every record on disk
    /// and persists every fulfilled calibration.
    pub fn with_store(store: ProfileStore, cfg: RegistryConfig) -> Result<Self> {
        let mut reg = Self::with_config(cfg);
        let records = store.load_all()?;
        let n = records.len();
        {
            let mut slots = reg.slots.lock().unwrap();
            for rec in records {
                let key =
                    ProfileKey::new(rec.task, rec.profile.mode, rec.profile.metric);
                slots.insert(
                    key,
                    Slot {
                        entry: Some(ProfileEntry {
                            profile: rec.profile,
                            signature: rec.signature,
                            drift_ref: vec![],
                            version: rec.version.max(1),
                            epoch: rec.version.max(1),
                            stale: false,
                            observed: 0,
                            mispredicted: 0,
                            warm_started: true,
                        }),
                        leased: false,
                        lease_seq: 0,
                    },
                );
            }
        }
        reg.metrics.add("profile_warm_starts", n as u64);
        // The warm start already reflects the store's current content:
        // record the generation so the first maybe_sync doesn't rescan.
        reg.sync.lock().unwrap().last_gen = store.generation();
        reg.store = Some(store);
        Ok(reg)
    }

    pub fn config(&self) -> &RegistryConfig {
        &self.cfg
    }

    /// Lease-release generation: increments on every fulfilled or
    /// abandoned lease. Unchanged generation ⇒ no parked request's
    /// admission class changed since it was read (time-based transitions
    /// aside), so a coordinator may skip rescanning its parked queue.
    pub fn lease_release_generation(&self) -> u64 {
        self.release_gen.load(Ordering::Acquire)
    }

    /// Fleet-wide profile/lease metrics (separate from any coordinator's).
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// Resolve `key` for one request: a ready profile, the calibration
    /// lease (first caller for an uncalibrated or stale key), or `InFlight`
    /// when a peer holds the lease. Never blocks.
    pub fn acquire(&self, key: &ProfileKey) -> Acquired<'_> {
        self.acquire_inner(key, false)
    }

    /// As [`ProfileRegistry::acquire`], but a key whose lease is held by a
    /// peer is taken over instead of reported `InFlight` — the escape hatch
    /// for a calibration that has been in flight past the caller's
    /// patience. The duplicated calibration resolves last-writer-wins.
    pub fn acquire_stealing(&self, key: &ProfileKey) -> Acquired<'_> {
        self.acquire_inner(key, true)
    }

    fn acquire_inner(&self, key: &ProfileKey, steal: bool) -> Acquired<'_> {
        self.maybe_sync();
        let mut slots = self.slots.lock().unwrap();
        let slot = slots
            .entry(key.clone())
            .or_insert_with(|| Slot { entry: None, leased: false, lease_seq: 0 });
        match (&slot.entry, slot.leased) {
            (Some(e), _) if !e.stale => {
                self.metrics.add("profile_hits", 1);
                Acquired::Ready(e.profile.clone(), e.epoch)
            }
            // stale with a recalibration already in flight: keep serving
            (Some(e), true) => {
                self.metrics.add("profile_stale_serves", 1);
                Acquired::Ready(e.profile.clone(), e.epoch)
            }
            (Some(_), false) => match self.cross_lease(key, steal) {
                CrossLease::Granted(sl) => {
                    slot.lease_seq += 1;
                    slot.leased = true;
                    self.metrics.add("leases_granted", 1);
                    Acquired::Lease(CalibrationLease {
                        registry: self,
                        key: key.clone(),
                        seq: slot.lease_seq,
                        fulfilled: false,
                        _store_lease: sl,
                    })
                }
                // a peer *process* holds the recalibration: keep serving
                // the stale profile, exactly like a local in-flight lease
                CrossLease::PeerHolds => {
                    self.metrics.add("profile_stale_serves", 1);
                    let e = slot.entry.as_ref().expect("entry matched Some");
                    Acquired::Ready(e.profile.clone(), e.epoch)
                }
            },
            (None, false) => match self.cross_lease(key, steal) {
                CrossLease::Granted(sl) => {
                    slot.lease_seq += 1;
                    slot.leased = true;
                    self.metrics.add("profile_misses", 1);
                    self.metrics.add("leases_granted", 1);
                    Acquired::Lease(CalibrationLease {
                        registry: self,
                        key: key.clone(),
                        seq: slot.lease_seq,
                        fulfilled: false,
                        _store_lease: sl,
                    })
                }
                CrossLease::PeerHolds => {
                    self.metrics.add("profile_waits", 1);
                    Acquired::InFlight
                }
            },
            (None, true) => {
                if steal {
                    // takeover becomes the *current* lease: the superseded
                    // holder's late fulfill/abandon can no longer clear it
                    slot.lease_seq += 1;
                    slot.leased = true;
                    self.metrics.add("lease_takeovers", 1);
                    let sl = match self.cross_lease(key, true) {
                        CrossLease::Granted(sl) => sl,
                        CrossLease::PeerHolds => None, // unreachable on steal
                    };
                    Acquired::Lease(CalibrationLease {
                        registry: self,
                        key: key.clone(),
                        seq: slot.lease_seq,
                        fulfilled: false,
                        _store_lease: sl,
                    })
                } else {
                    self.metrics.add("profile_waits", 1);
                    Acquired::InFlight
                }
            }
        }
    }

    /// Take the cross-process lease file for `key` (no-op `Granted(None)`
    /// when cross-process mode is off or no store is attached). `steal`
    /// forces the takeover — the in-memory protocol has already decided
    /// the outstanding holder is past its patience. I/O errors fail open
    /// to local-only single-flight: a broken shared filesystem degrades
    /// to at-most-once *per process*, never to a stalled fleet.
    fn cross_lease(&self, key: &ProfileKey, steal: bool) -> CrossLease {
        if !self.cfg.cross_process {
            return CrossLease::Granted(None);
        }
        let Some(store) = &self.store else {
            return CrossLease::Granted(None);
        };
        if steal {
            match store.force_lease(&key.task, key.mode, key.metric) {
                Ok(sl) => {
                    if sl.took_over {
                        self.metrics.add("cross_lease_takeovers", 1);
                    }
                    CrossLease::Granted(Some(sl))
                }
                Err(e) => {
                    log::warn!("cross-lease force for {key}: {e:#}");
                    CrossLease::Granted(None)
                }
            }
        } else {
            match store.try_lease(
                &key.task,
                key.mode,
                key.metric,
                self.cfg.cross_lease_ttl,
            ) {
                Ok(Some(sl)) => {
                    if sl.took_over {
                        self.metrics.add("cross_lease_takeovers", 1);
                    }
                    CrossLease::Granted(Some(sl))
                }
                Ok(None) => {
                    self.metrics.add("cross_lease_conflicts", 1);
                    CrossLease::PeerHolds
                }
                Err(e) => {
                    log::warn!("cross-lease attempt for {key}: {e:#}");
                    CrossLease::Granted(None)
                }
            }
        }
    }

    /// How `acquire` would classify `key` right now, without taking a
    /// lease — the coordinator's admission parking decisions use this.
    /// A stale entry reports `Ready`: it still serves traffic, and the one
    /// request that lands the recalibration lease runs it inline rather
    /// than parking every same-key request behind the drift event.
    pub fn peek(&self, key: &ProfileKey) -> PeekState {
        self.maybe_sync();
        let slots = self.slots.lock().unwrap();
        match slots.get(key) {
            None => PeekState::WouldCalibrate,
            Some(slot) => match (&slot.entry, slot.leased) {
                (Some(_), _) => PeekState::Ready,
                (None, true) => PeekState::InFlight,
                (None, false) => PeekState::WouldCalibrate,
            },
        }
    }

    /// Block until `key` has a usable profile (or `timeout`); used by
    /// callers with nothing better to do than wait on a peer's calibration.
    /// In cross-process mode the wait is chunked at `sync_interval` so a
    /// fulfill in a *peer process* (no local condvar notify) is still
    /// observed promptly via the store's generation counter.
    pub fn wait_ready(&self, key: &ProfileKey, timeout: Duration) -> Option<Profile> {
        let deadline = Instant::now() + timeout;
        loop {
            self.maybe_sync();
            let slots = self.slots.lock().unwrap();
            if let Some(e) = slots.get(key).and_then(|s| s.entry.as_ref()) {
                return Some(e.profile.clone());
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            let chunk = if self.cfg.cross_process {
                left.min(self.cfg.sync_interval)
            } else {
                left
            };
            // Guard drops at loop end; re-checked after every wakeup.
            let _ = self.cv.wait_timeout(slots, chunk).unwrap();
        }
    }

    /// Rate-limited cross-process sync: when the shared store's generation
    /// counter has moved past what this process last saw, re-scan the
    /// store and adopt any record whose version is newer than the local
    /// one. Adoption — not recalibration: the peer that fulfilled the
    /// lease already paid the calibration decode, which is what makes a
    /// drift event on one replica recalibrate exactly once fleet-wide.
    /// No-op unless `cross_process` is on and a store is attached.
    pub fn maybe_sync(&self) {
        if !self.cfg.cross_process || self.store.is_none() {
            return;
        }
        {
            let mut sync = self.sync.lock().unwrap();
            if sync.last_check.elapsed() < self.cfg.sync_interval {
                return;
            }
            sync.last_check = Instant::now();
            let gen = self.store.as_ref().expect("checked above").generation();
            if gen == sync.last_gen {
                return;
            }
            sync.last_gen = gen;
        }
        self.sync_from_store();
    }

    /// Unconditional store re-scan: adopt every on-disk record whose
    /// version is newer than the in-memory one. Public so tests and the
    /// admin path can force a sync without waiting out the throttle.
    pub fn sync_from_store(&self) {
        let Some(store) = &self.store else { return };
        let records = match store.load_all() {
            Ok(r) => r,
            Err(e) => {
                log::warn!("cross-process store scan failed: {e:#}");
                return;
            }
        };
        let mut adopted = 0u64;
        {
            let mut slots = self.slots.lock().unwrap();
            for rec in records {
                let key = ProfileKey::new(
                    rec.task.clone(),
                    rec.profile.mode,
                    rec.profile.metric,
                );
                let slot = slots.entry(key).or_insert_with(|| Slot {
                    entry: None,
                    leased: false,
                    lease_seq: 0,
                });
                let version = rec.version.max(1);
                let local = slot.entry.as_ref().map(|e| e.version).unwrap_or(0);
                if version <= local {
                    continue;
                }
                slot.entry = Some(ProfileEntry {
                    profile: rec.profile,
                    signature: rec.signature,
                    drift_ref: vec![],
                    version,
                    epoch: version,
                    stale: false,
                    observed: 0,
                    mispredicted: 0,
                    warm_started: true,
                });
                adopted += 1;
            }
        }
        if adopted > 0 {
            self.metrics.add("profile_cross_adoptions", adopted);
            // Adoption changes parked requests' admission class exactly
            // like a local fulfill: bump + wake waiters.
            self.release_gen.fetch_add(1, Ordering::AcqRel);
            self.cv.notify_all();
        }
    }

    fn fulfill(&self, key: &ProfileKey, seq: u64, profile: Profile, signature: Vec<f64>) {
        let record = {
            let mut slots = self.slots.lock().unwrap();
            let slot = slots
                .entry(key.clone())
                .or_insert_with(|| Slot { entry: None, leased: false, lease_seq: 0 });
            let prior = slot.entry.as_ref().map(|e| e.version).unwrap_or(0);
            let recalibration = slot.entry.is_some();
            let version = prior + 1;
            slot.entry = Some(ProfileEntry {
                profile: profile.clone(),
                signature: signature.clone(),
                drift_ref: vec![],
                version,
                epoch: version,
                stale: false,
                observed: 0,
                mispredicted: 0,
                warm_started: false,
            });
            // a superseded lease (stolen from) still installs its result
            // (last-writer-wins) but must not release the current holder's
            // outstanding lease
            if slot.lease_seq == seq {
                slot.leased = false;
            }
            self.metrics.add("calibrations_completed", 1);
            if recalibration {
                self.metrics.add("recalibrations", 1);
            }
            ProfileRecord {
                task: key.task.clone(),
                profile,
                signature,
                version,
            }
        };
        self.release_gen.fetch_add(1, Ordering::AcqRel);
        self.cv.notify_all();
        self.persist(&record);
    }

    fn abandon(&self, key: &ProfileKey, seq: u64) {
        let released = {
            let mut slots = self.slots.lock().unwrap();
            match slots.get_mut(key) {
                // only the current lease may release the key; a superseded
                // holder's failure must not re-open single-flight under the
                // thief still calibrating
                Some(slot) if slot.lease_seq == seq && slot.leased => {
                    slot.leased = false;
                    true
                }
                _ => false,
            }
        };
        if released {
            self.release_gen.fetch_add(1, Ordering::AcqRel);
            self.metrics.add("leases_abandoned", 1);
            self.cv.notify_all();
        } else {
            self.metrics.add("leases_superseded", 1);
        }
    }

    fn persist(&self, record: &ProfileRecord) {
        if let Some(store) = &self.store {
            if let Err(e) = store.save(record) {
                self.metrics.add("profile_persist_errors", 1);
                log::warn!("persisting profile {}: {e:#}", record.task);
            }
            // Signal peers *after* the record is on disk, so a generation
            // bump always points at a readable newer version.
            if self.cfg.cross_process {
                if let Err(e) = store.bump_generation() {
                    self.metrics.add("profile_persist_errors", 1);
                    log::warn!("bumping profile generation: {e:#}");
                }
            }
        }
    }

    /// Fold one completed OSDT decode into the registry: drift detection
    /// against the profile's drift reference, then (α > 0) EMA refinement
    /// of the thresholds — `AdaptiveOsdt`'s update rule at registry level.
    /// `epoch` is the value [`Acquired::Ready`] handed out when the decode
    /// acquired its profile; an observation from a superseded epoch (the
    /// key was recalibrated while the decode was in flight) is dropped so
    /// it cannot poison the new profile's drift reference.
    pub fn observe(&self, key: &ProfileKey, epoch: u64, trace: &CalibrationTrace) {
        let sig = trace.block_signatures();
        if sig.iter().all(Vec::is_empty) {
            return;
        }
        let mut slots = self.slots.lock().unwrap();
        let Some(entry) = slots.get_mut(key).and_then(|s| s.entry.as_mut()) else {
            return; // invalidated/removed since the decode started
        };
        if entry.epoch != epoch {
            self.metrics.add("observations_superseded", 1);
            return;
        }
        entry.observed += 1;
        if entry.signature.is_empty() {
            // schema-1 warm start: adopt provenance from the first decode
            entry.signature = trace.signature();
        }
        if entry.drift_ref.iter().all(Vec::is_empty) {
            // first post-calibration decode becomes the (policy-matched)
            // drift reference
            entry.drift_ref = sig;
            return;
        }
        if let Some(cos) = signature_cosine(&entry.drift_ref, &sig) {
            self.metrics.observe("profile_signature_cosine", cos);
            if cos < self.cfg.drift_floor && !entry.stale {
                entry.stale = true;
                self.metrics.add("drift_events", 1);
                log::info!(
                    "profile {key} drifted (cosine {cos:.4} < floor {}); \
                     recalibration scheduled",
                    self.cfg.drift_floor
                );
                return; // recalibration supersedes refinement
            }
        }
        if self.cfg.ema_alpha > 0.0 && !entry.stale {
            let fresh = Calibrator::calibrate(trace, key.mode, key.metric);
            entry.profile = entry.profile.blend(&fresh, self.cfg.ema_alpha);
            entry.version += 1;
            self.metrics.add("profile_ema_updates", 1);
        }
    }

    /// Fold `n` elision mispredictions from one completed decode into the
    /// profile's staleness tracking. A misprediction means the profile's
    /// acceptance trajectory told the planner a step run would be empty but
    /// the landing step fell back to argmax — evidence of drift that
    /// [`ProfileRegistry::observe`]'s signature comparison structurally
    /// cannot see, because the elided steps were never executed. Crossing
    /// [`RegistryConfig::misprediction_floor`] marks the entry stale
    /// exactly like a cosine drift event: the next acquire receives a
    /// recalibration lease while traffic keeps being served. Epoch-guarded
    /// like `observe` — mispredictions from a decode that started before a
    /// recalibration cannot poison the fresh profile.
    pub fn note_elision_mispredictions(&self, key: &ProfileKey, epoch: u64, n: u64) {
        if n == 0 {
            return;
        }
        let mut slots = self.slots.lock().unwrap();
        let Some(entry) = slots.get_mut(key).and_then(|s| s.entry.as_mut()) else {
            return; // invalidated/removed since the decode started
        };
        if entry.epoch != epoch {
            self.metrics.add("observations_superseded", 1);
            return;
        }
        entry.mispredicted += n;
        if entry.mispredicted >= self.cfg.misprediction_floor && !entry.stale {
            entry.stale = true;
            self.metrics.add("drift_events", 1);
            log::info!(
                "profile {key} accumulated {} elision mispredictions \
                 (floor {}); recalibration scheduled",
                entry.mispredicted,
                self.cfg.misprediction_floor
            );
        }
    }

    /// Mark a profile stale so the next request recalibrates. Returns
    /// whether the key was present.
    pub fn invalidate(&self, key: &ProfileKey) -> bool {
        let mut slots = self.slots.lock().unwrap();
        match slots.get_mut(key).and_then(|s| s.entry.as_mut()) {
            Some(entry) => {
                if !entry.stale {
                    entry.stale = true;
                    self.metrics.add("profile_invalidations", 1);
                }
                true
            }
            None => false,
        }
    }

    pub fn get(&self, key: &ProfileKey) -> Option<ProfileEntry> {
        self.slots
            .lock()
            .unwrap()
            .get(key)
            .and_then(|s| s.entry.clone())
    }

    /// Registered profile count (calibrated or warm-started).
    pub fn len(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .filter(|s| s.entry.is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admin listing, sorted by key for stable output.
    pub fn snapshot(&self) -> Vec<ProfileSummary> {
        let slots = self.slots.lock().unwrap();
        let mut out: Vec<ProfileSummary> = slots
            .iter()
            .filter_map(|(key, slot)| {
                slot.entry.as_ref().map(|e| ProfileSummary {
                    key: key.clone(),
                    version: e.version,
                    stale: e.stale,
                    leased: slot.leased,
                    observed: e.observed,
                    warm_started: e.warm_started,
                    num_blocks: e.profile.num_blocks(),
                })
            })
            .collect();
        out.sort_by(|a, b| {
            (&a.key.task, a.key.mode.as_str(), a.key.metric.as_str()).cmp(&(
                &b.key.task,
                b.key.mode.as_str(),
                b.key.metric.as_str(),
            ))
        });
        out
    }
}

/// Outcome of a cross-process lease-file attempt.
enum CrossLease {
    /// The caller may calibrate; holds the lease file when Some (None when
    /// cross-process mode is off or the filesystem failed open).
    Granted(Option<StoreLease>),
    /// A live peer process holds the fleet-wide lease.
    PeerHolds,
}

/// What `acquire` would do for a key right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeekState {
    /// A usable (possibly stale-but-leased) profile exists.
    Ready,
    /// This caller would receive the calibration lease.
    WouldCalibrate,
    /// A peer holds the lease; the caller would be told `InFlight`.
    InFlight,
}

/// Cosine between two per-block step-mean signatures. Blocks are aligned
/// by index; within a block the shorter signature is clamp-extended by
/// repeating its last step mean (mirroring `Profile::tau` step clamping),
/// so a policy legitimately finishing a block in fewer steps does not read
/// as drift. A block present in only one signature contributes zeros.
pub fn signature_cosine(a: &[Vec<f64>], b: &[Vec<f64>]) -> Option<f64> {
    if a.iter().all(Vec::is_empty) || b.iter().all(Vec::is_empty) {
        return None;
    }
    let empty: Vec<f64> = vec![];
    let mut fa = Vec::new();
    let mut fb = Vec::new();
    for i in 0..a.len().max(b.len()) {
        let xa = a.get(i).unwrap_or(&empty);
        let xb = b.get(i).unwrap_or(&empty);
        for s in 0..xa.len().max(xb.len()) {
            let clamp = |x: &[f64]| {
                x.get(s)
                    .copied()
                    .or_else(|| x.last().copied())
                    .unwrap_or(0.0)
            };
            fa.push(clamp(xa));
            fb.push(clamp(xb));
        }
    }
    crate::util::stats::cosine(&fa, &fb)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> ProfileKey {
        ProfileKey::new("synth-math", DynamicMode::Block, Metric::Q1)
    }

    fn profile(tau: f64) -> Profile {
        Profile::block(vec![tau, tau], Metric::Q1)
    }

    fn trace_with_signature(sig: &[f64]) -> CalibrationTrace {
        let mut t = CalibrationTrace::new(1);
        for (s, &v) in sig.iter().enumerate() {
            t.record(0, s, &[v as f32]);
        }
        t
    }

    #[test]
    fn first_acquire_leases_then_ready() {
        let reg = ProfileRegistry::in_memory();
        let lease = match reg.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!("first acquire must lease"),
        };
        // a peer sees the in-flight lease, not a second lease
        assert!(matches!(reg.acquire(&key()), Acquired::InFlight));
        assert_eq!(reg.peek(&key()), PeekState::InFlight);
        lease.fulfill(profile(0.6), vec![0.6, 0.6]);
        match reg.acquire(&key()) {
            Acquired::Ready(p, epoch) => {
                assert!((p.tau(0, 0) - 0.6).abs() < 1e-12);
                assert_eq!(epoch, 1);
            }
            _ => panic!("fulfilled key must be ready"),
        }
        assert_eq!(reg.metrics().counter_value("calibrations_completed"), 1);
        assert_eq!(reg.metrics().counter_value("leases_granted"), 1);
    }

    #[test]
    fn dropped_lease_releases_the_key() {
        let reg = ProfileRegistry::in_memory();
        {
            let _lease = match reg.acquire(&key()) {
                Acquired::Lease(l) => l,
                _ => panic!(),
            };
            // dropped unfulfilled (failed calibration)
        }
        assert_eq!(reg.metrics().counter_value("leases_abandoned"), 1);
        assert!(matches!(reg.acquire(&key()), Acquired::Lease(_)));
    }

    #[test]
    fn stealing_breaks_a_stuck_lease() {
        let reg = ProfileRegistry::in_memory();
        let _stuck = match reg.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!(),
        };
        assert!(matches!(reg.acquire(&key()), Acquired::InFlight));
        let thief = match reg.acquire_stealing(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!("steal must grant a lease"),
        };
        thief.fulfill(profile(0.5), vec![0.5]);
        assert!(matches!(reg.acquire(&key()), Acquired::Ready(..)));
        assert_eq!(reg.metrics().counter_value("lease_takeovers"), 1);
    }

    #[test]
    fn superseded_lease_failure_does_not_release_the_thief() {
        let reg = ProfileRegistry::in_memory();
        let stuck = match reg.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!(),
        };
        let thief = match reg.acquire_stealing(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!(),
        };
        drop(stuck); // the original calibration fails late
        // the thief's lease must still hold: no third calibrator admitted
        assert!(matches!(reg.acquire(&key()), Acquired::InFlight));
        assert_eq!(reg.metrics().counter_value("leases_superseded"), 1);
        assert_eq!(reg.metrics().counter_value("leases_abandoned"), 0);
        thief.fulfill(profile(0.5), vec![0.5]);
        assert!(matches!(reg.acquire(&key()), Acquired::Ready(..)));
        assert_eq!(reg.metrics().counter_value("calibrations_completed"), 1);
    }

    #[test]
    fn observations_from_a_superseded_epoch_are_dropped() {
        let reg = ProfileRegistry::in_memory();
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.6]),
            _ => panic!(),
        }
        // recalibrate: epoch 1 -> 2
        assert!(reg.invalidate(&key()));
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.5), vec![0.5]),
            _ => panic!(),
        }
        // a decode that started under epoch 1 retires late: it must not
        // become the new profile's drift reference
        reg.observe(&key(), 1, &trace_with_signature(&[0.9, 0.1]));
        let entry = reg.get(&key()).unwrap();
        assert_eq!(entry.observed, 0);
        assert!(entry.drift_ref.iter().all(Vec::is_empty));
        assert_eq!(reg.metrics().counter_value("observations_superseded"), 1);
        // a current-epoch observation is adopted normally
        reg.observe(&key(), 2, &trace_with_signature(&[0.4, 0.6]));
        assert_eq!(reg.get(&key()).unwrap().observed, 1);
    }

    #[test]
    fn concurrent_acquires_grant_exactly_one_lease() {
        let reg = Arc::new(ProfileRegistry::in_memory());
        let mut handles = vec![];
        for _ in 0..8 {
            let reg = reg.clone();
            handles.push(std::thread::spawn(move || {
                match reg.acquire(&key()) {
                    Acquired::Lease(l) => {
                        l.fulfill(profile(0.7), vec![0.7]);
                        1u64
                    }
                    Acquired::InFlight => {
                        assert!(
                            reg.wait_ready(&key(), Duration::from_secs(5)).is_some(),
                            "in-flight calibration never landed"
                        );
                        0
                    }
                    Acquired::Ready(..) => 0,
                }
            }));
        }
        let calibrations: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(calibrations, 1, "single-flight violated");
        assert_eq!(reg.metrics().counter_value("calibrations_completed"), 1);
    }

    #[test]
    fn release_generation_bumps_only_when_a_lease_resolves() {
        let reg = ProfileRegistry::in_memory();
        let g0 = reg.lease_release_generation();
        let lease = match reg.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!(),
        };
        // granting a lease changes nothing for parked peers
        assert_eq!(reg.lease_release_generation(), g0);
        lease.fulfill(profile(0.6), vec![0.6]);
        let g1 = reg.lease_release_generation();
        assert_eq!(g1, g0 + 1, "fulfill must bump the generation");
        // plain Ready acquires don't bump
        assert!(matches!(reg.acquire(&key()), Acquired::Ready(..)));
        assert_eq!(reg.lease_release_generation(), g1);
        // an abandoned lease (recalibration that failed) bumps too
        assert!(reg.invalidate(&key()));
        match reg.acquire(&key()) {
            Acquired::Lease(l) => drop(l),
            _ => panic!(),
        }
        assert_eq!(reg.lease_release_generation(), g1 + 1);
    }

    #[test]
    fn drift_marks_stale_and_schedules_recalibration() {
        let reg = ProfileRegistry::with_config(RegistryConfig {
            drift_floor: 0.95,
            ema_alpha: 0.0,
            ..RegistryConfig::default()
        });
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.5, 0.5, 0.5, 0.5]),
            _ => panic!(),
        }
        // first decode is adopted as the drift reference
        reg.observe(&key(), 1, &trace_with_signature(&[0.5, 0.5, 0.5, 0.5]));
        assert!(!reg.get(&key()).unwrap().stale);
        // aligned decode: cosine 1 -> no drift
        reg.observe(&key(), 1, &trace_with_signature(&[0.5, 0.5, 0.5, 0.5]));
        assert!(!reg.get(&key()).unwrap().stale);
        // divergent shape: cosine 0.5 < floor -> stale
        reg.observe(&key(), 1, &trace_with_signature(&[0.9, 0.0, 0.0, 0.0]));
        assert!(reg.get(&key()).unwrap().stale);
        assert_eq!(reg.metrics().counter_value("drift_events"), 1);
        // next acquire recalibrates while peers keep the stale profile
        let lease = match reg.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!("stale profile must grant a recalibration lease"),
        };
        assert!(matches!(reg.acquire(&key()), Acquired::Ready(..)));
        lease.fulfill(profile(0.4), vec![0.9, 0.0, 0.0, 0.0]);
        let entry = reg.get(&key()).unwrap();
        assert!(!entry.stale);
        assert_eq!(entry.version, 2);
        assert_eq!(reg.metrics().counter_value("recalibrations"), 1);
    }

    #[test]
    fn misprediction_storm_marks_stale_and_recalibration_resets() {
        let reg = ProfileRegistry::with_config(RegistryConfig {
            misprediction_floor: 3,
            ..RegistryConfig::default()
        });
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.6]),
            _ => panic!(),
        }
        // below the floor: accumulate, stay fresh
        reg.note_elision_mispredictions(&key(), 1, 2);
        let entry = reg.get(&key()).unwrap();
        assert_eq!(entry.mispredicted, 2);
        assert!(!entry.stale);
        // crossing the floor is a drift event like a cosine breach
        reg.note_elision_mispredictions(&key(), 1, 1);
        assert!(reg.get(&key()).unwrap().stale);
        assert_eq!(reg.metrics().counter_value("drift_events"), 1);
        // the scheduled recalibration installs a fresh entry: accumulator
        // reset, staleness cleared
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.5), vec![0.5]),
            _ => panic!("stale profile must grant a recalibration lease"),
        }
        let entry = reg.get(&key()).unwrap();
        assert_eq!(entry.mispredicted, 0);
        assert!(!entry.stale);
        assert_eq!(reg.metrics().counter_value("recalibrations"), 1);
    }

    #[test]
    fn mispredictions_from_a_superseded_epoch_are_dropped() {
        let reg = ProfileRegistry::in_memory();
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.6]),
            _ => panic!(),
        }
        assert!(reg.invalidate(&key()));
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.5), vec![0.5]),
            _ => panic!(),
        }
        // a decode that acquired under epoch 1 retires after the epoch-2
        // recalibration: its mispredictions target the dead profile
        reg.note_elision_mispredictions(&key(), 1, 100);
        let entry = reg.get(&key()).unwrap();
        assert_eq!(entry.mispredicted, 0);
        assert!(!entry.stale);
        assert_eq!(reg.metrics().counter_value("observations_superseded"), 1);
        // zero-count notes are a no-op, not an observation
        reg.note_elision_mispredictions(&key(), 2, 0);
        assert_eq!(reg.get(&key()).unwrap().mispredicted, 0);
    }

    #[test]
    fn ema_refinement_moves_thresholds() {
        let reg = ProfileRegistry::with_config(RegistryConfig {
            drift_floor: 0.0, // never mark stale in this test
            ema_alpha: 0.5,
            ..RegistryConfig::default()
        });
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.2), vec![0.2]),
            _ => panic!(),
        }
        let mut t = CalibrationTrace::new(2);
        t.record(0, 0, &[0.8; 4]);
        t.record(1, 0, &[0.8; 4]);
        // first observe only adopts the drift reference; the second refines
        reg.observe(&key(), 1, &t);
        assert!((reg.get(&key()).unwrap().profile.tau(0, 0) - 0.2).abs() < 1e-9);
        reg.observe(&key(), 1, &t);
        let entry = reg.get(&key()).unwrap();
        assert!((entry.profile.tau(0, 0) - 0.5).abs() < 1e-9, "{entry:?}");
        assert_eq!(entry.version, 2);
        assert_eq!(reg.metrics().counter_value("profile_ema_updates"), 1);
    }

    #[test]
    fn empty_signature_adopts_first_observation() {
        let reg = ProfileRegistry::in_memory();
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![]), // schema-1 style
            _ => panic!(),
        }
        reg.observe(&key(), 1, &trace_with_signature(&[0.4, 0.6]));
        let entry = reg.get(&key()).unwrap();
        assert_eq!(entry.signature, vec![0.4, 0.6]);
        assert!(!entry.stale);
    }

    #[test]
    fn invalidate_forces_recalibration() {
        let reg = ProfileRegistry::in_memory();
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.6]),
            _ => panic!(),
        }
        assert!(reg.invalidate(&key()));
        assert!(!reg.invalidate(&ProfileKey::new(
            "missing",
            DynamicMode::Block,
            Metric::Q1
        )));
        assert!(matches!(reg.acquire(&key()), Acquired::Lease(_)));
    }

    #[test]
    fn warm_start_round_trips_through_the_store() {
        let dir = std::env::temp_dir().join(format!(
            "osdt_registry_warm_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        {
            let reg = ProfileRegistry::with_store(
                ProfileStore::new(&dir).unwrap(),
                RegistryConfig::default(),
            )
            .unwrap();
            match reg.acquire(&key()) {
                Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.4, 0.9]),
                _ => panic!(),
            }
        }
        let reg = ProfileRegistry::with_store(
            ProfileStore::new(&dir).unwrap(),
            RegistryConfig::default(),
        )
        .unwrap();
        assert_eq!(reg.len(), 1);
        match reg.acquire(&key()) {
            Acquired::Ready(p, epoch) => {
                assert!((p.tau(0, 0) - 0.6).abs() < 1e-12);
                assert_eq!(epoch, 1);
            }
            _ => panic!("warm-started key must not calibrate"),
        }
        let entry = reg.get(&key()).unwrap();
        assert!(entry.warm_started);
        assert_eq!(entry.signature, vec![0.4, 0.9]);
        assert_eq!(reg.metrics().counter_value("profile_warm_starts"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn cross_cfg() -> RegistryConfig {
        RegistryConfig {
            cross_process: true,
            // sync on every call so tests need no sleeps
            sync_interval: Duration::ZERO,
            ..RegistryConfig::default()
        }
    }

    fn cross_pair(tag: &str) -> (ProfileRegistry, ProfileRegistry, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "osdt_registry_cross_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let a = ProfileRegistry::with_store(
            ProfileStore::new(&dir).unwrap(),
            cross_cfg(),
        )
        .unwrap();
        let b = ProfileRegistry::with_store(
            ProfileStore::new(&dir).unwrap(),
            cross_cfg(),
        )
        .unwrap();
        (a, b, dir)
    }

    #[test]
    fn cross_process_lease_is_single_flight_across_instances() {
        let (a, b, dir) = cross_pair("sf");
        // instance A (replica 1) takes the fleet-wide lease
        let lease = match a.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!("first fleet-wide acquire must lease"),
        };
        // instance B (replica 2, sharing the store dir) is fenced by the
        // lease *file*, not by A's in-memory state
        assert!(matches!(b.acquire(&key()), Acquired::InFlight));
        assert_eq!(b.metrics().counter_value("cross_lease_conflicts"), 1);
        assert_eq!(b.metrics().counter_value("leases_granted"), 0);
        // A fulfills: persists the record and bumps the store generation
        lease.fulfill(profile(0.6), vec![0.6]);
        // B's next acquire observes the generation, adopts the on-disk
        // profile, and serves it without ever calibrating
        match b.acquire(&key()) {
            Acquired::Ready(p, _) => assert!((p.tau(0, 0) - 0.6).abs() < 1e-12),
            _ => panic!("peer fulfill must be adopted, not recalibrated"),
        }
        assert_eq!(b.metrics().counter_value("profile_cross_adoptions"), 1);
        assert_eq!(b.metrics().counter_value("calibrations_completed"), 0);
        assert_eq!(a.metrics().counter_value("calibrations_completed"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wait_ready_observes_a_peer_process_fulfill() {
        let (a, b, dir) = cross_pair("wait");
        let lease = match a.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!(),
        };
        assert!(matches!(b.acquire(&key()), Acquired::InFlight));
        // B parks; A fulfills from another thread. B has no local condvar
        // signal for this — only the chunked cross-process sync sees it.
        let waiter = std::thread::spawn(move || {
            b.wait_ready(&key(), Duration::from_secs(5)).map(|p| p.tau(0, 0))
        });
        std::thread::sleep(Duration::from_millis(30));
        lease.fulfill(profile(0.7), vec![0.7]);
        let tau = waiter.join().unwrap().expect("peer fulfill never observed");
        assert!((tau - 0.7).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn drift_on_one_replica_recalibrates_exactly_once_fleet_wide() {
        let (a, b, dir) = cross_pair("drift");
        // replica A calibrates; replica B adopts
        match a.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.6), vec![0.6]),
            _ => panic!(),
        }
        assert!(matches!(b.acquire(&key()), Acquired::Ready(..)));
        // drift detected on B only
        assert!(b.invalidate(&key()));
        let lease = match b.acquire(&key()) {
            Acquired::Lease(l) => l,
            _ => panic!("stale profile must grant the recalibration lease"),
        };
        // while B recalibrates, A keeps serving its (fresh-to-A) profile
        assert!(matches!(a.acquire(&key()), Acquired::Ready(..)));
        lease.fulfill(profile(0.4), vec![0.4]);
        // A adopts version 2 from disk instead of recalibrating
        match a.acquire(&key()) {
            Acquired::Ready(p, _) => assert!((p.tau(0, 0) - 0.4).abs() < 1e-12),
            _ => panic!("peer recalibration must be adopted"),
        }
        assert_eq!(a.get(&key()).unwrap().version, 2);
        assert_eq!(a.metrics().counter_value("profile_cross_adoptions"), 1);
        // exactly one calibration + one recalibration happened fleet-wide
        assert_eq!(a.metrics().counter_value("calibrations_completed"), 1);
        assert_eq!(b.metrics().counter_value("calibrations_completed"), 1);
        assert_eq!(b.metrics().counter_value("recalibrations"), 1);
        assert_eq!(a.metrics().counter_value("recalibrations"), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_peer_lease_file_is_taken_over() {
        let dir = std::env::temp_dir().join(format!(
            "osdt_registry_cross_deadpeer_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let store = ProfileStore::new(&dir).unwrap();
        // a SIGKILLed replica left its lease file behind (dead pid)
        std::fs::write(
            dir.join(".lease.synth-math.block.q1"),
            format!("{} 0\n", u32::MAX),
        )
        .unwrap();
        let reg =
            ProfileRegistry::with_store(ProfileStore::new(&dir).unwrap(), cross_cfg())
                .unwrap();
        drop(store);
        match reg.acquire(&key()) {
            Acquired::Lease(l) => l.fulfill(profile(0.5), vec![0.5]),
            _ => panic!("dead holder's lease must be broken, not waited on"),
        }
        assert_eq!(reg.metrics().counter_value("cross_lease_takeovers"), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn abandoned_cross_lease_releases_the_fleet() {
        let (a, b, dir) = cross_pair("abandon");
        {
            let _lease = match a.acquire(&key()) {
                Acquired::Lease(l) => l,
                _ => panic!(),
            };
            assert!(matches!(b.acquire(&key()), Acquired::InFlight));
            // A's calibration fails; the lease (and its file) drop
        }
        // B can now take the fleet-wide lease itself
        assert!(matches!(b.acquire(&key()), Acquired::Lease(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_lists_sorted_entries() {
        let reg = ProfileRegistry::in_memory();
        for task in ["zeta", "alpha"] {
            let k = ProfileKey::new(task, DynamicMode::Block, Metric::Q1);
            match reg.acquire(&k) {
                Acquired::Lease(l) => l.fulfill(profile(0.5), vec![0.5]),
                _ => panic!(),
            }
        }
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].key.task, "alpha");
        assert_eq!(snap[1].key.task, "zeta");
        assert_eq!(snap[0].version, 1);
    }

    #[test]
    fn signature_cosine_clamp_extends_shorter_blocks() {
        // identical shapes -> 1
        let a = vec![vec![0.4, 0.9], vec![0.5]];
        assert!((signature_cosine(&a, &a).unwrap() - 1.0).abs() < 1e-12);
        // a block finishing in fewer steps clamps, not zero-pads: the
        // shorter [0.4] extends to [0.4, 0.4] against [0.4, 0.4, ...]
        let b = vec![vec![0.4]];
        let c = vec![vec![0.4, 0.4, 0.4]];
        assert!((signature_cosine(&b, &c).unwrap() - 1.0).abs() < 1e-12);
        // empty inputs are not comparable
        assert!(signature_cosine(&[], &a).is_none());
        assert!(signature_cosine(&[vec![]], &a).is_none());
        // divergent shapes drop the cosine
        let d = vec![vec![0.9, 0.0], vec![0.0]];
        assert!(signature_cosine(&a, &d).unwrap() < 0.9);
    }
}
