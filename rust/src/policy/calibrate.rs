//! Phase 1 of OSDT (Algorithm 1, lines 3–6): decode one sequence with the
//! standard static policy while recording per-(block, step) confidence
//! vectors, then reduce them with metric μ into a threshold profile.
//!
//! The trace is also the raw material for Figures 1 & 2 (step-block mean
//! confidence trajectories and their pairwise cosine similarity).

use super::{DynamicMode, Metric, Profile};

/// Raw confidences observed during one decoded sequence:
/// `per_block[b][s]` = confidences of the masked positions of block `b`
/// at its denoising step `s` (before committing).
#[derive(Clone, Debug, Default)]
pub struct CalibrationTrace {
    pub per_block: Vec<Vec<Vec<f64>>>,
}

impl CalibrationTrace {
    pub fn new(num_blocks: usize) -> Self {
        CalibrationTrace {
            per_block: vec![Vec::new(); num_blocks],
        }
    }

    /// Record the masked-position confidences at (block, step). Steps must
    /// arrive in order for each block.
    pub fn record(&mut self, block: usize, step: usize, conf: &[f32]) {
        let steps = &mut self.per_block[block];
        assert_eq!(step, steps.len(), "steps must be recorded in order");
        steps.push(conf.iter().map(|&c| f64::from(c)).collect());
    }

    /// Step-block mean-confidence vector, flattened in (block, step) order —
    /// the paper's "confidence signature" used for Figures 1–2.
    pub fn signature(&self) -> Vec<f64> {
        self.block_signatures().into_iter().flatten().collect()
    }

    /// Per-block step-mean confidences with the block structure preserved —
    /// the registry's drift-detection input, where per-block alignment
    /// matters because policies take different step counts per block.
    pub fn block_signatures(&self) -> Vec<Vec<f64>> {
        self.per_block
            .iter()
            .map(|steps| {
                steps
                    .iter()
                    .map(|v| {
                        if v.is_empty() {
                            0.0
                        } else {
                            v.iter().sum::<f64>() / v.len() as f64
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Total number of denoising steps across blocks.
    pub fn total_steps(&self) -> usize {
        self.per_block.iter().map(Vec::len).sum()
    }

    /// Number of steps recorded so far for `block` — the next executed-step
    /// index. Decode paths that jump the schedule (step elision) record at
    /// this index so `record`'s in-order invariant holds for executed steps.
    pub fn steps_recorded(&self, block: usize) -> usize {
        self.per_block.get(block).map(Vec::len).unwrap_or(0)
    }

    /// Per-(block, step) acceptance counts implied by the trace: the masked
    /// count shrinks between consecutive steps by exactly the number of
    /// positions committed, and the final step commits everything still
    /// masked. This is the profile's elision trajectory
    /// (`Profile::predict_empty_run`).
    pub fn accepts(&self) -> Vec<Vec<f64>> {
        self.per_block
            .iter()
            .map(|steps| {
                (0..steps.len())
                    .map(|s| match steps.get(s + 1) {
                        Some(next) => {
                            steps[s].len().saturating_sub(next.len()) as f64
                        }
                        None => steps[s].len() as f64,
                    })
                    .collect()
            })
            .collect()
    }

    /// JSON persistence — traces are the raw experimental record behind
    /// Figures 1–2 and calibration; `osdt traces --save` archives them.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![(
            "per_block",
            Json::Arr(
                self.per_block
                    .iter()
                    .map(|steps| {
                        Json::Arr(steps.iter().map(|v| Json::from_f64s(v)).collect())
                    })
                    .collect(),
            ),
        )])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Result<Self, String> {
        let blocks = j
            .req("per_block")?
            .as_arr()
            .ok_or("per_block not an array")?;
        let mut per_block = Vec::with_capacity(blocks.len());
        for b in blocks {
            let steps = b.as_arr().ok_or("block not an array")?;
            let mut out_steps = Vec::with_capacity(steps.len());
            for s in steps {
                let row = s.as_arr().ok_or("step not an array")?;
                let vals: Option<Vec<f64>> =
                    row.iter().map(crate::util::json::Json::as_f64).collect();
                out_steps.push(vals.ok_or("confidences must be numbers")?);
            }
            per_block.push(out_steps);
        }
        Ok(CalibrationTrace { per_block })
    }
}

/// CALIBRATE(conf, M, μ) — reduce a trace to a threshold profile.
pub struct Calibrator;

impl Calibrator {
    pub fn calibrate(
        trace: &CalibrationTrace,
        mode: DynamicMode,
        metric: Metric,
    ) -> Profile {
        match mode {
            DynamicMode::Block => {
                // unit = block: pool confidences across all steps of a block
                let taus = trace
                    .per_block
                    .iter()
                    .map(|steps| {
                        let pooled: Vec<f64> =
                            steps.iter().flatten().copied().collect();
                        // an empty block (shouldn't happen in practice)
                        // gets a permissive threshold of 0
                        metric.reduce(&pooled).unwrap_or(0.0)
                    })
                    .collect();
                Profile::block(taus, metric).with_accepts(trace.accepts())
            }
            DynamicMode::StepBlock => {
                // unit = (block, step): one τ per calibration step
                let taus = trace
                    .per_block
                    .iter()
                    .map(|steps| {
                        steps
                            .iter()
                            .map(|v| metric.reduce(v).unwrap_or(0.0))
                            .collect::<Vec<f64>>()
                    })
                    .collect();
                Profile::step_block(taus, metric).with_accepts(trace.accepts())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_trace() -> CalibrationTrace {
        let mut t = CalibrationTrace::new(2);
        t.record(0, 0, &[0.2, 0.4, 0.6]); // mean 0.4
        t.record(0, 1, &[0.8, 1.0]);      // mean 0.9
        t.record(1, 0, &[0.5, 0.5]);      // mean 0.5
        t
    }

    #[test]
    fn signature_is_step_means() {
        let sig = demo_trace().signature();
        let want = [0.4, 0.9, 0.5];
        assert_eq!(sig.len(), 3);
        for (a, b) in sig.iter().zip(want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn block_mode_pools_steps() {
        let p = Calibrator::calibrate(&demo_trace(), DynamicMode::Block, Metric::Mean);
        // block 0 pooled: (0.2+0.4+0.6+0.8+1.0)/5 = 0.6
        assert!((p.tau(0, 0) - 0.6).abs() < 1e-6);
        assert!((p.tau(0, 99) - 0.6).abs() < 1e-6); // step-independent
        assert!((p.tau(1, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn step_block_mode_per_step() {
        let p =
            Calibrator::calibrate(&demo_trace(), DynamicMode::StepBlock, Metric::Mean);
        assert!((p.tau(0, 0) - 0.4).abs() < 1e-6);
        assert!((p.tau(0, 1) - 0.9).abs() < 1e-6);
        // beyond calibrated depth clamps to last calibrated step
        assert!((p.tau(0, 7) - 0.9).abs() < 1e-6);
        assert!((p.tau(1, 0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn metrics_differ_on_skewed_data() {
        let mut t = CalibrationTrace::new(1);
        t.record(0, 0, &[0.1, 0.9, 0.92, 0.94, 0.96]);
        let mean = Calibrator::calibrate(&t, DynamicMode::Block, Metric::Mean);
        let q1 = Calibrator::calibrate(&t, DynamicMode::Block, Metric::Q1);
        let q3 = Calibrator::calibrate(&t, DynamicMode::Block, Metric::Q3);
        assert!(q1.tau(0, 0) < mean.tau(0, 0) || q1.tau(0, 0) < q3.tau(0, 0));
        assert!(q1.tau(0, 0) <= q3.tau(0, 0));
    }

    #[test]
    #[should_panic(expected = "steps must be recorded in order")]
    fn out_of_order_step_panics() {
        let mut t = CalibrationTrace::new(1);
        t.record(0, 1, &[0.5]);
    }

    #[test]
    fn total_steps() {
        assert_eq!(demo_trace().total_steps(), 3);
    }

    #[test]
    fn accepts_from_masked_count_shrinkage() {
        // block 0: 3 masked at step 0, 2 at step 1 -> committed 1, then 2
        // block 1: single step commits both masked positions
        let acc = demo_trace().accepts();
        assert_eq!(acc, vec![vec![1.0, 2.0], vec![2.0]]);
        // the calibrated profile carries the trajectory
        let p = Calibrator::calibrate(
            &demo_trace(),
            DynamicMode::StepBlock,
            Metric::Mean,
        );
        assert_eq!(p.trajectory_steps(0), 2);
        assert_eq!(p.predict_empty_run(0, 0, 1.5), 1);
        assert_eq!(p.predict_empty_run(0, 1, 1.5), 0);
    }

    #[test]
    fn steps_recorded_tracks_executed_steps() {
        let t = demo_trace();
        assert_eq!(t.steps_recorded(0), 2);
        assert_eq!(t.steps_recorded(1), 1);
        assert_eq!(t.steps_recorded(9), 0);
    }

    #[test]
    fn json_roundtrip() {
        let t = demo_trace();
        let back = CalibrationTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.per_block.len(), t.per_block.len());
        for (a, b) in back.per_block.iter().flatten().zip(t.per_block.iter().flatten()) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        use crate::util::json::Json;
        for bad in [
            r#"{}"#,
            r#"{"per_block": 3}"#,
            r#"{"per_block": [[["x"]]]}"#,
        ] {
            assert!(
                CalibrationTrace::from_json(&Json::parse(bad).unwrap()).is_err(),
                "{bad}"
            );
        }
    }
}
