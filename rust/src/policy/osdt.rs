//! Phase 2 of One-Shot Dynamic Thresholding (Algorithm 1, lines 8–22).
//!
//! Given a calibrated [`Profile`], each step applies
//!
//! ```text
//! τ      = T[b]            (block mode)      or  T[b][s]  (step-block)
//! τ_eff  = min(τ, κ) · (1 − ε)
//! S      = { j masked : conf[j] > τ_eff }
//! if S = ∅ : S = { argmax conf }             (liveness fallback)
//! ```
//!
//! κ (cap) bounds overly strict calibrated thresholds from above; ε (slack)
//! uniformly relaxes them to buy parallelism. Both are the paper's §4.1
//! hyperparameters.

use super::{
    f32_below, PlanContext, Policy, Profile, StepContext, StepPlan, StepRule,
};

/// Default elision floor: calibration acceptance counts are integers ≥ 1
/// (liveness commits at least the argmax every step), so a floor of 1.5
/// classifies exactly the fallback-only steps as empty — the most
/// conservative setting that elides anything at all.
pub const DEFAULT_ELIDE_FLOOR: f64 = 1.5;

#[derive(Clone, Debug)]
pub struct Osdt {
    profile: Profile,
    kappa: f64,
    epsilon: f64,
    /// `Some(floor)` enables profile-guided step elision (DESIGN.md §14):
    /// steps whose calibrated acceptance trajectory predicts fewer than
    /// `floor` commits are skipped over by `plan`'s `skip_ahead`, or — when
    /// the rest of the block's trajectory is all-empty — replaced by the
    /// argmax-liveness floor. `None` (the default) reproduces the plain
    /// OSDT schedule exactly.
    elide_floor: Option<f64>,
}

impl Osdt {
    pub fn from_profile(profile: Profile, kappa: f64, epsilon: f64) -> Self {
        assert!((0.0..=1.0).contains(&kappa), "kappa in [0,1]");
        assert!((0.0..1.0).contains(&epsilon), "epsilon in [0,1)");
        Osdt {
            profile,
            kappa,
            epsilon,
            elide_floor: None,
        }
    }

    /// Enable profile-guided step elision with the given acceptance floor.
    pub fn with_elision(mut self, floor: f64) -> Self {
        self.elide_floor = Some(floor);
        self
    }

    /// Whether (block, step) sits in an all-empty trajectory tail under the
    /// active elision floor — the argmax-liveness floor mode. Both `plan`
    /// and `select_raw` consult this so the fused and host paths agree
    /// (the §11 plan contract).
    fn floor_active(&self, block: usize, step: usize) -> bool {
        let Some(floor) = self.elide_floor else {
            return false;
        };
        let k = self.profile.predict_empty_run(block, step, floor);
        k > 0 && step + k >= self.profile.trajectory_steps(block)
    }

    /// The effective threshold used at (block, step) — exposed for tests
    /// and the sweep benches.
    pub fn tau_eff(&self, block: usize, step: usize) -> f64 {
        self.profile.tau(block, step).min(self.kappa) * (1.0 - self.epsilon)
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }
}

impl Policy for Osdt {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        // Floor mode mirrors `plan`'s τ=1.0 advertisement: nothing passes
        // the raw rule, so `select` commits exactly the argmax per pass.
        if self.floor_active(ctx.block, ctx.step) {
            return vec![];
        }
        let cut = self.tau_eff(ctx.block, ctx.step);
        (0..ctx.conf.len())
            .filter(|&i| f64::from(ctx.conf[i]) > cut)
            .collect()
    }

    /// The paper's core primitive: τ_eff is known per (block, step) before
    /// the pass runs, so OSDT steps fuse onto the device. `f32_below`
    /// quantises the f64 cutoff so the device's f32 strict compare selects
    /// exactly the same positions as `select_raw`'s f64 compare.
    ///
    /// With elision enabled, a step whose trajectory predicts an empty run
    /// of length `k` advertises `skip_ahead = k` together with the rule
    /// calibrated for the first productive step `s + k` — the scheduler
    /// advances the task's schedule before the pass, so the plan contract
    /// holds at the jumped-to step, where `predict_empty_run` is 0. An
    /// all-empty remaining trajectory instead drops to the argmax-liveness
    /// floor: τ=1.0 passes nothing, the fallback walks the remaining
    /// positions one per pass, and no steps are skipped (every fallback
    /// commit needs its own forward pass anyway).
    fn plan(&self, ctx: &PlanContext) -> StepPlan {
        if let Some(floor) = self.elide_floor {
            let k = self.profile.predict_empty_run(ctx.block, ctx.step, floor);
            if k > 0 {
                if ctx.step + k >= self.profile.trajectory_steps(ctx.block) {
                    return StepPlan {
                        rule: StepRule::Threshold { tau: 1.0 },
                        skip_ahead: 0,
                    };
                }
                return StepPlan {
                    rule: StepRule::Threshold {
                        tau: f32_below(self.tau_eff(ctx.block, ctx.step + k)),
                    },
                    skip_ahead: k,
                };
            }
        }
        StepPlan::threshold(f32_below(self.tau_eff(ctx.block, ctx.step)))
    }

    fn name(&self) -> String {
        format!(
            "osdt-{}-{}-k{}-e{}",
            self.profile.mode.as_str(),
            self.profile.metric.as_str(),
            self.kappa,
            self.epsilon
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Metric;
    use crate::util::{prop, rng::Rng};

    fn block_profile() -> Profile {
        Profile::block(vec![0.9, 0.5, 0.95], Metric::Mean)
    }

    #[test]
    fn tau_eff_applies_cap_and_slack() {
        let p = Osdt::from_profile(block_profile(), 0.8, 0.1);
        // block 0: min(0.9, 0.8)*(0.9) = 0.72
        assert!((p.tau_eff(0, 0) - 0.72).abs() < 1e-12);
        // block 1: min(0.5, 0.8)*0.9 = 0.45
        assert!((p.tau_eff(1, 0) - 0.45).abs() < 1e-12);
    }

    #[test]
    fn step_block_lookup_by_step() {
        let prof = Profile::step_block(
            vec![vec![0.2, 0.9], vec![0.6]],
            Metric::Median,
        );
        let p = Osdt::from_profile(prof, 1.0, 0.0);
        let low = StepContext { block: 0, step: 0, conf: &[0.3, 0.5] };
        let hi = StepContext { block: 0, step: 1, conf: &[0.3, 0.5] };
        // step 0: τ=0.2 -> both above
        assert_eq!(p.select(&low), vec![0, 1]);
        // step 1: τ=0.9 -> none above -> fallback argmax
        assert_eq!(p.select(&hi), vec![1]);
    }

    #[test]
    fn slack_strictly_increases_selection() {
        let prof = Profile::block(vec![0.8], Metric::Mean);
        let strict = Osdt::from_profile(prof.clone(), 1.0, 0.0);
        let relaxed = Osdt::from_profile(prof, 1.0, 0.2);
        let conf = [0.7f32, 0.78, 0.85, 0.3];
        let ctx = StepContext { block: 0, step: 0, conf: &conf };
        let s1 = strict.select(&ctx);
        let s2 = relaxed.select(&ctx);
        assert!(s2.len() >= s1.len());
        for i in &s1 {
            assert!(s2.contains(i), "relaxed must be a superset");
        }
    }

    fn elidable_profile() -> Profile {
        // step 0 productive, steps 1-3 fallback-only, step 4 productive
        Profile::step_block(
            vec![vec![0.5, 0.995, 0.995, 0.995, 0.25]],
            Metric::Q1,
        )
        .with_accepts(vec![vec![4.0, 1.0, 1.0, 1.0, 3.0]])
    }

    #[test]
    fn plan_skips_predicted_empty_run() {
        use crate::policy::{f32_below, PlanContext, StepPlan, StepRule};
        let p = Osdt::from_profile(elidable_profile(), 1.0, 0.0).with_elision(1.5);
        // productive step: plain rule, no skip
        assert_eq!(
            p.plan(&PlanContext { block: 0, step: 0 }),
            StepPlan::threshold(f32_below(0.5))
        );
        // empty run of 3: jump to step 4's rule
        assert_eq!(
            p.plan(&PlanContext { block: 0, step: 1 }),
            StepPlan {
                rule: StepRule::Threshold { tau: f32_below(0.25) },
                skip_ahead: 3,
            }
        );
        // mid-run suffix skips the remainder
        assert_eq!(p.plan(&PlanContext { block: 0, step: 3 }).skip_ahead, 1);
        // the jumped-to step itself is productive again
        assert_eq!(
            p.plan(&PlanContext { block: 0, step: 4 }),
            StepPlan::threshold(f32_below(0.25))
        );
    }

    #[test]
    fn plan_without_elision_never_skips() {
        use crate::policy::PlanContext;
        let p = Osdt::from_profile(elidable_profile(), 1.0, 0.0);
        for s in 0..6 {
            assert_eq!(p.plan(&PlanContext { block: 0, step: s }).skip_ahead, 0);
        }
    }

    #[test]
    fn all_empty_tail_drops_to_argmax_floor() {
        use crate::policy::{PlanContext, StepPlan, StepRule};
        let prof = Profile::step_block(
            vec![vec![0.5, 0.995, 0.995]],
            Metric::Q1,
        )
        .with_accepts(vec![vec![3.0, 1.0, 1.0]]);
        let p = Osdt::from_profile(prof, 1.0, 0.0).with_elision(1.5);
        // steps 1.. are all-empty to the trajectory's end: floor mode,
        // no skip (each fallback commit needs its own pass)
        assert_eq!(
            p.plan(&PlanContext { block: 0, step: 1 }),
            StepPlan {
                rule: StepRule::Threshold { tau: 1.0 },
                skip_ahead: 0,
            }
        );
        // host path mirrors the advertised rule: raw selection empty,
        // select commits exactly the argmax (plan contract, §11)
        let ctx = StepContext { block: 0, step: 1, conf: &[0.3, 0.7, 0.4] };
        assert!(p.select_raw(&ctx).is_empty());
        assert_eq!(p.select(&ctx), vec![1]);
        // without elision the same step selects by tau_eff as before
        let plain = Osdt::from_profile(
            Profile::step_block(vec![vec![0.5, 0.995, 0.995]], Metric::Q1)
                .with_accepts(vec![vec![3.0, 1.0, 1.0]]),
            1.0,
            0.0,
        );
        assert_eq!(plain.select(&ctx), vec![1]); // 0.995 cut -> fallback too
    }

    #[test]
    fn elision_noops_without_trajectory() {
        use crate::policy::PlanContext;
        // profile with no accepts: predict_empty_run is 0 everywhere, so
        // even with elision on the plan is the plain schedule
        let prof = Profile::step_block(vec![vec![0.9, 0.9]], Metric::Q1);
        let p = Osdt::from_profile(prof.clone(), 1.0, 0.0).with_elision(1.5);
        let plain = Osdt::from_profile(prof, 1.0, 0.0);
        for s in 0..4 {
            let ctx = PlanContext { block: 0, step: s };
            assert_eq!(p.plan(&ctx), plain.plan(&ctx));
        }
    }

    #[test]
    fn prop_monotone_in_kappa_and_epsilon() {
        // lower kappa / higher epsilon -> lower tau_eff -> superset selection
        prop::forall(
            "osdt-monotonicity",
            200,
            |r: &mut Rng| {
                let taus = prop::gen_f64_vec(r, 1, 4, 0.0, 1.0);
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 40, 0.0, 1.0)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                let k1 = r.next_f64();
                let k2 = k1 * r.next_f64(); // k2 <= k1
                let e1 = r.next_f64() * 0.9;
                let e2 = e1 + (0.99 - e1) * r.next_f64() * 0.99; // e2 >= e1
                (taus, conf, k1, k2, e1, e2)
            },
            |(taus, conf, k1, k2, e1, e2)| {
                let prof = Profile::block(taus.clone(), Metric::Mean);
                let a = Osdt::from_profile(prof.clone(), *k1, *e1);
                let b = Osdt::from_profile(prof.clone(), *k2, *e1);
                let c = Osdt::from_profile(prof.clone(), *k1, *e2);
                let block = (taus.len().max(1)) - 1;
                let ctx = StepContext { block, step: 0, conf };
                let sa = a.select(&ctx);
                for (name, other) in [("kappa", b.select(&ctx)), ("eps", c.select(&ctx))] {
                    for i in &sa {
                        if !other.contains(i) {
                            return Err(format!(
                                "relaxing {name} dropped index {i}: {sa:?} -> {other:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_liveness() {
        prop::forall(
            "osdt-liveness",
            200,
            |r: &mut Rng| {
                let taus = prop::gen_f64_vec(r, 1, 3, 0.5, 1.0);
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 30, 0.0, 0.4)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                (taus, conf)
            },
            |(taus, conf)| {
                // conf all below taus -> must still commit exactly the argmax
                let p = Osdt::from_profile(
                    Profile::block(taus.clone(), Metric::Mean),
                    1.0,
                    0.0,
                );
                let sel = p.select(&StepContext { block: 0, step: 0, conf });
                if sel.is_empty() {
                    return Err("liveness violated".into());
                }
                Ok(())
            },
        );
    }
}
