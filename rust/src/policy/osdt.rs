//! Phase 2 of One-Shot Dynamic Thresholding (Algorithm 1, lines 8–22).
//!
//! Given a calibrated [`Profile`], each step applies
//!
//! ```text
//! τ      = T[b]            (block mode)      or  T[b][s]  (step-block)
//! τ_eff  = min(τ, κ) · (1 − ε)
//! S      = { j masked : conf[j] > τ_eff }
//! if S = ∅ : S = { argmax conf }             (liveness fallback)
//! ```
//!
//! κ (cap) bounds overly strict calibrated thresholds from above; ε (slack)
//! uniformly relaxes them to buy parallelism. Both are the paper's §4.1
//! hyperparameters.

use super::{f32_below, PlanContext, Policy, Profile, StepContext, StepPlan};

#[derive(Clone, Debug)]
pub struct Osdt {
    profile: Profile,
    kappa: f64,
    epsilon: f64,
}

impl Osdt {
    pub fn from_profile(profile: Profile, kappa: f64, epsilon: f64) -> Self {
        assert!((0.0..=1.0).contains(&kappa), "kappa in [0,1]");
        assert!((0.0..1.0).contains(&epsilon), "epsilon in [0,1)");
        Osdt {
            profile,
            kappa,
            epsilon,
        }
    }

    /// The effective threshold used at (block, step) — exposed for tests
    /// and the sweep benches.
    pub fn tau_eff(&self, block: usize, step: usize) -> f64 {
        self.profile.tau(block, step).min(self.kappa) * (1.0 - self.epsilon)
    }

    pub fn profile(&self) -> &Profile {
        &self.profile
    }
}

impl Policy for Osdt {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        let cut = self.tau_eff(ctx.block, ctx.step);
        (0..ctx.conf.len())
            .filter(|&i| f64::from(ctx.conf[i]) > cut)
            .collect()
    }

    /// The paper's core primitive: τ_eff is known per (block, step) before
    /// the pass runs, so OSDT steps fuse onto the device. `f32_below`
    /// quantises the f64 cutoff so the device's f32 strict compare selects
    /// exactly the same positions as `select_raw`'s f64 compare.
    fn plan(&self, ctx: &PlanContext) -> StepPlan {
        StepPlan::Threshold { tau: f32_below(self.tau_eff(ctx.block, ctx.step)) }
    }

    fn name(&self) -> String {
        format!(
            "osdt-{}-{}-k{}-e{}",
            self.profile.mode.as_str(),
            self.profile.metric.as_str(),
            self.kappa,
            self.epsilon
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Metric;
    use crate::util::{prop, rng::Rng};

    fn block_profile() -> Profile {
        Profile::block(vec![0.9, 0.5, 0.95], Metric::Mean)
    }

    #[test]
    fn tau_eff_applies_cap_and_slack() {
        let p = Osdt::from_profile(block_profile(), 0.8, 0.1);
        // block 0: min(0.9, 0.8)*(0.9) = 0.72
        assert!((p.tau_eff(0, 0) - 0.72).abs() < 1e-12);
        // block 1: min(0.5, 0.8)*0.9 = 0.45
        assert!((p.tau_eff(1, 0) - 0.45).abs() < 1e-12);
    }

    #[test]
    fn step_block_lookup_by_step() {
        let prof = Profile::step_block(
            vec![vec![0.2, 0.9], vec![0.6]],
            Metric::Median,
        );
        let p = Osdt::from_profile(prof, 1.0, 0.0);
        let low = StepContext { block: 0, step: 0, conf: &[0.3, 0.5] };
        let hi = StepContext { block: 0, step: 1, conf: &[0.3, 0.5] };
        // step 0: τ=0.2 -> both above
        assert_eq!(p.select(&low), vec![0, 1]);
        // step 1: τ=0.9 -> none above -> fallback argmax
        assert_eq!(p.select(&hi), vec![1]);
    }

    #[test]
    fn slack_strictly_increases_selection() {
        let prof = Profile::block(vec![0.8], Metric::Mean);
        let strict = Osdt::from_profile(prof.clone(), 1.0, 0.0);
        let relaxed = Osdt::from_profile(prof, 1.0, 0.2);
        let conf = [0.7f32, 0.78, 0.85, 0.3];
        let ctx = StepContext { block: 0, step: 0, conf: &conf };
        let s1 = strict.select(&ctx);
        let s2 = relaxed.select(&ctx);
        assert!(s2.len() >= s1.len());
        for i in &s1 {
            assert!(s2.contains(i), "relaxed must be a superset");
        }
    }

    #[test]
    fn prop_monotone_in_kappa_and_epsilon() {
        // lower kappa / higher epsilon -> lower tau_eff -> superset selection
        prop::forall(
            "osdt-monotonicity",
            200,
            |r: &mut Rng| {
                let taus = prop::gen_f64_vec(r, 1, 4, 0.0, 1.0);
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 40, 0.0, 1.0)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                let k1 = r.next_f64();
                let k2 = k1 * r.next_f64(); // k2 <= k1
                let e1 = r.next_f64() * 0.9;
                let e2 = e1 + (0.99 - e1) * r.next_f64() * 0.99; // e2 >= e1
                (taus, conf, k1, k2, e1, e2)
            },
            |(taus, conf, k1, k2, e1, e2)| {
                let prof = Profile::block(taus.clone(), Metric::Mean);
                let a = Osdt::from_profile(prof.clone(), *k1, *e1);
                let b = Osdt::from_profile(prof.clone(), *k2, *e1);
                let c = Osdt::from_profile(prof.clone(), *k1, *e2);
                let block = (taus.len().max(1)) - 1;
                let ctx = StepContext { block, step: 0, conf };
                let sa = a.select(&ctx);
                for (name, other) in [("kappa", b.select(&ctx)), ("eps", c.select(&ctx))] {
                    for i in &sa {
                        if !other.contains(i) {
                            return Err(format!(
                                "relaxing {name} dropped index {i}: {sa:?} -> {other:?}"
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_liveness() {
        prop::forall(
            "osdt-liveness",
            200,
            |r: &mut Rng| {
                let taus = prop::gen_f64_vec(r, 1, 3, 0.5, 1.0);
                let conf: Vec<f32> = prop::gen_f64_vec(r, 1, 30, 0.0, 0.4)
                    .into_iter()
                    .map(|x| x as f32)
                    .collect();
                (taus, conf)
            },
            |(taus, conf)| {
                // conf all below taus -> must still commit exactly the argmax
                let p = Osdt::from_profile(
                    Profile::block(taus.clone(), Metric::Mean),
                    1.0,
                    0.0,
                );
                let sel = p.select(&StepContext { block: 0, step: 0, conf });
                if sel.is_empty() {
                    return Err("liveness violated".into());
                }
                Ok(())
            },
        );
    }
}
