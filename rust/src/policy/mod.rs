//! Threshold policies for parallel diffusion decoding — the paper's core
//! subject. Four policies share one interface:
//!
//! - [`SequentialTopK`]  — LLaDA baseline: fixed per-step quota, top-k by
//!   confidence (k=1 reproduces strictly sequential unmasking).
//! - [`StaticThreshold`] — Fast-dLLM fixed: commit every masked position
//!   with confidence > τ (global, static).
//! - [`FactorThreshold`] — Fast-dLLM factor: commit positions with
//!   confidence ≥ f · max-confidence of the step (relative cutoff; see
//!   DESIGN.md for the interpretation).
//! - [`Osdt`]            — the paper's One-Shot Dynamic Thresholding:
//!   per-block or per-(block, step) thresholds derived from a single
//!   calibration run, with cap κ and slack ε (Algorithm 1).
//!
//! Every policy guarantees **liveness**: if its raw rule selects nothing,
//! the most confident masked position is committed (the paper's argmax
//! fallback, line 19–21 of Algorithm 1). This invariant is property-tested.

mod adaptive;
mod calibrate;
mod factor;
mod osdt;
mod profile;
mod registry;
mod static_thresh;
mod topk;

pub use adaptive::AdaptiveOsdt;
pub use calibrate::{CalibrationTrace, Calibrator};
pub use factor::FactorThreshold;
pub use osdt::{Osdt, DEFAULT_ELIDE_FLOOR};
pub use profile::{
    encode_task, Profile, ProfileRecord, ProfileStore, PROFILE_SCHEMA_VERSION,
};
pub use registry::{
    signature_cosine, Acquired, CalibrationLease, PeekState, ProfileEntry,
    ProfileKey, ProfileRegistry, ProfileSummary, RegistryConfig,
};
pub use static_thresh::StaticThreshold;
pub use topk::SequentialTopK;

use anyhow::{bail, Result};

/// OSDT dynamic mode M (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DynamicMode {
    Block,
    StepBlock,
}

impl DynamicMode {
    pub fn parse(s: &str) -> Result<DynamicMode> {
        Ok(match s {
            "block" => DynamicMode::Block,
            "step-block" | "stepblock" => DynamicMode::StepBlock,
            _ => bail!("unknown mode {s:?} (block|step-block)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            DynamicMode::Block => "block",
            DynamicMode::StepBlock => "step-block",
        }
    }
}

/// OSDT threshold metric μ (paper §4.1): statistic over calibration
/// confidences. q2 == median.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Metric {
    Mean,
    Q1,
    Median,
    Q3,
    MinWhisker,
}

impl Metric {
    pub fn parse(s: &str) -> Result<Metric> {
        Ok(match s {
            "mean" => Metric::Mean,
            "q1" => Metric::Q1,
            "q2" | "median" => Metric::Median,
            "q3" => Metric::Q3,
            "min-whisker" | "minwhisker" => Metric::MinWhisker,
            _ => bail!("unknown metric {s:?} (mean|q1|q2|q3|min-whisker)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Metric::Mean => "mean",
            Metric::Q1 => "q1",
            Metric::Median => "q2",
            Metric::Q3 => "q3",
            Metric::MinWhisker => "min-whisker",
        }
    }

    /// Reduce a set of calibration confidences to a threshold.
    pub fn reduce(&self, values: &[f64]) -> Option<f64> {
        let s = crate::util::stats::summarize(values)?;
        Some(match self {
            Metric::Mean => s.mean,
            Metric::Q1 => s.q1,
            Metric::Median => s.median,
            Metric::Q3 => s.q3,
            Metric::MinWhisker => {
                let mut sorted = values.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                s.min_whisker(&sorted)
            }
        })
    }
}

/// Declarative policy description (CLI / wire / bench sweeps).
#[derive(Clone, Debug, PartialEq)]
pub enum PolicySpec {
    Sequential { k: usize },
    Static { tau: f64 },
    Factor { factor: f64 },
    Osdt {
        mode: DynamicMode,
        metric: Metric,
        kappa: f64,
        epsilon: f64,
    },
}

impl PolicySpec {
    /// Canonical spec string (inverse of `config::parse_policy_spec`).
    pub fn to_spec_string(&self) -> String {
        match self {
            PolicySpec::Sequential { k } => format!("sequential:{k}"),
            PolicySpec::Static { tau } => format!("static:{tau}"),
            PolicySpec::Factor { factor } => format!("factor:{factor}"),
            PolicySpec::Osdt { mode, metric, kappa, epsilon } => format!(
                "osdt:{}:{}:{}:{}",
                mode.as_str(),
                metric.as_str(),
                kappa,
                epsilon
            ),
        }
    }

    /// Whether this spec needs a calibration profile to instantiate.
    pub fn needs_profile(&self) -> bool {
        matches!(self, PolicySpec::Osdt { .. })
    }

    /// Instantiate a profile-free policy. OSDT must go through
    /// [`Osdt::from_profile`].
    pub fn build(&self) -> Result<Box<dyn Policy>> {
        Ok(match self {
            PolicySpec::Sequential { k } => Box::new(SequentialTopK::new(*k)),
            PolicySpec::Static { tau } => Box::new(StaticThreshold::new(*tau)),
            PolicySpec::Factor { factor } => Box::new(FactorThreshold::new(*factor)),
            PolicySpec::Osdt { .. } => {
                bail!("OSDT needs a calibration profile; use Osdt::from_profile")
            }
        })
    }
}

/// Everything a policy may consult at one denoising step.
pub struct StepContext<'a> {
    /// Current gen block index (0-based).
    pub block: usize,
    /// Denoising step index *within* the current block (0-based).
    pub step: usize,
    /// Confidences of the still-masked positions of the current block
    /// (parallel to the engine's masked-position list).
    pub conf: &'a [f32],
}

/// The step metadata a policy sees *before* the forward pass runs — what
/// [`Policy::plan`] decides on. This is [`StepContext`] minus the
/// confidences: a device-fusible rule is exactly one that needs nothing
/// else.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanContext {
    pub block: usize,
    pub step: usize,
}

/// A policy's decision rule for one step, advertised ahead of the forward
/// pass (DESIGN.md §11). `Threshold`/`FactorMax` are device-fusible: the
/// scheduler routes such steps through the fused `fwd_window_accept`
/// kernels and the host never sees the confidence rows. `HostFull` keeps
/// the classic download-then-select path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepRule {
    /// Commit every masked position with `conf > tau` (f32 strict compare;
    /// see [`f32_below`] for the exact f64→f32 cutoff quantisation).
    Threshold { tau: f32 },
    /// Commit every masked position with `conf >= factor · cmax`, where
    /// `cmax` is the step's max masked confidence (f32 math).
    FactorMax { factor: f32 },
    /// The policy must see the full confidence row on the host.
    HostFull,
}

/// What a policy advertises for the next pass: the decision [`StepRule`]
/// plus an elision component. `skip_ahead = k > 0` means the policy's
/// profile predicts steps `s..s+k` of this block accept nothing beyond the
/// liveness fallback, so the scheduler should advance the schedule by `k`
/// and run the rule calibrated for step `s + k` instead (DESIGN.md §14).
/// The plan contract is unchanged: the advertised rule (+ argmax fallback)
/// must match `select_explain` at the *jumped-to* step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepPlan {
    pub rule: StepRule,
    pub skip_ahead: usize,
}

impl StepPlan {
    pub fn threshold(tau: f32) -> StepPlan {
        StepPlan { rule: StepRule::Threshold { tau }, skip_ahead: 0 }
    }

    pub fn factor_max(factor: f32) -> StepPlan {
        StepPlan { rule: StepRule::FactorMax { factor }, skip_ahead: 0 }
    }

    pub fn host_full() -> StepPlan {
        StepPlan { rule: StepRule::HostFull, skip_ahead: 0 }
    }
}

/// A threshold policy: selects which masked positions to commit.
pub trait Policy: Send {
    /// Raw selection rule. Returns indices **into `ctx.conf`**. May return
    /// an empty set — the engine-facing [`Policy::select`] applies the
    /// argmax fallback.
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize>;

    /// Human-readable name for logs/benches.
    fn name(&self) -> String;

    /// Advertise this step's decision rule *before* the pass runs — the
    /// device-fusible capability (DESIGN.md §11). A non-`HostFull` plan
    /// promises: applying the plan's rule (+ argmax fallback) to the
    /// masked positions yields exactly [`Policy::select_explain`]'s
    /// result. Default: `HostFull` (policy must see raw confidences).
    fn plan(&self, _ctx: &PlanContext) -> StepPlan {
        StepPlan::host_full()
    }

    /// Selection with the liveness fallback (Algorithm 1 lines 19–21):
    /// never returns an empty set for a non-empty `ctx.conf`.
    fn select(&self, ctx: &StepContext) -> Vec<usize> {
        self.select_explain(ctx).0
    }

    /// As [`Policy::select`], also reporting whether the argmax fallback
    /// fired (the A2 ablation measures how often each policy relies on it).
    fn select_explain(&self, ctx: &StepContext) -> (Vec<usize>, bool) {
        let picked = self.select_raw(ctx);
        if !picked.is_empty() || ctx.conf.is_empty() {
            return (picked, false);
        }
        (vec![argmax(ctx.conf)], true)
    }
}

/// Boxed policies are policies. Every method forwards — in particular
/// `plan`, which must NOT fall back to the trait default (that would
/// silently strip fusibility from any boxed policy).
impl Policy for Box<dyn Policy> {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        (**self).select_raw(ctx)
    }

    fn name(&self) -> String {
        (**self).name()
    }

    fn plan(&self, ctx: &PlanContext) -> StepPlan {
        (**self).plan(ctx)
    }

    fn select(&self, ctx: &StepContext) -> Vec<usize> {
        (**self).select(ctx)
    }

    fn select_explain(&self, ctx: &StepContext) -> (Vec<usize>, bool) {
        (**self).select_explain(ctx)
    }
}

/// Force the host-full decision path for a wrapped policy. Calibration
/// decodes (and any driver that needs complete per-step confidence
/// vectors, e.g. Figure 1/2 trace collection) wrap their policy in this:
/// a fused decode records only per-step mean confidences, which is enough
/// for drift signatures but not for `Calibrator`'s quantile metrics.
pub struct HostTraced<P: Policy>(pub P);

impl<P: Policy> Policy for HostTraced<P> {
    fn select_raw(&self, ctx: &StepContext) -> Vec<usize> {
        self.0.select_raw(ctx)
    }

    fn name(&self) -> String {
        format!("host-traced({})", self.0.name())
    }

    // inherits the default HostFull plan — that is the whole point
}

/// Largest f32 `c` with `c <= x` — the exact cutoff quantisation for
/// [`StepPlan::Threshold`]: for every f32 `conf`,
/// `conf > f32_below(x)` (f32 compare) ⟺ `f64::from(conf) > x` (f64
/// compare). Proof sketch: f32 values are a subset of f64, so
/// `f64::from(conf) > x` ⟺ `conf > x` as reals ⟺ `conf > c` (there is
/// no f32 strictly between `c` and `x` by maximality of `c`).
pub fn f32_below(x: f64) -> f32 {
    let c = x as f32; // round-to-nearest may land above x
    if f64::from(c) <= x {
        return c;
    }
    // step down one ulp
    if c == 0.0 {
        return -f32::from_bits(1);
    }
    let bits = c.to_bits();
    f32::from_bits(if c > 0.0 { bits - 1 } else { bits + 1 })
}

/// Index of the maximum confidence (ties -> lowest index, deterministic).
pub fn argmax(conf: &[f32]) -> usize {
    let mut best = 0;
    for (i, &c) in conf.iter().enumerate() {
        if c > conf[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_parse_roundtrip() {
        for m in [Metric::Mean, Metric::Q1, Metric::Median, Metric::Q3, Metric::MinWhisker] {
            assert_eq!(Metric::parse(m.as_str()).unwrap(), m);
        }
        assert!(Metric::parse("q5").is_err());
    }

    #[test]
    fn metric_reduce_matches_stats() {
        let xs = [0.1, 0.2, 0.3, 0.4, 0.5];
        assert!((Metric::Mean.reduce(&xs).unwrap() - 0.3).abs() < 1e-12);
        assert!((Metric::Q1.reduce(&xs).unwrap() - 0.2).abs() < 1e-12);
        assert!((Metric::Median.reduce(&xs).unwrap() - 0.3).abs() < 1e-12);
        assert!((Metric::Q3.reduce(&xs).unwrap() - 0.4).abs() < 1e-12);
        assert!(Metric::Mean.reduce(&[]).is_none());
    }

    #[test]
    fn argmax_tie_breaks_low() {
        assert_eq!(argmax(&[0.5, 0.9, 0.9, 0.1]), 1);
        assert_eq!(argmax(&[0.5]), 0);
    }

    #[test]
    fn spec_string_roundtrip() {
        use crate::config::parse_policy_spec;
        for spec in [
            PolicySpec::Sequential { k: 2 },
            PolicySpec::Static { tau: 0.9 },
            PolicySpec::Factor { factor: 0.95 },
            PolicySpec::Osdt {
                mode: DynamicMode::StepBlock,
                metric: Metric::Median,
                kappa: 0.75,
                epsilon: 0.2,
            },
        ] {
            let s = spec.to_spec_string();
            assert_eq!(parse_policy_spec(&s).unwrap(), spec, "{s}");
        }
    }

    #[test]
    fn f32_below_is_exact_strict_compare_quantisation() {
        use crate::util::{prop, rng::Rng};
        // spot values: representable, non-representable, boundaries
        assert_eq!(f32_below(0.5), 0.5);
        assert!(f64::from(f32_below(0.9)) <= 0.9);
        assert!(f64::from(f32_below(0.9)) > 0.8999);
        assert_eq!(f32_below(0.0), 0.0);
        assert!(f32_below(-1e-300) < 0.0);
        prop::forall(
            "f32-below-equivalence",
            500,
            |r: &mut Rng| {
                let tau = r.next_f64() * 1.2 - 0.1;
                let conf = (r.next_f64() * 1.2 - 0.1) as f32;
                (tau, conf)
            },
            |&(tau, conf)| {
                let c = f32_below(tau);
                if f64::from(c) > tau {
                    return Err(format!("f32_below({tau}) = {c} above input"));
                }
                let host = f64::from(conf) > tau;
                let dev = conf > c;
                if host != dev {
                    return Err(format!(
                        "conf {conf} tau {tau} cut {c}: host {host} != device {dev}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn plans_advertise_fusible_rules() {
        let ctx = PlanContext { block: 0, step: 0 };
        assert_eq!(
            StaticThreshold::new(0.9).plan(&ctx),
            StepPlan::threshold(f32_below(0.9))
        );
        assert_eq!(
            FactorThreshold::new(0.95).plan(&ctx),
            StepPlan::factor_max(0.95f64 as f32)
        );
        assert_eq!(SequentialTopK::new(1).plan(&ctx), StepPlan::host_full());
        // profile-free policies never elide
        assert_eq!(StaticThreshold::new(0.9).plan(&ctx).skip_ahead, 0);
        // the wrapper strips fusibility without changing selection
        let wrapped = HostTraced(StaticThreshold::new(0.9));
        assert_eq!(wrapped.plan(&ctx), StepPlan::host_full());
        let c = StepContext { block: 0, step: 0, conf: &[0.95, 0.2] };
        assert_eq!(wrapped.select(&c), StaticThreshold::new(0.9).select(&c));
    }

    #[test]
    fn fallback_guarantees_progress() {
        // a static policy with impossible tau still commits one position
        let p = StaticThreshold::new(0.99);
        let ctx = StepContext { block: 0, step: 0, conf: &[0.1, 0.5, 0.3] };
        assert_eq!(p.select(&ctx), vec![1]);
        // empty conf -> empty selection (block already done)
        let ctx2 = StepContext { block: 0, step: 0, conf: &[] };
        assert!(p.select(&ctx2).is_empty());
    }
}
