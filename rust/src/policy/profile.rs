//! Calibrated threshold profiles + on-disk persistence.
//!
//! A `Profile` is the output of Phase 1 (calibration) and the input to the
//! OSDT policy in Phase 2. `ProfileStore` persists profiles as JSON under a
//! directory keyed by (task, mode, metric) so a calibration can be reused
//! across server restarts — the "reusable task-level confidence signature"
//! the paper's conclusion points at.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::{DynamicMode, Metric};

/// Calibrated thresholds at block or step-block granularity.
#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    pub mode: DynamicMode,
    pub metric: Metric,
    /// Block mode: taus[b]. Step-block mode: taus_sb[b][s].
    block_taus: Vec<f64>,
    step_block_taus: Vec<Vec<f64>>,
}

impl Profile {
    pub fn block(taus: Vec<f64>, metric: Metric) -> Self {
        Profile {
            mode: DynamicMode::Block,
            metric,
            block_taus: taus,
            step_block_taus: vec![],
        }
    }

    pub fn step_block(taus: Vec<Vec<f64>>, metric: Metric) -> Self {
        Profile {
            mode: DynamicMode::StepBlock,
            metric,
            block_taus: vec![],
            step_block_taus: taus,
        }
    }

    pub fn num_blocks(&self) -> usize {
        match self.mode {
            DynamicMode::Block => self.block_taus.len(),
            DynamicMode::StepBlock => self.step_block_taus.len(),
        }
    }

    /// Calibrated step depth of block `b` (block mode: 1 if present).
    pub fn steps_in_block(&self, b: usize) -> usize {
        match self.mode {
            DynamicMode::Block => usize::from(b < self.block_taus.len()),
            DynamicMode::StepBlock => {
                self.step_block_taus.get(b).map(Vec::len).unwrap_or(0)
            }
        }
    }

    /// τ lookup (Algorithm 1 lines 13–16). Blocks beyond the calibrated
    /// range clamp to the last block; steps beyond the calibrated depth of
    /// a block clamp to its last step.
    pub fn tau(&self, block: usize, step: usize) -> f64 {
        match self.mode {
            DynamicMode::Block => {
                let b = block.min(self.block_taus.len().saturating_sub(1));
                self.block_taus.get(b).copied().unwrap_or(0.0)
            }
            DynamicMode::StepBlock => {
                let b = block.min(self.step_block_taus.len().saturating_sub(1));
                match self.step_block_taus.get(b) {
                    None => 0.0,
                    Some(steps) if steps.is_empty() => 0.0,
                    Some(steps) => {
                        let s = step.min(steps.len() - 1);
                        steps[s]
                    }
                }
            }
        }
    }

    // -- JSON persistence ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let taus = match self.mode {
            DynamicMode::Block => Json::from_f64s(&self.block_taus),
            DynamicMode::StepBlock => Json::Arr(
                self.step_block_taus
                    .iter()
                    .map(|v| Json::from_f64s(v))
                    .collect(),
            ),
        };
        Json::obj(vec![
            ("mode", Json::Str(self.mode.as_str().into())),
            ("metric", Json::Str(self.metric.as_str().into())),
            ("taus", taus),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Profile> {
        let mode = match j.req("mode").map_err(anyhow::Error::msg)?.as_str() {
            Some("block") => DynamicMode::Block,
            Some("step-block") => DynamicMode::StepBlock,
            m => bail!("bad profile mode {m:?}"),
        };
        let metric = Metric::parse(
            j.req("metric")
                .map_err(anyhow::Error::msg)?
                .as_str()
                .context("metric not a string")?,
        )?;
        let taus = j
            .req("taus")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("taus not an array")?;
        Ok(match mode {
            DynamicMode::Block => {
                let v: Option<Vec<f64>> = taus.iter().map(Json::as_f64).collect();
                Profile::block(v.context("taus must be numbers")?, metric)
            }
            DynamicMode::StepBlock => {
                let mut out = Vec::with_capacity(taus.len());
                for row in taus {
                    let row = row.as_arr().context("taus rows must be arrays")?;
                    let v: Option<Vec<f64>> = row.iter().map(Json::as_f64).collect();
                    out.push(v.context("taus must be numbers")?);
                }
                Profile::step_block(out, metric)
            }
        })
    }
}

/// Directory-backed profile store: one JSON file per (task, mode, metric).
pub struct ProfileStore {
    dir: PathBuf,
}

impl ProfileStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(ProfileStore { dir })
    }

    fn path(&self, task: &str, mode: DynamicMode, metric: Metric) -> PathBuf {
        self.dir
            .join(format!("{task}.{}.{}.json", mode.as_str(), metric.as_str()))
    }

    pub fn save(&self, task: &str, profile: &Profile) -> Result<PathBuf> {
        let path = self.path(task, profile.mode, profile.metric);
        let mut doc = profile.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("task".into(), Json::Str(task.into()));
        }
        std::fs::write(&path, format!("{doc}\n"))
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    pub fn load(&self, task: &str, mode: DynamicMode, metric: Metric) -> Result<Profile> {
        let path = self.path(task, mode, metric);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Profile::from_json(&Json::parse(&text)?)
    }

    pub fn exists(&self, task: &str, mode: DynamicMode, metric: Metric) -> bool {
        self.path(task, mode, metric).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_clamps_block_mode() {
        let p = Profile::block(vec![0.5, 0.7], Metric::Mean);
        assert_eq!(p.tau(0, 0), 0.5);
        assert_eq!(p.tau(1, 3), 0.7);
        assert_eq!(p.tau(9, 0), 0.7); // clamp to last block
    }

    #[test]
    fn tau_clamps_step_block_mode() {
        let p = Profile::step_block(vec![vec![0.3, 0.6], vec![0.9]], Metric::Q1);
        assert_eq!(p.tau(0, 0), 0.3);
        assert_eq!(p.tau(0, 1), 0.6);
        assert_eq!(p.tau(0, 5), 0.6); // clamp step
        assert_eq!(p.tau(1, 0), 0.9);
        assert_eq!(p.tau(5, 5), 0.9); // clamp block then step
    }

    #[test]
    fn empty_profile_is_permissive() {
        let p = Profile::block(vec![], Metric::Mean);
        assert_eq!(p.tau(0, 0), 0.0);
        let q = Profile::step_block(vec![vec![]], Metric::Mean);
        assert_eq!(q.tau(0, 0), 0.0);
    }

    #[test]
    fn json_roundtrip_block() {
        let p = Profile::block(vec![0.25, 0.5, 0.75], Metric::Q3);
        let back = Profile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn json_roundtrip_step_block() {
        let p = Profile::step_block(
            vec![vec![0.1, 0.2], vec![0.3], vec![]],
            Metric::MinWhisker,
        );
        let back = Profile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn store_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "osdt_profile_test_{}",
            std::process::id()
        ));
        let store = ProfileStore::new(&dir).unwrap();
        let p = Profile::block(vec![0.6, 0.7, 0.8], Metric::Q1);
        assert!(!store.exists("synth-math", DynamicMode::Block, Metric::Q1));
        store.save("synth-math", &p).unwrap();
        assert!(store.exists("synth-math", DynamicMode::Block, Metric::Q1));
        let back = store
            .load("synth-math", DynamicMode::Block, Metric::Q1)
            .unwrap();
        assert_eq!(p, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"mode":"spiral","metric":"q1","taus":[]}"#,
            r#"{"mode":"block","metric":"zzz","taus":[]}"#,
            r#"{"mode":"block","metric":"q1","taus":["a"]}"#,
            r#"{"mode":"block","metric":"q1"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Profile::from_json(&j).is_err(), "{bad}");
        }
    }
}
