//! Calibrated threshold profiles + on-disk persistence.
//!
//! A `Profile` is the output of Phase 1 (calibration) and the input to the
//! OSDT policy in Phase 2. A [`ProfileRecord`] wraps a profile with its
//! provenance — the calibration sequence's confidence signature and a
//! monotonically increasing version — and [`ProfileStore`] persists records
//! as JSON under a directory keyed by (task, mode, metric) so a calibration
//! can be reused across server restarts: the "reusable task-level confidence
//! signature" the paper's conclusion points at, made durable.
//!
//! Persistence format (DESIGN.md §9): one JSON object per file with
//! `schema` (currently 2), `task`, `mode`, `metric`, `taus`, `signature`
//! (step-block mean confidences of the calibration sequence, the drift
//! reference), and `version`. Schema-1 files (no signature/version) still
//! load; their signature is adopted from the first live decode. Task names
//! are percent-encoded into filenames so keys like `a/b` cannot escape the
//! store directory, and saves go through a temp-file + rename so a crashed
//! writer never leaves a torn profile behind.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::{DynamicMode, Metric};

/// On-disk schema version written by [`ProfileStore::save`]. Schema 3 adds
/// the optional `accepts` acceptance trajectory (absent in older records).
pub const PROFILE_SCHEMA_VERSION: u64 = 3;

/// Calibrated thresholds at block or step-block granularity.
#[derive(Clone, Debug, PartialEq)]
pub struct Profile {
    pub mode: DynamicMode,
    pub metric: Metric,
    /// Block mode: taus[b]. Step-block mode: taus_sb[b][s].
    block_taus: Vec<f64>,
    step_block_taus: Vec<Vec<f64>>,
    /// Per-(block, step) acceptance counts observed during calibration:
    /// `accepts[b][s]` = number of positions the calibrating decode
    /// committed at step `s` of block `b`. Empty when the profile predates
    /// schema 3 or was built without a trace — every prediction query then
    /// answers "no data" (0), which disables elision for that profile.
    accepts: Vec<Vec<f64>>,
}

impl Profile {
    pub fn block(taus: Vec<f64>, metric: Metric) -> Self {
        Profile {
            mode: DynamicMode::Block,
            metric,
            block_taus: taus,
            step_block_taus: vec![],
            accepts: vec![],
        }
    }

    pub fn step_block(taus: Vec<Vec<f64>>, metric: Metric) -> Self {
        Profile {
            mode: DynamicMode::StepBlock,
            metric,
            block_taus: vec![],
            step_block_taus: taus,
            accepts: vec![],
        }
    }

    /// Attach the calibration acceptance trajectory (`accepts[b][s]` =
    /// committed positions at step `s` of block `b`) — the raw material for
    /// the elision planner's [`Profile::predict_empty_run`] query.
    pub fn with_accepts(mut self, accepts: Vec<Vec<f64>>) -> Self {
        self.accepts = accepts;
        self
    }

    pub fn num_blocks(&self) -> usize {
        match self.mode {
            DynamicMode::Block => self.block_taus.len(),
            DynamicMode::StepBlock => self.step_block_taus.len(),
        }
    }

    /// Calibrated step depth of block `b` (block mode: 1 if present).
    pub fn steps_in_block(&self, b: usize) -> usize {
        match self.mode {
            DynamicMode::Block => usize::from(b < self.block_taus.len()),
            DynamicMode::StepBlock => {
                self.step_block_taus.get(b).map(Vec::len).unwrap_or(0)
            }
        }
    }

    /// τ lookup (Algorithm 1 lines 13–16). Blocks beyond the calibrated
    /// range clamp to the last block; steps beyond the calibrated depth of
    /// a block clamp to its last step.
    pub fn tau(&self, block: usize, step: usize) -> f64 {
        match self.mode {
            DynamicMode::Block => {
                let b = block.min(self.block_taus.len().saturating_sub(1));
                self.block_taus.get(b).copied().unwrap_or(0.0)
            }
            DynamicMode::StepBlock => {
                let b = block.min(self.step_block_taus.len().saturating_sub(1));
                match self.step_block_taus.get(b) {
                    None => 0.0,
                    Some(steps) if steps.is_empty() => 0.0,
                    Some(steps) => {
                        let s = step.min(steps.len() - 1);
                        steps[s]
                    }
                }
            }
        }
    }

    /// Calibrated trajectory depth of block `b`; 0 when no acceptance
    /// trajectory was recorded for it.
    pub fn trajectory_steps(&self, block: usize) -> usize {
        self.accepts.get(block).map(Vec::len).unwrap_or(0)
    }

    /// Elision query: how many consecutive steps starting at `step` does the
    /// calibration trajectory predict to accept fewer than `floor`
    /// positions? The no-data answer is 0 — an uncalibrated block, a step
    /// beyond the recorded trajectory, or a profile without an acceptance
    /// trajectory all predict "run the step" (elision never fires on
    /// guesswork). Unlike `tau()`, this deliberately does NOT clamp to
    /// neighbouring units: clamped extrapolation is exactly the low-confidence
    /// case the planner must treat as no-data.
    pub fn predict_empty_run(&self, block: usize, step: usize, floor: f64) -> usize {
        let Some(steps) = self.accepts.get(block) else {
            return 0;
        };
        steps
            .iter()
            .skip(step)
            .take_while(|&&a| a < floor)
            .count()
    }

    /// Per-unit EMA toward `new`: τ' = (1 − α)·τ + α·τ_new, the refinement
    /// rule shared by [`super::AdaptiveOsdt`] and the registry's
    /// observation path. Units calibrated in only one of the two profiles
    /// blend against the other's clamped `tau()` lookup, so the result
    /// covers the deeper of the two. The acceptance trajectory is carried
    /// forward from `self` unchanged: refinement adjusts thresholds, while
    /// the trajectory stays anchored to the original calibration decode
    /// (a fresh one arrives only through full recalibration).
    pub fn blend(&self, new: &Profile, alpha: f64) -> Profile {
        let nb = self.num_blocks().max(new.num_blocks());
        let blended = match self.mode {
            DynamicMode::Block => {
                let taus = (0..nb)
                    .map(|b| {
                        (1.0 - alpha) * self.tau(b, 0) + alpha * new.tau(b, 0)
                    })
                    .collect();
                Profile::block(taus, self.metric)
            }
            DynamicMode::StepBlock => {
                let taus = (0..nb)
                    .map(|b| {
                        let depth =
                            self.steps_in_block(b).max(new.steps_in_block(b)).max(1);
                        (0..depth)
                            .map(|s| {
                                (1.0 - alpha) * self.tau(b, s) + alpha * new.tau(b, s)
                            })
                            .collect()
                    })
                    .collect();
                Profile::step_block(taus, self.metric)
            }
        };
        blended.with_accepts(self.accepts.clone())
    }

    // -- JSON persistence ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        let taus = match self.mode {
            DynamicMode::Block => Json::from_f64s(&self.block_taus),
            DynamicMode::StepBlock => Json::Arr(
                self.step_block_taus
                    .iter()
                    .map(|v| Json::from_f64s(v))
                    .collect(),
            ),
        };
        let mut fields = vec![
            ("mode", Json::Str(self.mode.as_str().into())),
            ("metric", Json::Str(self.metric.as_str().into())),
            ("taus", taus),
        ];
        if !self.accepts.is_empty() {
            fields.push((
                "accepts",
                Json::Arr(
                    self.accepts.iter().map(|v| Json::from_f64s(v)).collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Profile> {
        let mode = match j.req("mode").map_err(anyhow::Error::msg)?.as_str() {
            Some("block") => DynamicMode::Block,
            Some("step-block") => DynamicMode::StepBlock,
            m => bail!("bad profile mode {m:?}"),
        };
        let metric = Metric::parse(
            j.req("metric")
                .map_err(anyhow::Error::msg)?
                .as_str()
                .context("metric not a string")?,
        )?;
        let taus = j
            .req("taus")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("taus not an array")?;
        let profile = match mode {
            DynamicMode::Block => {
                let v: Option<Vec<f64>> = taus.iter().map(Json::as_f64).collect();
                Profile::block(v.context("taus must be numbers")?, metric)
            }
            DynamicMode::StepBlock => {
                let mut out = Vec::with_capacity(taus.len());
                for row in taus {
                    let row = row.as_arr().context("taus rows must be arrays")?;
                    let v: Option<Vec<f64>> = row.iter().map(Json::as_f64).collect();
                    out.push(v.context("taus must be numbers")?);
                }
                Profile::step_block(out, metric)
            }
        };
        // schema-3 acceptance trajectory; absent in older records
        let accepts = match j.get("accepts").and_then(Json::as_arr) {
            None => vec![],
            Some(rows) => {
                let mut out = Vec::with_capacity(rows.len());
                for row in rows {
                    let row = row.as_arr().context("accepts rows must be arrays")?;
                    let v: Option<Vec<f64>> = row.iter().map(Json::as_f64).collect();
                    out.push(v.context("accepts must be numbers")?);
                }
                out
            }
        };
        Ok(profile.with_accepts(accepts))
    }
}

/// A profile with its persistence metadata: the owning task, the
/// calibration sequence's confidence signature (the drift-detection
/// reference), and a version that increments on every recalibration or
/// refinement.
#[derive(Clone, Debug, PartialEq)]
pub struct ProfileRecord {
    pub task: String,
    pub profile: Profile,
    /// `CalibrationTrace::signature()` of the calibrating sequence; empty
    /// for schema-1 records (adopted lazily from the first live decode).
    pub signature: Vec<f64>,
    pub version: u64,
}

impl ProfileRecord {
    pub fn new(task: impl Into<String>, profile: Profile, signature: Vec<f64>) -> Self {
        ProfileRecord {
            task: task.into(),
            profile,
            signature,
            version: 1,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut doc = self.profile.to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema".into(), Json::Num(PROFILE_SCHEMA_VERSION as f64));
            m.insert("task".into(), Json::Str(self.task.clone()));
            m.insert("signature".into(), Json::from_f64s(&self.signature));
            m.insert("version".into(), Json::Num(self.version as f64));
        }
        doc
    }

    /// Parse a persisted record. Schema-1 documents (no `schema` key) are
    /// accepted with an empty signature and version 0; unknown newer
    /// schemas are rejected.
    pub fn from_json(j: &Json, fallback_task: &str) -> Result<ProfileRecord> {
        let schema = j.get("schema").and_then(Json::as_f64).unwrap_or(1.0) as u64;
        if schema > PROFILE_SCHEMA_VERSION {
            bail!("profile schema {schema} is newer than supported {PROFILE_SCHEMA_VERSION}");
        }
        let profile = Profile::from_json(j)?;
        let signature = match j.get("signature").and_then(Json::as_arr) {
            None => vec![],
            Some(arr) => {
                let v: Option<Vec<f64>> = arr.iter().map(Json::as_f64).collect();
                v.context("signature must be numbers")?
            }
        };
        Ok(ProfileRecord {
            task: j
                .get("task")
                .and_then(Json::as_str)
                .unwrap_or(fallback_task)
                .to_string(),
            profile,
            signature,
            version: j.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        })
    }
}

/// Percent-encode a task name into a filename-safe component: `[A-Za-z0-9_-]`
/// pass through, everything else (including `/`, `.`, `%`) becomes `%XX`
/// per byte. The result contains no path separators and no `.` so the
/// `task.mode.metric.json` filename splits unambiguously.
pub fn encode_task(task: &str) -> String {
    let mut out = String::with_capacity(task.len());
    for b in task.bytes() {
        if b.is_ascii_alphanumeric() || b == b'_' || b == b'-' {
            out.push(b as char);
        } else {
            out.push_str(&format!("%{b:02X}"));
        }
    }
    out
}

/// Inverse of [`encode_task`].
pub fn decode_task(encoded: &str) -> Result<String> {
    let bytes = encoded.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = encoded
                .get(i + 1..i + 3)
                .with_context(|| format!("truncated escape in {encoded:?}"))?;
            out.push(
                u8::from_str_radix(hex, 16)
                    .with_context(|| format!("bad escape %{hex} in {encoded:?}"))?,
            );
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).context("decoded task is not UTF-8")
}

/// Unique suffix for temp files so concurrent saves never collide.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Directory-backed profile store: one JSON file per (task, mode, metric).
pub struct ProfileStore {
    dir: PathBuf,
}

impl ProfileStore {
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        Ok(ProfileStore { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, task: &str, mode: DynamicMode, metric: Metric) -> PathBuf {
        self.dir.join(format!(
            "{}.{}.{}.json",
            encode_task(task),
            mode.as_str(),
            metric.as_str()
        ))
    }

    /// Atomically persist a record: write a unique temp file in the store
    /// directory, then rename over the target.
    pub fn save(&self, record: &ProfileRecord) -> Result<PathBuf> {
        let path = self.path(&record.task, record.profile.mode, record.profile.metric);
        let tmp = self.dir.join(format!(
            ".tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, format!("{}\n", record.to_json()))
            .with_context(|| format!("writing {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, &path) {
            std::fs::remove_file(&tmp).ok();
            return Err(e).with_context(|| {
                format!("renaming {} -> {}", tmp.display(), path.display())
            });
        }
        Ok(path)
    }

    pub fn load(&self, task: &str, mode: DynamicMode, metric: Metric) -> Result<ProfileRecord> {
        let path = self.path(task, mode, metric);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        ProfileRecord::from_json(&Json::parse(&text)?, task)
    }

    pub fn exists(&self, task: &str, mode: DynamicMode, metric: Metric) -> bool {
        self.path(task, mode, metric).exists()
    }

    /// Load every parseable record in the store (warm start). Files that
    /// fail to parse are skipped with a warning — one corrupt profile must
    /// not prevent the rest of the fleet state from loading.
    pub fn load_all(&self) -> Result<Vec<ProfileRecord>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)
            .with_context(|| format!("listing {}", self.dir.display()))?
        {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some(stem) = name.strip_suffix(".json") else {
                continue; // temp files, foreign content
            };
            // filename is ENCTASK.MODE.METRIC — split from the right since
            // the encoded task cannot contain '.'
            let mut parts = stem.rsplitn(3, '.');
            let (Some(_metric), Some(_mode), Some(enc)) =
                (parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let task = match decode_task(enc) {
                Ok(t) => t,
                Err(e) => {
                    log::warn!("skipping profile {name}: {e:#}");
                    continue;
                }
            };
            let parsed = std::fs::read_to_string(&path)
                .map_err(anyhow::Error::from)
                .and_then(|text| Json::parse(&text).map_err(anyhow::Error::from))
                .and_then(|j| ProfileRecord::from_json(&j, &task));
            match parsed {
                Ok(rec) => out.push(rec),
                Err(e) => log::warn!("skipping profile {name}: {e:#}"),
            }
        }
        Ok(out)
    }

    // -- cross-process invalidation (DESIGN.md §16) ----------------------

    fn generation_path(&self) -> PathBuf {
        self.dir.join(GENERATION_FILE)
    }

    /// Fleet-wide profile generation: bumped exactly once per fulfilled
    /// calibration anywhere in the fleet. 0 while the file is absent.
    /// Peers compare it against their last-synced value to decide when
    /// to re-scan the store for newer profile versions.
    pub fn generation(&self) -> u64 {
        std::fs::read_to_string(self.generation_path())
            .ok()
            .and_then(|s| s.trim().parse().ok())
            .unwrap_or(0)
    }

    /// Bump the generation counter (temp + rename). Callers hold the
    /// per-key calibration lease, so concurrent bumps are for *different*
    /// keys; losing a counter race costs at most one extra store scan on
    /// a peer, never a missed invalidation (peers compare per-record
    /// `version`s, the generation is only the cheap change signal).
    pub fn bump_generation(&self) -> Result<u64> {
        let next = self.generation() + 1;
        let tmp = self.dir.join(format!(
            ".tmp.gen.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, format!("{next}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, self.generation_path()) {
            std::fs::remove_file(&tmp).ok();
            return Err(e).context("renaming generation file");
        }
        Ok(next)
    }

    fn lease_path(&self, task: &str, mode: DynamicMode, metric: Metric) -> PathBuf {
        self.dir.join(format!(
            ".lease.{}.{}.{}",
            encode_task(task),
            mode.as_str(),
            metric.as_str()
        ))
    }

    /// Try to take the *cross-process* calibration lease for one key:
    /// `O_CREAT|O_EXCL` on a lease file holding `pid created_unix_ms`.
    /// `Ok(Some)` — the caller holds the fleet-wide lease (released when
    /// the [`StoreLease`] drops). `Ok(None)` — a live peer process holds
    /// it. A lease whose recorded holder is dead (checked via `/proc`) or
    /// whose age exceeds `ttl` is broken and taken over, so a SIGKILLed
    /// calibrator cannot wedge the key fleet-wide.
    pub fn try_lease(
        &self,
        task: &str,
        mode: DynamicMode,
        metric: Metric,
        ttl: std::time::Duration,
    ) -> Result<Option<StoreLease>> {
        let path = self.lease_path(task, mode, metric);
        let mut broke_stale = false;
        for _ in 0..4 {
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut f) => {
                    use std::io::Write;
                    writeln!(f, "{} {}", std::process::id(), unix_ms())
                        .with_context(|| format!("writing {}", path.display()))?;
                    return Ok(Some(StoreLease { path, took_over: broke_stale }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let content =
                        std::fs::read_to_string(&path).unwrap_or_default();
                    let mut it = content.split_whitespace();
                    let pid: u32 =
                        it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
                    let created: u64 =
                        it.next().and_then(|s| s.parse().ok()).unwrap_or(0);
                    let expired =
                        unix_ms().saturating_sub(created) > ttl.as_millis() as u64;
                    if crate::util::procfs::pid_alive(pid) && !expired {
                        return Ok(None);
                    }
                    // Dead or expired holder: break the lease and retry
                    // the exclusive create (bounded — two breakers racing
                    // resolve within a couple of iterations, and a loser
                    // reporting Ok(None) merely waits a round).
                    broke_stale = true;
                    let _ = std::fs::remove_file(&path);
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("creating lease {}", path.display())
                    })
                }
            }
        }
        Ok(None)
    }

    /// Forcibly take the cross-process lease regardless of its holder —
    /// the file analogue of `ProfileRegistry::acquire_stealing`, used
    /// when an in-memory steal has already decided the outstanding
    /// calibration is past its patience.
    pub fn force_lease(
        &self,
        task: &str,
        mode: DynamicMode,
        metric: Metric,
    ) -> Result<StoreLease> {
        let path = self.lease_path(task, mode, metric);
        let took_over = path.exists();
        std::fs::write(&path, format!("{} {}\n", std::process::id(), unix_ms()))
            .with_context(|| format!("writing lease {}", path.display()))?;
        Ok(StoreLease { path, took_over })
    }
}

/// Name of the fleet-wide generation counter file inside a store dir.
const GENERATION_FILE: &str = ".generation";

fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Held cross-process calibration lease; the lease file is removed on
/// drop (fulfilled or abandoned — the in-memory lease protocol decides
/// which, the file only fences *other processes*).
#[derive(Debug)]
pub struct StoreLease {
    path: PathBuf,
    /// The lease was taken from a dead/expired/stolen-from holder.
    pub took_over: bool,
}

impl Drop for StoreLease {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> (ProfileStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "osdt_profile_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        (ProfileStore::new(&dir).unwrap(), dir)
    }

    #[test]
    fn tau_clamps_block_mode() {
        let p = Profile::block(vec![0.5, 0.7], Metric::Mean);
        assert_eq!(p.tau(0, 0), 0.5);
        assert_eq!(p.tau(1, 3), 0.7);
        assert_eq!(p.tau(9, 0), 0.7); // clamp to last block
    }

    #[test]
    fn tau_clamps_step_block_mode() {
        let p = Profile::step_block(vec![vec![0.3, 0.6], vec![0.9]], Metric::Q1);
        assert_eq!(p.tau(0, 0), 0.3);
        assert_eq!(p.tau(0, 1), 0.6);
        assert_eq!(p.tau(0, 5), 0.6); // clamp step
        assert_eq!(p.tau(1, 0), 0.9);
        assert_eq!(p.tau(5, 5), 0.9); // clamp block then step
    }

    #[test]
    fn empty_profile_is_permissive() {
        let p = Profile::block(vec![], Metric::Mean);
        assert_eq!(p.tau(0, 0), 0.0);
        let q = Profile::step_block(vec![vec![]], Metric::Mean);
        assert_eq!(q.tau(0, 0), 0.0);
    }

    #[test]
    fn blend_moves_toward_new() {
        let old = Profile::block(vec![0.2, 0.2], Metric::Mean);
        let new = Profile::block(vec![0.8, 0.8], Metric::Mean);
        let b = old.blend(&new, 0.5);
        assert!((b.tau(0, 0) - 0.5).abs() < 1e-12);
        assert_eq!(old.blend(&new, 0.0), old);
        assert_eq!(old.blend(&new, 1.0), new);
    }

    #[test]
    fn predict_empty_run_counts_below_floor() {
        let p = Profile::step_block(
            vec![vec![0.5; 5], vec![0.5; 3]],
            Metric::Q1,
        )
        .with_accepts(vec![vec![4.0, 1.0, 1.0, 1.0, 3.0], vec![1.0, 1.0, 1.0]]);
        // floor 1.5: steps accepting only the liveness fallback are "empty"
        assert_eq!(p.predict_empty_run(0, 0, 1.5), 0); // productive step
        assert_eq!(p.predict_empty_run(0, 1, 1.5), 3); // run of 3 fallback steps
        assert_eq!(p.predict_empty_run(0, 2, 1.5), 2); // suffix of that run
        assert_eq!(p.predict_empty_run(0, 4, 1.5), 0);
        assert_eq!(p.predict_empty_run(1, 0, 1.5), 3); // all-empty block
        assert_eq!(p.trajectory_steps(0), 5);
        assert_eq!(p.trajectory_steps(1), 3);
    }

    #[test]
    fn predict_empty_run_no_data_is_zero() {
        // no trajectory attached at all
        let bare = Profile::step_block(vec![vec![0.5, 0.5]], Metric::Q1);
        assert_eq!(bare.predict_empty_run(0, 0, 1.5), 0);
        assert_eq!(bare.trajectory_steps(0), 0);
        let p = Profile::step_block(vec![vec![0.5, 0.5]], Metric::Q1)
            .with_accepts(vec![vec![1.0, 1.0]]);
        // block beyond the trajectory: no clamping, answer 0
        assert_eq!(p.predict_empty_run(7, 0, 1.5), 0);
        // step beyond the recorded depth: answer 0
        assert_eq!(p.predict_empty_run(0, 2, 1.5), 0);
        assert_eq!(p.predict_empty_run(0, 99, 1.5), 0);
    }

    #[test]
    fn blend_preserves_accepts_trajectory() {
        let old = Profile::block(vec![0.2], Metric::Mean)
            .with_accepts(vec![vec![2.0, 1.0]]);
        let new = Profile::block(vec![0.8], Metric::Mean);
        let b = old.blend(&new, 0.5);
        assert_eq!(b.predict_empty_run(0, 1, 1.5), 1);
        assert_eq!(b.trajectory_steps(0), 2);
    }

    #[test]
    fn json_roundtrip_with_accepts() {
        let p = Profile::step_block(vec![vec![0.1, 0.2], vec![0.3]], Metric::Q1)
            .with_accepts(vec![vec![3.0, 1.0], vec![2.0]]);
        let back = Profile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
        // schema-2 documents (no accepts key) still load, with no trajectory
        let j = Json::parse(
            r#"{"schema":2,"mode":"block","metric":"q1","taus":[0.5]}"#,
        )
        .unwrap();
        let rec = ProfileRecord::from_json(&j, "t").unwrap();
        assert_eq!(rec.profile.trajectory_steps(0), 0);
    }

    #[test]
    fn json_roundtrip_block() {
        let p = Profile::block(vec![0.25, 0.5, 0.75], Metric::Q3);
        let back = Profile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn json_roundtrip_step_block() {
        let p = Profile::step_block(
            vec![vec![0.1, 0.2], vec![0.3], vec![]],
            Metric::MinWhisker,
        );
        let back = Profile::from_json(&p.to_json()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn record_roundtrip_with_signature() {
        let rec = ProfileRecord {
            task: "synth-math".into(),
            profile: Profile::block(vec![0.6, 0.7], Metric::Q1),
            signature: vec![0.4, 0.9, 0.5],
            version: 3,
        };
        let back = ProfileRecord::from_json(&rec.to_json(), "fallback").unwrap();
        assert_eq!(rec, back);
    }

    #[test]
    fn schema1_record_loads_with_empty_signature() {
        let j = Json::parse(r#"{"mode":"block","metric":"q1","taus":[0.5]}"#).unwrap();
        let rec = ProfileRecord::from_json(&j, "t").unwrap();
        assert_eq!(rec.task, "t");
        assert!(rec.signature.is_empty());
        assert_eq!(rec.version, 0);
    }

    #[test]
    fn newer_schema_rejected() {
        let j = Json::parse(r#"{"schema":99,"mode":"block","metric":"q1","taus":[0.5]}"#)
            .unwrap();
        assert!(ProfileRecord::from_json(&j, "t").is_err());
    }

    #[test]
    fn task_encoding_roundtrip() {
        for task in ["synth-math", "a/b", "../../etc/passwd", "dots.and.%", "日本語"] {
            let enc = encode_task(task);
            assert!(!enc.contains('/') && !enc.contains('.'), "{enc}");
            assert_eq!(decode_task(&enc).unwrap(), task, "{task}");
        }
        assert!(decode_task("%Z").is_err());
        assert!(decode_task("%4").is_err());
    }

    #[test]
    fn store_roundtrip() {
        let (store, dir) = tmp_store("roundtrip");
        let rec = ProfileRecord::new(
            "synth-math",
            Profile::block(vec![0.6, 0.7, 0.8], Metric::Q1),
            vec![0.1, 0.2],
        );
        assert!(!store.exists("synth-math", DynamicMode::Block, Metric::Q1));
        store.save(&rec).unwrap();
        assert!(store.exists("synth-math", DynamicMode::Block, Metric::Q1));
        let back = store
            .load("synth-math", DynamicMode::Block, Metric::Q1)
            .unwrap();
        assert_eq!(rec, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hostile_task_names_stay_inside_the_store() {
        let (store, dir) = tmp_store("hostile");
        let rec = ProfileRecord::new(
            "../escape/attempt",
            Profile::block(vec![0.5], Metric::Mean),
            vec![],
        );
        let path = store.save(&rec).unwrap();
        assert_eq!(path.parent().unwrap(), dir.as_path());
        let entries: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(entries.len(), 1, "exactly one file, inside the store dir");
        let back = store
            .load("../escape/attempt", DynamicMode::Block, Metric::Mean)
            .unwrap();
        assert_eq!(back.task, "../escape/attempt");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_all_recovers_every_record() {
        let (store, dir) = tmp_store("loadall");
        for (task, tau) in [("synth-math", 0.6), ("a/b", 0.7)] {
            store
                .save(&ProfileRecord::new(
                    task,
                    Profile::block(vec![tau], Metric::Q1),
                    vec![tau],
                ))
                .unwrap();
        }
        // corrupt stragglers are skipped, not fatal
        std::fs::write(dir.join("bogus.block.q1.json"), "{not json").unwrap();
        std::fs::write(dir.join("README"), "hi").unwrap();
        let mut all = store.load_all().unwrap();
        all.sort_by(|a, b| a.task.cmp(&b.task));
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].task, "a/b");
        assert_eq!(all[1].task, "synth-math");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_leaves_no_temp_files() {
        let (store, dir) = tmp_store("atomic");
        store
            .save(&ProfileRecord::new(
                "t",
                Profile::block(vec![0.5], Metric::Mean),
                vec![],
            ))
            .unwrap();
        for e in std::fs::read_dir(&dir).unwrap() {
            let name = e.unwrap().file_name();
            assert!(
                name.to_string_lossy().ends_with(".json"),
                "stray file {name:?}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn generation_counter_bumps_and_survives_reopen() {
        let (store, dir) = tmp_store("gen");
        assert_eq!(store.generation(), 0, "absent file reads as 0");
        assert_eq!(store.bump_generation().unwrap(), 1);
        assert_eq!(store.bump_generation().unwrap(), 2);
        // a second store handle on the same dir (another process in
        // production) observes the same counter
        let peer = ProfileStore::new(&dir).unwrap();
        assert_eq!(peer.generation(), 2);
        // the counter file is ignored by warm-start scans
        assert!(store.load_all().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_lease_is_exclusive_and_released_on_drop() {
        let (store, dir) = tmp_store("lease");
        let ttl = std::time::Duration::from_secs(60);
        let lease = store
            .try_lease("t", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .expect("first taker holds the lease");
        assert!(!lease.took_over);
        // our own (live) pid holds it: a peer store on the same dir is
        // refused — exactly the two-replica single-flight case
        let peer = ProfileStore::new(&dir).unwrap();
        assert!(peer
            .try_lease("t", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .is_none());
        // a different key is independent
        assert!(peer
            .try_lease("t2", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .is_some());
        drop(lease);
        assert!(store
            .try_lease("t", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_holder_lease_is_taken_over() {
        let (store, dir) = tmp_store("leasedead");
        let ttl = std::time::Duration::from_secs(60);
        // hand-write a lease naming a dead pid, as a SIGKILLed replica
        // would leave behind
        std::fs::write(
            dir.join(".lease.t.block.q1"),
            format!("{} {}\n", u32::MAX, 0),
        )
        .unwrap();
        let lease = store
            .try_lease("t", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .expect("dead holder must be broken");
        drop(lease);
        // an *expired* lease from a live pid is broken too
        std::fs::write(
            dir.join(".lease.t.block.q1"),
            format!("{} {}\n", std::process::id(), 0),
        )
        .unwrap();
        assert!(store
            .try_lease(
                "t",
                DynamicMode::Block,
                Metric::Q1,
                std::time::Duration::from_millis(1),
            )
            .unwrap()
            .is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn force_lease_steals_from_a_live_holder() {
        let (store, dir) = tmp_store("leaseforce");
        let ttl = std::time::Duration::from_secs(60);
        let _held = store
            .try_lease("t", DynamicMode::Block, Metric::Q1, ttl)
            .unwrap()
            .unwrap();
        let stolen =
            store.force_lease("t", DynamicMode::Block, Metric::Q1).unwrap();
        assert!(stolen.took_over);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"mode":"spiral","metric":"q1","taus":[]}"#,
            r#"{"mode":"block","metric":"zzz","taus":[]}"#,
            r#"{"mode":"block","metric":"q1","taus":["a"]}"#,
            r#"{"mode":"block","metric":"q1"}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Profile::from_json(&j).is_err(), "{bad}");
        }
    }
}
