//! Multi-replica request router (the vLLM-router analog for this stack).
//!
//! A [`Router`] fronts several [`Coordinator`] replicas and places each
//! request according to a [`RoutingPolicy`]:
//!
//! - `RoundRobin`       — uniform spread.
//! - `LeastOutstanding` — join-the-shortest-queue by in-flight count.
//! - `TaskAffinity`     — hash the task name to a home replica, spilling to
//!   the least-loaded one when the home replica is overloaded.
//!
//! Since the fleet-wide [`ProfileRegistry`](crate::policy::ProfileRegistry)
//! (replicas built via [`Coordinator::start_with_registry`] around one
//! shared `Arc`), *single calibration per task* holds under **any** routing
//! policy by construction — the registry's calibration lease, not hash
//! placement, enforces it. `TaskAffinity` remains as a cache-warmth
//! optimization: keeping a task's requests on one replica keeps that
//! replica's runtime and batch composition warm for the task, and for
//! fleets of *independent* coordinators (separate registries, e.g. separate
//! processes without a shared store) it still bounds calibrations to one
//! per task per process.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::{Coordinator, Request, Response};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastOutstanding,
    TaskAffinity {
        /// spill to least-loaded when home has this many more in-flight
        /// requests than the least-loaded replica
        spill_margin: usize,
    },
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RoutingPolicy::RoundRobin,
            "least-outstanding" | "lo" => RoutingPolicy::LeastOutstanding,
            "task-affinity" | "affinity" => {
                RoutingPolicy::TaskAffinity { spill_margin: 4 }
            }
            other => bail!("unknown routing policy {other:?}"),
        })
    }
}

struct Replica {
    coordinator: Arc<Coordinator>,
    outstanding: AtomicUsize,
    routed_total: AtomicU64,
}

pub struct Router {
    replicas: Vec<Replica>,
    policy: RoutingPolicy,
    rr_cursor: AtomicUsize,
}

/// FNV-1a, stable across runs (task -> home replica).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Router {
    pub fn new(replicas: Vec<Arc<Coordinator>>, policy: RoutingPolicy) -> Result<Self> {
        if replicas.is_empty() {
            bail!("router needs at least one replica");
        }
        Ok(Router {
            replicas: replicas
                .into_iter()
                .map(|coordinator| Replica {
                    coordinator,
                    outstanding: AtomicUsize::new(0),
                    routed_total: AtomicU64::new(0),
                })
                .collect(),
            policy,
            rr_cursor: AtomicUsize::new(0),
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Requests routed to each replica so far.
    pub fn routed_counts(&self) -> Vec<u64> {
        self.replicas
            .iter()
            .map(|r| r.routed_total.load(Ordering::Relaxed))
            .collect()
    }

    /// In-flight per replica (requests submitted whose response has not yet
    /// been *observed through* [`RoutedResponse`]).
    pub fn outstanding(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.outstanding.load(Ordering::Relaxed))
            .collect()
    }

    fn least_loaded(&self) -> usize {
        (0..self.replicas.len())
            .min_by_key(|&i| self.replicas[i].outstanding.load(Ordering::Relaxed))
            .unwrap()
    }

    /// Pick a replica index for this request.
    pub fn place(&self, req: &Request) -> usize {
        match self.policy {
            RoutingPolicy::RoundRobin => {
                self.rr_cursor.fetch_add(1, Ordering::Relaxed) % self.replicas.len()
            }
            RoutingPolicy::LeastOutstanding => self.least_loaded(),
            RoutingPolicy::TaskAffinity { spill_margin } => {
                let home = (fnv1a(&req.task) as usize) % self.replicas.len();
                let least = self.least_loaded();
                let home_load = self.replicas[home].outstanding.load(Ordering::Relaxed);
                let least_load =
                    self.replicas[least].outstanding.load(Ordering::Relaxed);
                if home_load > least_load + spill_margin {
                    least // overload spill
                } else {
                    home
                }
            }
        }
    }

    /// Route and submit; the returned handle decrements the in-flight count
    /// when the response is received.
    pub fn submit(&self, req: Request) -> RoutedResponse<'_> {
        let idx = self.place(&req);
        let replica = &self.replicas[idx];
        replica.outstanding.fetch_add(1, Ordering::Relaxed);
        replica.routed_total.fetch_add(1, Ordering::Relaxed);
        let rx = replica.coordinator.submit(req);
        RoutedResponse { router: self, replica: idx, rx }
    }

    /// Convenience blocking call.
    pub fn generate(&self, task: &str, prompt: &str, policy: &str) -> Result<Response> {
        self.submit(Request {
            id: 0,
            task: task.into(),
            prompt: prompt.into(),
            policy: policy.into(),
            slo_ms: None,
        })
        .recv()
    }
}

/// A pending routed request.
pub struct RoutedResponse<'r> {
    router: &'r Router,
    replica: usize,
    rx: Receiver<Response>,
}

impl RoutedResponse<'_> {
    pub fn replica(&self) -> usize {
        self.replica
    }

    pub fn recv(self) -> Result<Response> {
        let out = self.rx.recv();
        self.router.replicas[self.replica]
            .outstanding
            .fetch_sub(1, Ordering::Relaxed);
        out.map_err(|_| anyhow::anyhow!("replica {} dropped the request", self.replica))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::model::fixtures::tiny_config;
    use crate::policy::ProfileRegistry;
    use crate::sim::SimModel;

    fn replica() -> Arc<Coordinator> {
        Arc::new(
            Coordinator::start(CoordinatorConfig::default(), tiny_config(), |_| {
                Ok(SimModel::math_like(1))
            })
            .unwrap(),
        )
    }

    fn replica_with(registry: &Arc<ProfileRegistry>) -> Arc<Coordinator> {
        Arc::new(
            Coordinator::start_with_registry(
                CoordinatorConfig::default(),
                tiny_config(),
                registry.clone(),
                |_| Ok(SimModel::math_like(1)),
            )
            .unwrap(),
        )
    }

    fn req(task: &str, i: usize) -> Request {
        Request {
            id: 0,
            task: task.into(),
            prompt: format!("Q: {i}+1=?"),
            policy: "static:0.9".into(),
            slo_ms: None,
        }
    }

    #[test]
    fn round_robin_spreads_evenly() {
        let r = Router::new(vec![replica(), replica(), replica()], RoutingPolicy::RoundRobin)
            .unwrap();
        let pending: Vec<_> = (0..9).map(|i| r.submit(req("synth-math", i))).collect();
        assert_eq!(r.routed_counts(), vec![3, 3, 3]);
        for p in pending {
            assert!(p.recv().unwrap().error.is_none());
        }
        assert_eq!(r.outstanding(), vec![0, 0, 0]);
    }

    #[test]
    fn task_affinity_pins_tasks() {
        let r = Router::new(
            vec![replica(), replica(), replica()],
            RoutingPolicy::TaskAffinity { spill_margin: 100 },
        )
        .unwrap();
        let a0 = r.place(&req("synth-math", 0));
        let a1 = r.place(&req("synth-math", 1));
        assert_eq!(a0, a1, "same task -> same replica");
        // osdt flows: exactly one calibration per task across the fleet
        let pending: Vec<_> = (0..6)
            .map(|i| {
                r.submit(Request {
                    policy: "osdt:block:q1:0.75:0.2".into(),
                    ..req("synth-math", i)
                })
            })
            .collect();
        let calibrated: usize = pending
            .into_iter()
            .map(|p| usize::from(p.recv().unwrap().calibrated))
            .sum();
        assert_eq!(calibrated, 1, "task affinity -> one calibration");
    }

    #[test]
    fn shared_registry_calibrates_once_under_any_routing() {
        // the registry acceptance bar: N replicas sharing one registry,
        // M concurrent same-task OSDT requests, *round-robin* routing (no
        // affinity to lean on) -> exactly one calibration fleet-wide,
        // enforced by the calibration lease alone
        let registry = Arc::new(ProfileRegistry::in_memory());
        let replicas = vec![
            replica_with(&registry),
            replica_with(&registry),
            replica_with(&registry),
        ];
        let coords: Vec<Arc<Coordinator>> = replicas.clone();
        let r = Router::new(replicas, RoutingPolicy::RoundRobin).unwrap();
        let pending: Vec<_> = (0..12)
            .map(|_| {
                r.submit(Request {
                    id: 0,
                    task: "synth-math".into(),
                    prompt: "Q: 2+2=?".into(),
                    policy: "osdt:block:q1:0.75:0.2".into(),
                    slo_ms: None,
                })
            })
            .collect();
        let mut calibrated = 0usize;
        for p in pending {
            let resp = p.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            calibrated += usize::from(resp.calibrated);
        }
        assert_eq!(calibrated, 1, "single-flight violated across replicas");
        let fleet: u64 = coords
            .iter()
            .map(|c| c.metrics.counter_value("calibrations"))
            .sum();
        assert_eq!(fleet, 1);
        assert_eq!(registry.metrics().counter_value("calibrations_completed"), 1);
    }

    #[test]
    fn affinity_spills_under_load() {
        let r = Router::new(
            vec![replica(), replica()],
            RoutingPolicy::TaskAffinity { spill_margin: 0 },
        )
        .unwrap();
        let home = r.place(&req("synth-math", 0));
        // saturate the home replica's in-flight count artificially
        let held: Vec<_> = (0..3).map(|i| r.submit(req("synth-math", i))).collect();
        // with margin 0 and home loaded, the next placement must spill
        let spilled = r.place(&req("synth-math", 99));
        assert_ne!(spilled, home, "overloaded home must spill");
        for h in held {
            h.recv().unwrap();
        }
    }

    #[test]
    fn least_outstanding_prefers_idle() {
        let r = Router::new(
            vec![replica(), replica()],
            RoutingPolicy::LeastOutstanding,
        )
        .unwrap();
        let held = r.submit(req("synth-math", 0));
        let second = r.place(&req("synth-math", 1));
        assert_ne!(second, held.replica());
        held.recv().unwrap();
    }

    #[test]
    fn policy_parse() {
        assert_eq!(RoutingPolicy::parse("rr").unwrap(), RoutingPolicy::RoundRobin);
        assert!(matches!(
            RoutingPolicy::parse("task-affinity").unwrap(),
            RoutingPolicy::TaskAffinity { .. }
        ));
        assert!(RoutingPolicy::parse("warp").is_err());
    }

    #[test]
    fn empty_router_rejected() {
        assert!(Router::new(vec![], RoutingPolicy::RoundRobin).is_err());
    }
}
