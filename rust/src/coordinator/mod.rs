//! The serving coordinator: request queue, dynamic batcher, engine worker
//! threads, and OSDT calibration lifecycle management.
//!
//! Shape follows the vLLM-router pattern scaled to this model: a leader
//! (the [`Coordinator`]) owns a queue; N workers each own a full PJRT
//! runtime (the `xla` client is not `Sync`) and pull batches off the queue.
//!
//! OSDT's two-phase structure lives here (Algorithm 1 at serving level):
//! the **first request of a task** that asks for an OSDT policy is decoded
//! with the static calibration policy while its trace is recorded; the
//! resulting profile is stored in the shared [`ProfileStore`] cache and
//! every subsequent request of that task reuses it. Calibration is
//! per-(task, mode, metric) and happens at most once.

pub mod router;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheConfig;
use crate::config::parse_policy_spec;
use crate::decode::{DecodeResult, Engine, ForwardModel};
use crate::metrics::Registry;
use crate::model::ModelConfig;
use crate::policy::{Calibrator, Osdt, Policy, PolicySpec, Profile, StaticThreshold};
use crate::tokenizer::Tokenizer;

/// Calibration decode policy (Phase 1 uses Fast-dLLM's static default).
const CALIBRATION_TAU: f64 = 0.9;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub task: String,
    pub prompt: String,
    /// Policy spec string, e.g. "osdt:block:q1:0.75:0.2".
    pub policy: String,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub completion: String,
    pub steps: usize,
    pub full_passes: usize,
    pub window_passes: usize,
    pub latency_ms: f64,
    /// gen-region tokens per second of wall-clock decode time
    pub tokens_per_sec: f64,
    /// true iff this request performed the task's calibration run
    pub calibrated: bool,
    pub error: Option<String>,
}

impl Response {
    fn failure(id: u64, err: impl std::fmt::Display) -> Self {
        Response {
            id,
            completion: String::new(),
            steps: 0,
            full_passes: 0,
            window_passes: 0,
            latency_ms: 0.0,
            tokens_per_sec: 0.0,
            calibrated: false,
            error: Some(err.to_string()),
        }
    }
}

struct Job {
    req: Request,
    resp: Sender<Response>,
    enqueued: Instant,
}

/// Shared OSDT profile cache keyed by (task, mode, metric).
type ProfileKey = (String, &'static str, &'static str);
pub type SharedProfiles = Arc<Mutex<HashMap<ProfileKey, Profile>>>;

/// Coordinator options.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub batch_wait: Duration,
    pub cache: CacheConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(5),
            cache: CacheConfig::disabled(),
        }
    }
}

pub struct Coordinator {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
    pub profiles: SharedProfiles,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Spawn workers, each building its own forward model via `factory`.
    pub fn start<M, F>(cfg: CoordinatorConfig, model_cfg: ModelConfig, factory: F) -> Result<Self>
    where
        M: ForwardModel,
        F: Fn(usize) -> Result<M> + Send + Sync + Clone + 'static,
    {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Registry::new());
        let profiles: SharedProfiles = Arc::new(Mutex::new(HashMap::new()));
        let tok = Tokenizer::from_config(&model_cfg)?;

        let mut handles = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let profiles = profiles.clone();
            let factory = factory.clone();
            let model_cfg = model_cfg.clone();
            let tok = tok.clone();
            let ccfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("osdt-worker-{wid}"))
                    .spawn(move || {
                        let model = match factory(wid) {
                            Ok(m) => m,
                            Err(e) => {
                                log::error!("worker {wid}: model init failed: {e:#}");
                                return;
                            }
                        };
                        worker_loop(
                            wid, &model, &model_cfg, &tok, &ccfg, &rx, &metrics, &profiles,
                        );
                    })
                    .context("spawning worker")?,
            );
        }
        Ok(Coordinator {
            tx: Some(tx),
            handles,
            metrics,
            profiles,
            next_id: AtomicU64::new(1),
        })
    }

    /// Submit a request; returns the channel its response will arrive on.
    pub fn submit(&self, mut req: Request) -> Receiver<Response> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let (rtx, rrx) = channel();
        self.metrics.add("requests_submitted", 1);
        if let Some(tx) = &self.tx {
            if tx
                .send(Job { req, resp: rtx, enqueued: Instant::now() })
                .is_err()
            {
                // workers gone; receiver will see a closed channel
            }
        }
        rrx
    }

    /// Convenience: submit and block for the response.
    pub fn generate(&self, task: &str, prompt: &str, policy: &str) -> Result<Response> {
        let rx = self.submit(Request {
            id: 0,
            task: task.into(),
            prompt: prompt.into(),
            policy: policy.into(),
        });
        rx.recv().context("coordinator dropped the request")
    }

    /// Graceful shutdown: close the queue, join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // closes the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build the policy for a request, running calibration if needed.
/// Returns (policy, calibrated_now).
fn resolve_policy<M: ForwardModel>(
    spec: &PolicySpec,
    task: &str,
    engine: &Engine<'_, M>,
    tok: &Tokenizer,
    model_cfg: &ModelConfig,
    prompt: &str,
    profiles: &SharedProfiles,
) -> Result<(Box<dyn Policy>, Option<DecodeResult>)> {
    match spec {
        PolicySpec::Osdt { mode, metric, kappa, epsilon } => {
            let key = (task.to_string(), mode.as_str(), metric.as_str());
            if let Some(p) = profiles.lock().unwrap().get(&key).cloned() {
                return Ok((Box::new(Osdt::from_profile(p, *kappa, *epsilon)), None));
            }
            // Phase 1: calibrate on THIS sequence with the static policy
            let layout = tok.layout_prompt(model_cfg, prompt)?;
            let cal = engine.decode(layout, &StaticThreshold::new(CALIBRATION_TAU))?;
            let profile = Calibrator::calibrate(&cal.trace, *mode, *metric);
            profiles
                .lock()
                .unwrap()
                .insert(key, profile.clone());
            Ok((
                Box::new(Osdt::from_profile(profile, *kappa, *epsilon)),
                Some(cal),
            ))
        }
        other => Ok((other.build()?, None)),
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<M: ForwardModel>(
    wid: usize,
    model: &M,
    model_cfg: &ModelConfig,
    tok: &Tokenizer,
    cfg: &CoordinatorConfig,
    rx: &Arc<Mutex<Receiver<Job>>>,
    metrics: &Arc<Registry>,
    profiles: &SharedProfiles,
) {
    let engine = Engine::with_cache(model, cfg.cache);
    log::info!("worker {wid} ready (cache={:?})", cfg.cache);
    loop {
        // ---- gather a batch -------------------------------------------------
        let first = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => j,
                Err(_) => break, // queue closed
            }
        };
        let mut jobs = vec![first];
        // batching only helps the uncached path (cached decode is batch-1).
        // NOTE: the gather must use try_lock — an idle sibling worker parks
        // inside `recv()` *holding* the shared-receiver mutex, so a blocking
        // lock here deadlocks until the next request arrives.
        if !cfg.cache.enabled {
            let deadline = Instant::now() + cfg.batch_wait;
            while jobs.len() < cfg.max_batch.min(model.max_batch()) {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match rx.try_lock() {
                    Ok(guard) => match guard.recv_timeout(remaining) {
                        Ok(j) => jobs.push(j),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    },
                    Err(std::sync::TryLockError::WouldBlock) => {
                        // a sibling holds the queue; it will take the next
                        // job anyway — stop gathering and decode what we have
                        break;
                    }
                    Err(std::sync::TryLockError::Poisoned(_)) => break,
                }
            }
        }
        metrics.set_gauge("last_batch_size", jobs.len() as i64);

        // ---- resolve policies / layouts; split off failures & calibrations --
        let mut ready: Vec<(Job, Vec<u32>, Box<dyn Policy>)> = Vec::new();
        for job in jobs {
            metrics.observe_us(
                "queue_wait",
                job.enqueued.elapsed().as_secs_f64() * 1e6,
            );
            let t0 = Instant::now();
            let spec = match parse_policy_spec(&job.req.policy) {
                Ok(s) => s,
                Err(e) => {
                    metrics.add("requests_failed", 1);
                    let _ = job.resp.send(Response::failure(job.req.id, e));
                    continue;
                }
            };
            match resolve_policy(
                &spec, &job.req.task, &engine, tok, model_cfg, &job.req.prompt, profiles,
            ) {
                Err(e) => {
                    metrics.add("requests_failed", 1);
                    let _ = job.resp.send(Response::failure(job.req.id, format!("{e:#}")));
                }
                Ok((_, Some(cal))) => {
                    // calibration run doubles as this request's decode
                    metrics.add("calibrations", 1);
                    let resp =
                        make_response(&job.req, &cal, t0, model_cfg, tok, true);
                    record_metrics(metrics, &resp, model_cfg);
                    let _ = job.resp.send(resp);
                }
                Ok((policy, None)) => match tok.layout_prompt(model_cfg, &job.req.prompt) {
                    Ok(layout) => ready.push((job, layout, policy)),
                    Err(e) => {
                        metrics.add("requests_failed", 1);
                        let _ = job
                            .resp
                            .send(Response::failure(job.req.id, format!("{e:#}")));
                    }
                },
            }
        }
        if ready.is_empty() {
            continue;
        }

        // ---- decode ---------------------------------------------------------
        let t0 = Instant::now();
        if cfg.cache.enabled || ready.len() == 1 {
            for (job, layout, policy) in ready {
                let t1 = Instant::now();
                match engine.decode(layout, policy.as_ref()) {
                    Ok(res) => {
                        let resp =
                            make_response(&job.req, &res, t1, model_cfg, tok, false);
                        record_metrics(metrics, &resp, model_cfg);
                        let _ = job.resp.send(resp);
                    }
                    Err(e) => {
                        metrics.add("requests_failed", 1);
                        let _ = job
                            .resp
                            .send(Response::failure(job.req.id, format!("{e:#}")));
                    }
                }
            }
        } else {
            let layouts: Vec<Vec<u32>> =
                ready.iter().map(|(_, l, _)| l.clone()).collect();
            let policies: Vec<&dyn Policy> =
                ready.iter().map(|(_, _, p)| p.as_ref()).collect();
            match engine.decode_batch(layouts, &policies) {
                Ok(results) => {
                    for ((job, _, _), res) in ready.into_iter().zip(results) {
                        let resp = make_response(&job.req, &res, t0, model_cfg, tok, false);
                        record_metrics(metrics, &resp, model_cfg);
                        let _ = job.resp.send(resp);
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for (job, _, _) in ready {
                        metrics.add("requests_failed", 1);
                        let _ = job.resp.send(Response::failure(job.req.id, &msg));
                    }
                }
            }
        }
    }
    log::info!("worker {wid} exiting");
}

fn make_response(
    req: &Request,
    res: &DecodeResult,
    started: Instant,
    cfg: &ModelConfig,
    tok: &Tokenizer,
    calibrated: bool,
) -> Response {
    let latency = started.elapsed().as_secs_f64();
    Response {
        id: req.id,
        completion: tok.decode_until_eos(res.gen_tokens(cfg)),
        steps: res.steps,
        full_passes: res.full_passes,
        window_passes: res.window_passes,
        latency_ms: latency * 1e3,
        tokens_per_sec: cfg.gen_len as f64 / latency.max(1e-9),
        calibrated,
        error: None,
    }
}

fn record_metrics(metrics: &Registry, resp: &Response, cfg: &ModelConfig) {
    metrics.add("requests_completed", 1);
    metrics.add("tokens_generated", cfg.gen_len as u64);
    metrics.add("decode_steps", resp.steps as u64);
    metrics.observe_us("request_latency", resp.latency_ms * 1e3);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;
    use crate::sim::SimModel;

    fn start_sim(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::start(cfg, tiny_config(), |_wid| Ok(SimModel::math_like(5)))
            .unwrap()
    }

    #[test]
    fn serves_static_request() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.steps > 0);
        assert!(r.tokens_per_sec > 0.0);
        assert!(!r.calibrated);
        c.shutdown();
    }

    #[test]
    fn first_osdt_request_calibrates_then_reuses() {
        let c = start_sim(CoordinatorConfig::default());
        let spec = "osdt:block:q1:0.75:0.2";
        let r1 = c.generate("synth-math", "Q: 1+2=?", spec).unwrap();
        assert!(r1.calibrated, "first OSDT request must calibrate");
        let r2 = c.generate("synth-math", "Q: 3+4=?", spec).unwrap();
        assert!(!r2.calibrated, "profile must be reused");
        assert_eq!(c.metrics.counter_value("calibrations"), 1);
        // a different task calibrates separately
        let r3 = c.generate("synth-qa", "Q: class of x?", spec).unwrap();
        assert!(r3.calibrated);
        assert_eq!(c.metrics.counter_value("calibrations"), 2);
        c.shutdown();
    }

    #[test]
    fn bad_policy_returns_error_response() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 1+1=?", "warp:9").unwrap();
        assert!(r.error.is_some());
        assert_eq!(c.metrics.counter_value("requests_failed"), 1);
        c.shutdown();
    }

    #[test]
    fn oversized_prompt_fails_cleanly() {
        let c = start_sim(CoordinatorConfig::default());
        let long = "x".repeat(500);
        let r = c.generate("synth-math", &long, "static:0.9").unwrap();
        assert!(r.error.is_some());
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let c = Arc::new(start_sim(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        }));
        let mut rxs = Vec::new();
        for i in 0..16 {
            rxs.push(c.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: format!("Q: {i}+1=?"),
                policy: "static:0.85".into(),
            }));
        }
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(c.metrics.counter_value("requests_completed"), 16);
        Arc::try_unwrap(c).ok().map(Coordinator::shutdown);
    }

    #[test]
    fn cached_mode_serves() {
        let c = start_sim(CoordinatorConfig {
            cache: CacheConfig::block_boundary(),
            ..CoordinatorConfig::default()
        });
        let r = c.generate("synth-math", "Q: 5+5=?", "static:0.9").unwrap();
        assert!(r.error.is_none());
        assert!(r.window_passes > 0, "cache path must use window passes");
        c.shutdown();
    }

    #[test]
    fn sequential_policy_spec_works_end_to_end() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 2+2=?", "sequential:1").unwrap();
        assert_eq!(r.steps, tiny_config().gen_len);
        c.shutdown();
    }
}
