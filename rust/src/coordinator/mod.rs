//! The serving coordinator: shared request queue, continuous-batching
//! worker loops, and OSDT calibration lifecycle management (DESIGN.md §6).
//!
//! Shape follows the vLLM-router pattern scaled to this model: a leader
//! (the [`Coordinator`]) owns a Condvar-backed [`JobQueue`] consumed in
//! predicted-cost order (aged shortest-predicted-job-first, DESIGN.md §15;
//! `CoordinatorConfig::predictive = false` restores plain FIFO); N workers
//! each own a full PJRT runtime (the `xla` client is not `Sync`) and drive
//! a [`StepScheduler`]. Requests are admitted into a worker's scheduler at
//! any step boundary, share forward passes with whatever is already
//! decoding — KV cache on or off — and retire the moment they finish. This
//! replaces the old lockstep gather (an `Arc<Mutex<Receiver>>` shared
//! between workers, with a documented try_lock dance to avoid deadlocking
//! on an idle sibling parked inside `recv()` holding the mutex).
//!
//! OSDT's two-phase structure (Algorithm 1 at serving level) runs against
//! the fleet-wide [`ProfileRegistry`] (DESIGN.md §9): the **first request
//! of a task** that asks for an OSDT policy takes the registry's
//! calibration lease, is decoded with the static calibration policy while
//! its trace is recorded, and fulfills the lease; every subsequent request
//! — on this worker, a sibling worker, or another replica sharing the
//! registry — reuses the profile. Peers that arrive while the lease is in
//! flight are parked (co-scheduled around the calibration) rather than
//! calibrating redundantly: calibration is per-(task, mode, metric) and
//! happens at most once across the fleet, by construction. Every completed
//! OSDT decode is folded back into the registry for signature-drift
//! detection and optional EMA refinement.
//!
//! Worker-loop metrics: `queue_depth` (gauge), `batch_occupancy` (gauge +
//! unitless histogram, with a `batch_occupancy_peak` high-water gauge),
//! `admission_wait` (histogram, enqueue → scheduler admission), the
//! `scheduler_steps` / `scheduled_seq_steps` counters whose ratio is the
//! mean occupancy, the `full_passes` / `window_passes` /
//! `fused_window_passes` pass-mix counters (fused ÷ window = the fraction
//! of steady-state steps whose decision ran on device, DESIGN.md §11),
//! and the `accepted_per_step` histogram of tokens committed per sequence
//! step. The `ttft` histogram anchors on the step reports: a sequence's
//! first step with a non-zero commit count marks its time-to-first-token
//! (enqueue → that step). `calibrations_deferred` counts local calibrations
//! parked to protect co-scheduled peers; `calibrations_awaited` counts
//! requests parked behind a peer's in-flight calibration lease. Workers
//! with a stats-reporting model (the PJRT runtime) additionally publish
//! transfer accounting deltas every iteration — `bytes_{up,down}loaded`,
//! `cache_bytes_{up,down}loaded`, `model_{exec,transfer}_us` — the
//! counters `serving_load` turns into bytes-per-token (DESIGN.md §10).
//!
//! Predictive scheduling (DESIGN.md §15): every submitted request is
//! stamped with a [`StepForecast`] from the task's calibrated acceptance
//! trajectory (worst-case prior while calibration is pending). The forecast
//! drives queue ordering, the scheduler's alignment-aware grouping, the
//! `predicted_backlog` gauge (queued + in-flight predicted passes), and the
//! `--shed-watermark` / `--slo-ms` guardrails — requests predicted to blow
//! the budget are rejected **at admission only** with a forecast-derived
//! `retry_after_ms`; in-flight decodes are never cancelled. Forecast
//! accuracy is tracked per retirement (`forecast_error`,
//! `group_alignment_drag` histograms).

pub mod router;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cache::CacheConfig;
use crate::config::parse_policy_spec;
use crate::decode::{
    CostModel, DecodeResult, Engine, ForwardModel, StepForecast, StepScheduler,
};
use crate::metrics::Registry;
use crate::model::ModelConfig;
use crate::policy::{
    Acquired, Calibrator, HostTraced, Osdt, PeekState, Policy, PolicySpec,
    ProfileKey, ProfileRegistry, StaticThreshold,
};
use crate::runtime::RuntimeStats;
use crate::tokenizer::Tokenizer;

/// Calibration decode policy (Phase 1 uses Fast-dLLM's static default).
const CALIBRATION_TAU: f64 = 0.9;

/// How long an idle worker parks on the queue before re-checking.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// How long a calibration-triggering request may be parked while the
/// worker is busy before it is run anyway (stalling co-scheduled peers).
const CALIBRATION_DEFER_MAX: Duration = Duration::from_millis(500);

/// How long a request parked behind a *peer's* in-flight calibration lease
/// waits before stealing the lease and calibrating itself — the liveness
/// bound against a stuck or lost calibrator.
const CALIBRATION_STEAL_MAX: Duration = Duration::from_secs(5);

/// Aged-SPJF aging rate: each second a job waits shrinks its effective
/// predicted cost by this many passes, so a long job's priority overtakes
/// any fresh short job within (cost / rate) seconds — the starvation bound.
const AGING_PASSES_PER_SEC: f64 = 50.0;

/// Prior for the observed wall-milliseconds-per-pass EMA before any decode
/// has retired; keeps `retry_after_ms` finite from the first shed.
const DEFAULT_PASS_MS: f64 = 2.0;

/// Blend rate for the milliseconds-per-pass EMA (per retired decode).
const PASS_EMA_ALPHA: f64 = 0.2;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub task: String,
    pub prompt: String,
    /// Policy spec string, e.g. "osdt:block:q1:0.75:0.2".
    pub policy: String,
    /// Deadline budget, milliseconds (DESIGN.md §15). A request whose
    /// forecast-predicted completion exceeds its budget is shed at
    /// admission with an honest `retry_after_ms` instead of decoding past
    /// its deadline. `None` inherits the server default (`--slo-ms`).
    pub slo_ms: Option<f64>,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub completion: String,
    pub steps: usize,
    pub full_passes: usize,
    pub window_passes: usize,
    pub latency_ms: f64,
    /// gen-region tokens per second of wall-clock decode time
    pub tokens_per_sec: f64,
    /// true iff this request performed the task's calibration run
    pub calibrated: bool,
    /// enqueue → first committed token, milliseconds. Calibration
    /// responses report their full decode latency here (the calibration
    /// decode runs inline, outside the scheduler), an honest upper bound.
    pub ttft_ms: f64,
    pub error: Option<String>,
    /// Set only on shed responses: forecast-derived retry hint,
    /// milliseconds. Always finite and positive (DESIGN.md §15).
    pub retry_after_ms: Option<f64>,
}

impl Response {
    fn failure(id: u64, err: impl std::fmt::Display) -> Self {
        Response {
            id,
            completion: String::new(),
            steps: 0,
            full_passes: 0,
            window_passes: 0,
            latency_ms: 0.0,
            tokens_per_sec: 0.0,
            calibrated: false,
            ttft_ms: 0.0,
            error: Some(err.to_string()),
            retry_after_ms: None,
        }
    }

    /// An admission-time rejection under the shedding guardrails. Only ever
    /// built before the request enters the queue — an in-flight decode is
    /// never cancelled into one of these.
    pub(crate) fn shed(id: u64, retry_after_ms: f64, reason: impl std::fmt::Display) -> Self {
        Response { retry_after_ms: Some(retry_after_ms), ..Self::failure(id, reason) }
    }
}

struct Job {
    req: Request,
    resp: Sender<Response>,
    enqueued: Instant,
    /// Stamped at submit from the task's profile (or the worst-case
    /// prior): queue priority, backlog accounting, and the scheduler's
    /// alignment signal all read this one forecast.
    forecast: StepForecast,
}

/// Coordinator options.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// Per-worker continuous-batching slot count (clamped to the model's
    /// compiled max batch).
    pub max_batch: usize,
    /// How long an idle worker holds its first job to let concurrent
    /// arrivals join the same first step. Later arrivals join mid-flight at
    /// step boundaries regardless.
    pub batch_wait: Duration,
    pub cache: CacheConfig,
    /// How long a request parked behind a *peer's* in-flight calibration
    /// lease waits before stealing it — the liveness bound against a stuck
    /// or lost calibrator. The chaos tests shrink this to force steal
    /// churn quickly.
    pub steal_after: Duration,
    /// Profile-guided step elision (DESIGN.md §14): Ready OSDT policies
    /// skip window passes their calibration trajectory predicts empty.
    /// Off by default; calibration decodes (HostTraced) and non-OSDT
    /// policies are never eligible regardless.
    pub step_elision: bool,
    /// Acceptance floor below which a calibrated step counts as empty for
    /// elision (`--elide-floor`). The default classifies exactly the
    /// fallback-only steps.
    pub elide_floor: f64,
    /// Consume the queue in aged shortest-predicted-job-first order
    /// (DESIGN.md §15). `false` restores plain FIFO — the bench A/B arm.
    pub predictive: bool,
    /// Alignment band for the scheduler's co-scheduling preference
    /// (`--align-band`): prefer promoting waiting rows whose predicted
    /// remaining passes are within this distance of the active group's
    /// soonest-retiring row. 0 disables (plain FIFO promotion).
    pub align_band: usize,
    /// Predicted-backlog watermark in forward passes (`--shed-watermark`):
    /// a request whose forecast would push the backlog past it is shed at
    /// admission with a forecast-derived `retry_after_ms`. 0 disables.
    pub shed_watermark: usize,
    /// Default per-request deadline budget, milliseconds (`--slo-ms`),
    /// applied when a request carries no `slo_ms` of its own. 0 disables.
    pub slo_ms: f64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(5),
            cache: CacheConfig::disabled(),
            steal_after: CALIBRATION_STEAL_MAX,
            step_elision: false,
            elide_floor: crate::policy::DEFAULT_ELIDE_FLOOR,
            predictive: true,
            align_band: 0,
            shed_watermark: 0,
            slo_ms: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// Job queue
// ---------------------------------------------------------------------------

struct QueueInner {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl QueueInner {
    /// Take the next job. FIFO, or — predictive — the minimum *effective*
    /// cost (forecast passes minus the [`AGING_PASSES_PER_SEC`] wait-time
    /// credit, so long jobs age to the front instead of starving). The
    /// scan is strictly-less so equal priorities keep FIFO order
    /// (`Iterator::min_by` would keep the *last* minimum).
    fn take(&mut self, predictive: bool) -> Option<Job> {
        if !predictive || self.jobs.len() <= 1 {
            return self.jobs.pop_front();
        }
        let now = Instant::now();
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, job) in self.jobs.iter().enumerate() {
            let age = now.saturating_duration_since(job.enqueued).as_secs_f64();
            let score = job.forecast.total_passes as f64 - age * AGING_PASSES_PER_SEC;
            if score < best_score {
                best = i;
                best_score = score;
            }
        }
        self.jobs.remove(best)
    }
}

/// Multi-consumer job queue (Mutex + Condvar) with predicted-cost priority
/// consumption and forecast-backlog accounting. Closing wakes every
/// waiter; queued jobs are still drained after close so shutdown is
/// graceful.
struct JobQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    /// Aged-SPJF consumption when set; plain FIFO otherwise.
    predictive: bool,
    /// Predicted passes of jobs admitted into a scheduler and not yet
    /// retired — the in-flight half of the `predicted_backlog` gauge.
    active_forecast: AtomicI64,
    /// EMA of observed wall-milliseconds per forward pass (f64 bits),
    /// seeded with [`DEFAULT_PASS_MS`] so `retry_after_ms` is finite from
    /// the first shed.
    pass_ms_bits: AtomicU64,
}

enum Popped {
    Job(Box<Job>),
    Empty,
    Closed,
}

impl JobQueue {
    fn new(predictive: bool) -> Self {
        JobQueue {
            inner: Mutex::new(QueueInner { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            predictive,
            active_forecast: AtomicI64::new(0),
            pass_ms_bits: AtomicU64::new(DEFAULT_PASS_MS.to_bits()),
        }
    }

    /// Enqueue; returns false (dropping nothing but the caller's hope) if
    /// the queue is closed.
    fn push(&self, job: Job) -> bool {
        {
            let mut g = self.inner.lock().unwrap();
            if g.closed {
                return false;
            }
            g.jobs.push_back(job);
        }
        self.cv.notify_one();
        true
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Non-blocking pop. `Closed` only once the queue is both closed and
    /// drained.
    fn try_pop(&self) -> Popped {
        let mut g = self.inner.lock().unwrap();
        match g.take(self.predictive) {
            Some(j) => Popped::Job(Box::new(j)),
            None if g.closed => Popped::Closed,
            None => Popped::Empty,
        }
    }

    /// Blocking pop with a deadline.
    fn pop_timeout(&self, timeout: Duration) -> Popped {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(j) = g.take(self.predictive) {
                return Popped::Job(Box::new(j));
            }
            if g.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::Empty;
            }
            let (guard, _timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Queue depth and predicted backlog (queued + in-flight forecast
    /// passes) in one snapshot, for [`publish_queue_gauges`].
    fn load_stats(&self) -> (usize, i64) {
        let (depth, queued) = {
            let g = self.inner.lock().unwrap();
            let queued: i64 =
                g.jobs.iter().map(|j| j.forecast.total_passes as i64).sum();
            (g.jobs.len(), queued)
        };
        let backlog = queued + self.active_forecast.load(Ordering::Relaxed);
        (depth, backlog.max(0))
    }

    fn predicted_backlog(&self) -> i64 {
        self.load_stats().1
    }

    /// Fold a job's predicted passes into (positive, at scheduler
    /// admission) or out of (negative, at retirement/failure) the
    /// in-flight backlog.
    fn note_active(&self, delta: i64) {
        self.active_forecast.fetch_add(delta, Ordering::Relaxed);
    }

    fn pass_ms(&self) -> f64 {
        f64::from_bits(self.pass_ms_bits.load(Ordering::Relaxed))
    }

    /// Fold one retired decode's observed milliseconds-per-pass into the
    /// EMA behind `retry_after_ms`. Load-blend-store is racy across
    /// workers, but the EMA is a coarse hint and any interleaving still
    /// converges on the same scale.
    fn note_pass_ms(&self, ms: f64) {
        if !ms.is_finite() || ms <= 0.0 {
            return;
        }
        let blended = self.pass_ms() * (1.0 - PASS_EMA_ALPHA) + ms * PASS_EMA_ALPHA;
        self.pass_ms_bits.store(blended.to_bits(), Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

pub struct Coordinator {
    queue: Arc<JobQueue>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Registry>,
    /// Calibration/profile state; share one instance across replicas for
    /// fleet-wide single-flight calibration.
    pub registry: Arc<ProfileRegistry>,
    next_id: AtomicU64,
    /// Layout geometry for the admission-time cost model.
    model_cfg: ModelConfig,
    /// Forecasting rule (mirrors the worker's elision setting so forecasts
    /// walk the same predicted-empty jumps the planner will).
    cost_model: CostModel,
    /// `--shed-watermark` in forecast passes; 0 disables shedding.
    shed_watermark: usize,
    /// `--slo-ms` default deadline budget; 0 disables.
    slo_ms: f64,
}

impl Coordinator {
    /// Spawn workers with a private, ephemeral [`ProfileRegistry`] (single
    /// replica, no persistence). Fleets share state via
    /// [`Coordinator::start_with_registry`].
    pub fn start<M, F>(cfg: CoordinatorConfig, model_cfg: ModelConfig, factory: F) -> Result<Self>
    where
        M: ForwardModel + 'static,
        F: Fn(usize) -> Result<M> + Send + Sync + Clone + 'static,
    {
        Self::start_with_registry(
            cfg,
            model_cfg,
            Arc::new(ProfileRegistry::in_memory()),
            factory,
        )
    }

    /// Spawn workers, each building its own forward model via `factory`,
    /// all resolving profiles through `registry`.
    pub fn start_with_registry<M, F>(
        cfg: CoordinatorConfig,
        model_cfg: ModelConfig,
        registry: Arc<ProfileRegistry>,
        factory: F,
    ) -> Result<Self>
    where
        M: ForwardModel + 'static,
        F: Fn(usize) -> Result<M> + Send + Sync + Clone + 'static,
    {
        let queue = Arc::new(JobQueue::new(cfg.predictive));
        let metrics = Arc::new(Registry::new());
        let tok = Tokenizer::from_config(&model_cfg)?;

        let mut handles = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let registry = registry.clone();
            let factory = factory.clone();
            let model_cfg = model_cfg.clone();
            let tok = tok.clone();
            let ccfg = cfg.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("osdt-worker-{wid}"))
                    .spawn(move || {
                        let model = match factory(wid) {
                            Ok(m) => m,
                            Err(e) => {
                                log::error!("worker {wid}: model init failed: {e:#}");
                                return;
                            }
                        };
                        worker_loop(
                            wid, &model, &model_cfg, &tok, &ccfg, &queue, &metrics,
                            &registry,
                        );
                    })
                    .context("spawning worker")?,
            );
        }
        let elision = cfg.step_elision.then_some(cfg.elide_floor);
        Ok(Coordinator {
            queue,
            handles,
            metrics,
            registry,
            next_id: AtomicU64::new(1),
            model_cfg,
            cost_model: CostModel::new(elision),
            shed_watermark: cfg.shed_watermark,
            slo_ms: cfg.slo_ms,
        })
    }

    /// Submit a request; returns the channel its response will arrive on.
    ///
    /// The request is forecast here (DESIGN.md §15): the predicted pass
    /// count drives queue ordering, the `predicted_backlog` gauge, and —
    /// when `--shed-watermark` / `--slo-ms` are set — the shedding
    /// decision. Shedding only ever happens at this point, before any work
    /// starts; an in-flight decode is never cancelled.
    pub fn submit(&self, mut req: Request) -> Receiver<Response> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        if req.slo_ms.is_none() && self.slo_ms > 0.0 {
            req.slo_ms = Some(self.slo_ms);
        }
        let (rtx, rrx) = channel();
        self.metrics.add("requests_submitted", 1);
        let forecast = self.forecast(&req);
        self.metrics
            .observe("predicted_steps", forecast.total_passes as f64);
        if let Some((retry_after_ms, reason)) = self.shed_check(&req, &forecast) {
            self.metrics.add("requests_shed", 1);
            let _ = rtx.send(Response::shed(req.id, retry_after_ms, reason));
            return rrx;
        }
        if self
            .queue
            .push(Job { req, resp: rtx, enqueued: Instant::now(), forecast })
        {
            publish_queue_gauges(&self.metrics, &self.queue);
        }
        // if the queue is closed the sender was dropped and the receiver
        // observes a closed channel
        rrx
    }

    /// Stamp a forecast for `req`: the task's calibrated profile when its
    /// policy is OSDT and the profile is registered, otherwise the
    /// layout-derived worst-case prior (calibration pending, or a policy
    /// with no signature to forecast from).
    fn forecast(&self, req: &Request) -> StepForecast {
        let profile = match parse_policy_spec(&req.policy) {
            Ok(PolicySpec::Osdt { mode, metric, .. }) => self
                .registry
                .get(&ProfileKey::new(req.task.clone(), mode, metric))
                .map(|e| e.profile),
            _ => None,
        };
        self.cost_model.forecast(profile.as_ref(), &self.model_cfg)
    }

    /// Admission-time shedding decision: `Some((retry_after_ms, reason))`
    /// when the request should be rejected. The retry hint scales the
    /// predicted overload by the observed milliseconds-per-pass EMA, so it
    /// is always finite and tracks real decode speed.
    fn shed_check(&self, req: &Request, forecast: &StepForecast) -> Option<(f64, String)> {
        let backlog = self.queue.predicted_backlog().max(0) as usize;
        let cost = forecast.total_passes;
        let pass_ms = self.queue.pass_ms();
        if self.shed_watermark > 0 && backlog + cost > self.shed_watermark {
            let over = (backlog + cost - self.shed_watermark) as f64;
            return Some((
                (over * pass_ms).max(1.0),
                format!(
                    "shed: predicted backlog {backlog}+{cost} passes over \
                     watermark {}",
                    self.shed_watermark
                ),
            ));
        }
        let slo = req.slo_ms.filter(|&s| s > 0.0)?;
        let predicted_ms = (backlog + cost) as f64 * pass_ms;
        if predicted_ms > slo {
            return Some((
                (predicted_ms - slo).max(1.0),
                format!(
                    "shed: predicted completion {predicted_ms:.0}ms exceeds \
                     slo {slo:.0}ms"
                ),
            ));
        }
        None
    }

    /// Convenience: submit and block for the response.
    pub fn generate(&self, task: &str, prompt: &str, policy: &str) -> Result<Response> {
        let rx = self.submit(Request {
            id: 0,
            task: task.into(),
            prompt: prompt.into(),
            policy: policy.into(),
            slo_ms: None,
        });
        rx.recv().context("coordinator dropped the request")
    }

    /// Graceful shutdown: close the queue, join workers (queued jobs are
    /// still served first).
    pub fn shutdown(mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Worker loop
// ---------------------------------------------------------------------------

/// Outcome of resolving a request's policy against the registry.
enum Resolved {
    /// Ready to decode; the key + profile epoch are carried for
    /// post-decode observation (the epoch lets the registry drop
    /// observations from decodes that started before a recalibration).
    Policy(Box<dyn Policy>, Option<(ProfileKey, u64)>),
    /// This request held the calibration lease; its calibration decode
    /// doubles as its response.
    Calibrated(DecodeResult),
    /// A peer holds the calibration lease — park and retry later.
    Parked,
}

/// Build the policy for a request, running calibration under the
/// registry's lease if this request is the key's calibrator. With `steal`,
/// an in-flight peer lease is taken over instead of parking (the
/// [`CALIBRATION_STEAL_MAX`] escape hatch).
#[allow(clippy::too_many_arguments)]
fn resolve_policy<M: ForwardModel>(
    spec: &PolicySpec,
    task: &str,
    engine: &Engine<'_, M>,
    tok: &Tokenizer,
    model_cfg: &ModelConfig,
    prompt: &str,
    registry: &ProfileRegistry,
    steal: bool,
    elision: Option<f64>,
) -> Result<Resolved> {
    match spec {
        PolicySpec::Osdt { mode, metric, kappa, epsilon } => {
            let key = ProfileKey::new(task, *mode, *metric);
            let acquired = if steal {
                registry.acquire_stealing(&key)
            } else {
                registry.acquire(&key)
            };
            match acquired {
                Acquired::Ready(profile, epoch) => {
                    // Elision only applies to Phase-2 decodes: the profile's
                    // acceptance trajectory is what the planner consults, and
                    // the calibration decode below must execute every step to
                    // record that trajectory in the first place.
                    let mut policy = Osdt::from_profile(profile, *kappa, *epsilon);
                    if let Some(floor) = elision {
                        policy = policy.with_elision(floor);
                    }
                    Ok(Resolved::Policy(Box::new(policy), Some((key, epoch))))
                }
                Acquired::InFlight => Ok(Resolved::Parked),
                Acquired::Lease(lease) => {
                    // Phase 1: calibrate on THIS sequence with the static
                    // policy; an error drops the lease so a peer retries.
                    // HostTraced forces the full-download path — the
                    // calibrator's quantile metrics need complete per-step
                    // confidence vectors, which a fused decode never ships
                    let layout = tok.layout_prompt(model_cfg, prompt)?;
                    let cal = engine.decode(
                        layout,
                        &HostTraced(StaticThreshold::new(CALIBRATION_TAU)),
                    )?;
                    let profile = Calibrator::calibrate(&cal.trace, *mode, *metric);
                    lease.fulfill(profile, cal.trace.signature());
                    Ok(Resolved::Calibrated(cal))
                }
            }
        }
        other => Ok(Resolved::Policy(other.build()?, None)),
    }
}

/// A request admitted into the scheduler, awaiting retirement.
struct Inflight {
    job: Job,
    admitted: Instant,
    /// Set for OSDT requests: the profile key + epoch to observe
    /// (drift/EMA) when the decode retires.
    osdt_key: Option<(ProfileKey, u64)>,
    /// Filled by the first scheduler step that commits tokens for this
    /// sequence (enqueue → that step, milliseconds).
    ttft_ms: Option<f64>,
}

/// A request parked at admission (calibration in flight, or a local
/// calibration deferred to protect co-scheduled peers).
struct Parked {
    job: Job,
    since: Instant,
    /// The job's OSDT key, parsed once at park time so the per-iteration
    /// re-classification of parked jobs doesn't re-parse the policy spec.
    key: Option<ProfileKey>,
}

/// What admitting this job right now would mean for the scheduler.
enum AdmitClass {
    /// Decodes through the scheduler (or fails fast) — admit.
    Plain,
    /// Would run a Phase-1 calibration decode inline on this worker.
    Calibrate,
    /// Blocked behind a peer's in-flight calibration lease.
    WaitRemote,
}

/// The job's OSDT profile key, if its spec parses to an OSDT policy
/// (parse errors fail fast inside `admit_job`).
fn osdt_key(job: &Job) -> Option<ProfileKey> {
    match parse_policy_spec(&job.req.policy) {
        Ok(PolicySpec::Osdt { mode, metric, .. }) => {
            Some(ProfileKey::new(job.req.task.clone(), mode, metric))
        }
        _ => None,
    }
}

fn classify(key: Option<&ProfileKey>, registry: &ProfileRegistry) -> AdmitClass {
    match key {
        None => AdmitClass::Plain,
        Some(key) => match registry.peek(key) {
            PeekState::Ready => AdmitClass::Plain,
            PeekState::WouldCalibrate => AdmitClass::Calibrate,
            PeekState::InFlight => AdmitClass::WaitRemote,
        },
    }
}

enum Admitted {
    Scheduled,
    Responded,
    /// The registry told us to wait on a peer's calibration — hand the job
    /// back for parking.
    Parked(Job),
}

/// Parse + resolve one job and admit it into the scheduler. Requests that
/// fail, or whose calibration decode doubles as their response, are
/// answered immediately and never enter the scheduler.
#[allow(clippy::too_many_arguments)]
fn admit_job<M: ForwardModel>(
    job: Job,
    steal: bool,
    sched: &mut StepScheduler<'_, M, Box<dyn Policy>>,
    inflight: &mut HashMap<u64, Inflight>,
    next_seq: &mut u64,
    engine: &Engine<'_, M>,
    tok: &Tokenizer,
    model_cfg: &ModelConfig,
    metrics: &Registry,
    registry: &ProfileRegistry,
    elision: Option<f64>,
) -> Admitted {
    fn fail(metrics: &Registry, job: &Job, e: impl std::fmt::Display) {
        metrics.add("requests_failed", 1);
        let _ = job.resp.send(Response::failure(job.req.id, e));
    }
    let t0 = Instant::now();
    let spec = match parse_policy_spec(&job.req.policy) {
        Ok(s) => s,
        Err(e) => {
            fail(metrics, &job, e);
            return Admitted::Responded;
        }
    };
    let resolved = resolve_policy(
        &spec, &job.req.task, engine, tok, model_cfg, &job.req.prompt, registry,
        steal, elision,
    );
    if !matches!(resolved, Ok(Resolved::Parked)) {
        metrics.observe_us(
            "admission_wait",
            job.enqueued.elapsed().as_secs_f64() * 1e6,
        );
    }
    match resolved {
        Err(e) => {
            fail(metrics, &job, format!("{e:#}"));
            Admitted::Responded
        }
        Ok(Resolved::Parked) => Admitted::Parked(job),
        Ok(Resolved::Calibrated(cal)) => {
            // calibration run doubles as this request's decode
            metrics.add("calibrations", 1);
            let resp = make_response(&job.req, &cal, t0, model_cfg, tok, true, None);
            record_metrics(metrics, &resp, model_cfg);
            let _ = job.resp.send(resp);
            Admitted::Responded
        }
        Ok(Resolved::Policy(policy, osdt_key)) => {
            match tok.layout_prompt(model_cfg, &job.req.prompt) {
                Ok(layout) => {
                    let id = *next_seq;
                    *next_seq += 1;
                    let forecast = job.forecast.clone();
                    match sched.admit_with_forecast(id, layout, policy, Some(forecast)) {
                        Ok(()) => {
                            inflight.insert(
                                id,
                                Inflight {
                                    job,
                                    admitted: Instant::now(),
                                    osdt_key,
                                    ttft_ms: None,
                                },
                            );
                            Admitted::Scheduled
                        }
                        Err(e) => {
                            fail(metrics, &job, format!("{e:#}"));
                            Admitted::Responded
                        }
                    }
                }
                Err(e) => {
                    fail(metrics, &job, format!("{e:#}"));
                    Admitted::Responded
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop<M: ForwardModel>(
    wid: usize,
    model: &M,
    model_cfg: &ModelConfig,
    tok: &Tokenizer,
    cfg: &CoordinatorConfig,
    queue: &Arc<JobQueue>,
    metrics: &Arc<Registry>,
    registry: &Arc<ProfileRegistry>,
) {
    let engine = Engine::with_cache(model, cfg.cache);
    let mut sched = engine.scheduler::<Box<dyn Policy>>(cfg.max_batch);
    sched.set_align_band(cfg.align_band);
    if registry.config().ema_alpha > 0.0 {
        // registry-level EMA refinement (the fleet analog of
        // AdaptiveOsdt::observe) recalibrates from every decode's trace —
        // that needs full per-step confidence vectors, so this worker keeps
        // the host decision path for all policies
        sched.set_fusion(false);
    }
    let max_active = sched.max_active();
    // per-worker elision toggle, resolved once: Phase-2 OSDT policies built
    // by admit_job get the planner attached; calibration decodes never do
    let elision = if cfg.step_elision { Some(cfg.elide_floor) } else { None };
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    // parked requests: local calibrations deferred while the scheduler is
    // busy (they would stall co-scheduled peers), and requests waiting on a
    // peer's in-flight calibration lease; re-examined every loop iteration
    let mut deferred: VecDeque<Parked> = VecDeque::new();
    let mut next_seq: u64 = 0;
    // cumulative transfer/exec accounting snapshot (delta-published)
    let mut last_stats = model.runtime_stats().unwrap_or_default();
    log::info!(
        "worker {wid} ready (cache={:?}, slots={max_active})",
        cfg.cache
    );
    macro_rules! admit {
        ($job:expr, $since:expr, $steal:expr) => {{
            let job = $job;
            let cost = job.forecast.total_passes as i64;
            match admit_job(
                job, $steal, &mut sched, &mut inflight, &mut next_seq, &engine,
                tok, model_cfg, metrics, registry, elision,
            ) {
                // the in-flight half of the predicted-backlog gauge
                Admitted::Scheduled => queue.note_active(cost),
                Admitted::Responded => {}
                Admitted::Parked(job) => {
                    // lost the race to a peer's lease between classify and
                    // acquire — park behind it (keeping the original park
                    // time)
                    metrics.add("calibrations_awaited", 1);
                    let key = osdt_key(&job);
                    deferred.push_back(Parked { job, since: $since, key });
                }
            }
        }};
    }
    let mut lease_gen = registry.lease_release_generation();
    loop {
        // ---- parked jobs: run any that has become runnable ------------------
        // A parked job's class only changes when a lease resolves (the
        // registry's release generation bumps), a park deadline passes, or
        // the scheduler drains — busy iterations where none of that
        // happened skip the linear re-classification entirely.
        let gen = registry.lease_release_generation();
        let park_deadline = CALIBRATION_DEFER_MAX.min(cfg.steal_after);
        let rescan_due = !deferred.is_empty()
            && (gen != lease_gen
                || sched.is_idle()
                || deferred.iter().any(|p| p.since.elapsed() >= park_deadline));
        if rescan_due {
            lease_gen = gen;
            for _ in 0..deferred.len() {
                let p = deferred.pop_front().expect("len checked");
                let steal = p.since.elapsed() >= cfg.steal_after;
                match classify(p.key.as_ref(), registry) {
                    AdmitClass::Plain => admit!(p.job, p.since, false),
                    // local calibration: run once the worker drains, or after
                    // CALIBRATION_DEFER_MAX anyway rather than waiting forever
                    AdmitClass::Calibrate
                        if sched.is_idle()
                            || p.since.elapsed() > CALIBRATION_DEFER_MAX =>
                    {
                        admit!(p.job, p.since, false)
                    }
                    // a peer's lease outstanding past patience: steal it
                    AdmitClass::WaitRemote if steal => admit!(p.job, p.since, true),
                    _ => deferred.push_back(p),
                }
            }
        }

        // ---- admission: fill free slots at the step boundary ---------------
        if sched.is_idle() {
            match queue.pop_timeout(IDLE_POLL) {
                Popped::Closed => {
                    // serve parked jobs before exiting (stealing any stuck
                    // remote lease); scheduled work drains on later turns
                    while let Some(p) = deferred.pop_front() {
                        admit!(p.job, p.since, true);
                    }
                    if sched.is_idle() {
                        break;
                    }
                }
                Popped::Empty => continue,
                Popped::Job(job) => {
                    admit!(*job, Instant::now(), false);
                    // batching window: let concurrent arrivals share the
                    // first step instead of trailing one step behind
                    let deadline = Instant::now() + cfg.batch_wait;
                    while sched.scheduled_len() < max_active {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match queue.pop_timeout(left) {
                            Popped::Job(job) => {
                                let key = osdt_key(&job);
                                match classify(key.as_ref(), registry) {
                                    AdmitClass::Plain => {
                                        admit!(*job, Instant::now(), false)
                                    }
                                    // a calibration would stall the peers
                                    // already admitted this window — park it
                                    // (unless the window is still empty)
                                    AdmitClass::Calibrate if sched.is_idle() => {
                                        admit!(*job, Instant::now(), false)
                                    }
                                    class => {
                                        park(metrics, &class, &mut deferred, *job, key);
                                    }
                                }
                            }
                            _ => break,
                        }
                    }
                }
            }
        } else {
            while sched.scheduled_len() < max_active {
                match queue.try_pop() {
                    Popped::Job(job) => {
                        let key = osdt_key(&job);
                        match classify(key.as_ref(), registry) {
                            AdmitClass::Plain => admit!(*job, Instant::now(), false),
                            class => park(metrics, &class, &mut deferred, *job, key),
                        }
                    }
                    _ => break,
                }
            }
        }
        publish_queue_gauges(metrics, queue);
        if sched.is_idle() {
            // calibration decodes run inline at admission — fold their
            // transfer accounting in even though no step will run
            publish_model_stats(metrics, model, &mut last_stats);
            continue; // admissions failed, parked, or served by calibration
        }

        // ---- one scheduler step: every active sequence advances ------------
        match sched.step() {
            Ok(report) => {
                if report.occupancy > 0 {
                    metrics.add("scheduler_steps", 1);
                    metrics.add("scheduled_seq_steps", report.occupancy as u64);
                    metrics.set_gauge("batch_occupancy", report.occupancy as i64);
                    metrics.max_gauge("batch_occupancy_peak", report.occupancy as i64);
                    metrics.observe("batch_occupancy", report.occupancy as f64);
                    metrics.add("full_passes", report.full_passes as u64);
                    metrics.add("window_passes", report.window_passes as u64);
                    metrics.add(
                        "fused_window_passes",
                        report.fused_window_passes as u64,
                    );
                    // paged-pool + bucketing observability (DESIGN.md §13)
                    metrics.add(
                        "prefix_sharing_saved_full_passes",
                        report.saved_full_passes as u64,
                    );
                    metrics.add("kv_page_reuse", report.pages_reused as u64);
                    metrics.add(
                        "window_padding_rows",
                        report.padding_rows as u64,
                    );
                    metrics.set_gauge(
                        "kv_pages_in_use",
                        report.kv_pages_in_use as i64,
                    );
                    // profile-guided step elision observability (DESIGN.md §14)
                    metrics.add("steps_elided", report.steps_elided as u64);
                    metrics.add(
                        "elision_mispredictions",
                        report.elision_mispredictions as u64,
                    );
                    metrics.add(
                        "blocks_retired_early",
                        report.blocks_retired_early as u64,
                    );
                    metrics.add(
                        "prefix_sharing_skipped_device",
                        report.prefix_sharing_skipped_device as u64,
                    );
                    for &(live, _bucket) in &report.window_groups {
                        metrics.observe("window_bucket_occupancy", live as f64);
                    }
                    // predicted-remaining spread of each co-scheduled group
                    // (DESIGN.md §15): high drag means stragglers padded
                    // through passes their groupmates didn't need
                    for &drag in &report.alignment_drag {
                        metrics.observe("group_alignment_drag", drag as f64);
                    }
                    for &(id, n) in &report.accepted {
                        metrics.observe("accepted_per_step", n as f64);
                        if n == 0 {
                            continue;
                        }
                        if let Some(inf) = inflight.get_mut(&id) {
                            if inf.ttft_ms.is_none() {
                                let ms =
                                    inf.job.enqueued.elapsed().as_secs_f64() * 1e3;
                                inf.ttft_ms = Some(ms);
                                metrics.observe_us("ttft", ms * 1e3);
                            }
                        }
                    }
                }
                for (id, res) in report.retired {
                    let Some(inf) = inflight.remove(&id) else {
                        log::warn!("worker {wid}: retired unknown sequence {id}");
                        continue;
                    };
                    // settle the forecast: release its backlog share, score
                    // its accuracy, and refine the ms-per-pass EMA behind
                    // retry_after_ms
                    let predicted = inf.job.forecast.total_passes;
                    queue.note_active(-(predicted as i64));
                    let actual = (res.full_passes + res.window_passes) as f64;
                    metrics.observe(
                        "forecast_error",
                        (predicted as f64 - actual).abs(),
                    );
                    queue.note_pass_ms(
                        inf.admitted.elapsed().as_secs_f64() * 1e3 / actual.max(1.0),
                    );
                    // fold the decode back into the registry: drift
                    // detection + optional EMA refinement
                    if let Some((key, epoch)) = &inf.osdt_key {
                        registry.observe(key, *epoch, &res.trace);
                        // mispredicted elisions are drift evidence the trace
                        // alone can't show (the skipped steps were never
                        // executed): feed them to the registry so a storm
                        // marks the profile stale and forces recalibration
                        if res.elision_mispredictions > 0 {
                            registry.note_elision_mispredictions(
                                key,
                                *epoch,
                                res.elision_mispredictions as u64,
                            );
                        }
                    }
                    let resp = make_response(
                        &inf.job.req, &res, inf.admitted, model_cfg, tok, false,
                        inf.ttft_ms,
                    );
                    record_metrics(metrics, &resp, model_cfg);
                    let _ = inf.job.resp.send(resp);
                }
                if sched.is_idle() {
                    // don't leave a phantom occupancy on the gauge once the
                    // worker drains (peak + histogram keep the history), and
                    // settle the backlog gauge the retirements just reduced
                    metrics.set_gauge("batch_occupancy", 0);
                    publish_queue_gauges(metrics, queue);
                }
            }
            Err(e) => {
                // a failed forward pass poisons every scheduled sequence:
                // fail them all and restart from an empty scheduler
                let msg = format!("{e:#}");
                log::error!("worker {wid}: scheduler step failed: {msg}");
                metrics.add("scheduler_step_failures", 1);
                for (_, inf) in inflight.drain() {
                    queue.note_active(-(inf.job.forecast.total_passes as i64));
                    metrics.add("requests_failed", 1);
                    let _ = inf.job.resp.send(Response::failure(inf.job.req.id, &msg));
                }
                let fusion = sched.fusion();
                sched = engine.scheduler::<Box<dyn Policy>>(max_active);
                sched.set_fusion(fusion);
                sched.set_align_band(cfg.align_band);
                metrics.set_gauge("batch_occupancy", 0);
            }
        }
        publish_model_stats(metrics, model, &mut last_stats);
    }
    publish_model_stats(metrics, model, &mut last_stats);
    log::info!("worker {wid} exiting");
}

/// Fold the model's cumulative transfer/exec accounting into the serving
/// metrics as deltas since the last publish. `cache_bytes_uploaded` is the
/// device-residency acceptance counter: it stays flat when no per-step
/// host K/V round trip happens. No-op for backends without stats (sim).
fn publish_model_stats<M: ForwardModel>(
    metrics: &Registry,
    model: &M,
    last: &mut RuntimeStats,
) {
    let Some(now) = model.runtime_stats() else { return };
    let d = |a: u64, b: u64| a.saturating_sub(b);
    metrics.add("model_exec_us", d(now.exec_micros(), last.exec_micros()));
    metrics.add("model_transfer_us", d(now.transfer_micros(), last.transfer_micros()));
    metrics.add("bytes_uploaded", d(now.upload_bytes(), last.upload_bytes()));
    metrics.add("bytes_downloaded", d(now.download_bytes(), last.download_bytes()));
    metrics.add(
        "cache_bytes_uploaded",
        d(now.cache_upload_bytes, last.cache_upload_bytes),
    );
    metrics.add(
        "cache_bytes_downloaded",
        d(now.cache_download_bytes, last.cache_download_bytes),
    );
    *last = now;
}

/// The one place both queue gauges are published (submit + worker loop):
/// `queue_depth` and its §15 companion `predicted_backlog` move together
/// by construction instead of drifting apart from independent call sites.
fn publish_queue_gauges(metrics: &Registry, queue: &JobQueue) {
    let (depth, backlog) = queue.load_stats();
    metrics.set_gauge("queue_depth", depth as i64);
    metrics.set_gauge("predicted_backlog", backlog);
}

/// Park a job that cannot be admitted right now, counting why.
fn park(
    metrics: &Registry,
    class: &AdmitClass,
    deferred: &mut VecDeque<Parked>,
    job: Job,
    key: Option<ProfileKey>,
) {
    match class {
        AdmitClass::Calibrate => metrics.add("calibrations_deferred", 1),
        AdmitClass::WaitRemote => metrics.add("calibrations_awaited", 1),
        AdmitClass::Plain => {}
    }
    deferred.push_back(Parked { job, since: Instant::now(), key });
}

fn make_response(
    req: &Request,
    res: &DecodeResult,
    started: Instant,
    cfg: &ModelConfig,
    tok: &Tokenizer,
    calibrated: bool,
    ttft_ms: Option<f64>,
) -> Response {
    let latency = started.elapsed().as_secs_f64();
    Response {
        id: req.id,
        completion: tok.decode_until_eos(res.gen_tokens(cfg)),
        steps: res.steps,
        full_passes: res.full_passes,
        window_passes: res.window_passes,
        latency_ms: latency * 1e3,
        tokens_per_sec: cfg.gen_len as f64 / latency.max(1e-9),
        calibrated,
        // calibration decodes run inline, outside the scheduler: their
        // whole latency stands in for TTFT (an honest upper bound)
        ttft_ms: ttft_ms.unwrap_or(latency * 1e3),
        error: None,
        retry_after_ms: None,
    }
}

fn record_metrics(metrics: &Registry, resp: &Response, cfg: &ModelConfig) {
    metrics.add("requests_completed", 1);
    metrics.add("tokens_generated", cfg.gen_len as u64);
    metrics.add("decode_steps", resp.steps as u64);
    metrics.observe_us("request_latency", resp.latency_ms * 1e3);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;
    use crate::sim::SimModel;

    fn start_sim(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator::start(cfg, tiny_config(), |_wid| Ok(SimModel::math_like(5)))
            .unwrap()
    }

    #[test]
    fn serves_static_request() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.steps > 0);
        assert!(r.tokens_per_sec > 0.0);
        assert!(!r.calibrated);
        c.shutdown();
    }

    #[test]
    fn first_osdt_request_calibrates_then_reuses() {
        let c = start_sim(CoordinatorConfig::default());
        let spec = "osdt:block:q1:0.75:0.2";
        let r1 = c.generate("synth-math", "Q: 1+2=?", spec).unwrap();
        assert!(r1.calibrated, "first OSDT request must calibrate");
        let r2 = c.generate("synth-math", "Q: 3+4=?", spec).unwrap();
        assert!(!r2.calibrated, "profile must be reused");
        assert_eq!(c.metrics.counter_value("calibrations"), 1);
        // a different task calibrates separately
        let r3 = c.generate("synth-qa", "Q: class of x?", spec).unwrap();
        assert!(r3.calibrated);
        assert_eq!(c.metrics.counter_value("calibrations"), 2);
        // registry-level fleet counters agree
        assert_eq!(
            c.registry.metrics().counter_value("calibrations_completed"),
            2
        );
        c.shutdown();
    }

    #[test]
    fn concurrent_osdt_requests_calibrate_exactly_once() {
        // single-flight across workers: even with 2 workers racing on the
        // same task, the registry lease allows exactly one calibration
        let c = Arc::new(start_sim(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        }));
        let spec = "osdt:block:q1:0.75:0.2";
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                c.submit(Request {
                    id: 0,
                    task: "synth-math".into(),
                    prompt: "Q: 2+2=?".into(),
                    policy: spec.into(),
                    slo_ms: None,
                })
            })
            .collect();
        let mut calibrated = 0usize;
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            calibrated += usize::from(r.calibrated);
        }
        assert_eq!(calibrated, 1, "exactly one response may calibrate");
        assert_eq!(c.metrics.counter_value("calibrations"), 1);
        assert_eq!(
            c.registry.metrics().counter_value("calibrations_completed"),
            1
        );
        Arc::try_unwrap(c).ok().map(Coordinator::shutdown);
    }

    #[test]
    fn invalidated_profile_recalibrates_on_next_request() {
        let c = start_sim(CoordinatorConfig::default());
        let spec = "osdt:block:q1:0.75:0.2";
        assert!(c.generate("synth-math", "Q: 1+2=?", spec).unwrap().calibrated);
        let key = ProfileKey::new(
            "synth-math",
            crate::policy::DynamicMode::Block,
            crate::policy::Metric::Q1,
        );
        assert!(c.registry.invalidate(&key));
        let r = c.generate("synth-math", "Q: 3+4=?", spec).unwrap();
        assert!(r.calibrated, "stale profile must recalibrate");
        assert_eq!(c.metrics.counter_value("calibrations"), 2);
        assert_eq!(c.registry.metrics().counter_value("recalibrations"), 1);
        c.shutdown();
    }

    #[test]
    fn completed_decodes_are_observed_by_the_registry() {
        let c = start_sim(CoordinatorConfig::default());
        let spec = "osdt:block:q1:0.75:0.2";
        c.generate("synth-math", "Q: 1+2=?", spec).unwrap();
        for i in 0..3 {
            c.generate("synth-math", &format!("Q: {i}+4=?"), spec).unwrap();
        }
        let key = ProfileKey::new(
            "synth-math",
            crate::policy::DynamicMode::Block,
            crate::policy::Metric::Q1,
        );
        let entry = c.registry.get(&key).unwrap();
        assert_eq!(entry.observed, 3, "non-calibration decodes feed drift tracking");
        c.shutdown();
    }

    #[test]
    fn bad_policy_returns_error_response() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 1+1=?", "warp:9").unwrap();
        assert!(r.error.is_some());
        assert_eq!(c.metrics.counter_value("requests_failed"), 1);
        c.shutdown();
    }

    #[test]
    fn oversized_prompt_fails_cleanly() {
        let c = start_sim(CoordinatorConfig::default());
        let long = "x".repeat(500);
        let r = c.generate("synth-math", &long, "static:0.9").unwrap();
        assert!(r.error.is_some());
        c.shutdown();
    }

    #[test]
    fn failed_calibration_releases_the_lease() {
        // an oversized prompt fails its calibration decode; the dropped
        // lease must let the next request calibrate instead of deadlocking
        let c = start_sim(CoordinatorConfig::default());
        let spec = "osdt:block:q1:0.75:0.2";
        let bad = c.generate("synth-math", &"x".repeat(500), spec).unwrap();
        assert!(bad.error.is_some());
        let good = c.generate("synth-math", "Q: 1+2=?", spec).unwrap();
        assert!(good.error.is_none(), "{:?}", good.error);
        assert!(good.calibrated, "lease must have been released");
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let c = Arc::new(start_sim(CoordinatorConfig {
            workers: 2,
            ..CoordinatorConfig::default()
        }));
        let mut rxs = Vec::new();
        for i in 0..16 {
            rxs.push(c.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: format!("Q: {i}+1=?"),
                policy: "static:0.85".into(),
                slo_ms: None,
            }));
        }
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
        }
        assert_eq!(c.metrics.counter_value("requests_completed"), 16);
        Arc::try_unwrap(c).ok().map(Coordinator::shutdown);
    }

    #[test]
    fn cached_mode_serves() {
        let c = start_sim(CoordinatorConfig {
            cache: CacheConfig::block_boundary(),
            ..CoordinatorConfig::default()
        });
        let r = c.generate("synth-math", "Q: 5+5=?", "static:0.9").unwrap();
        assert!(r.error.is_none());
        assert!(r.window_passes > 0, "cache path must use window passes");
        c.shutdown();
    }

    #[test]
    fn cached_coordinator_forms_batches() {
        // the acceptance bar for the continuous-batching refactor: with the
        // KV cache ON (the config the old lockstep gather refused to batch)
        // a single worker must still co-schedule concurrent requests
        let c = start_sim(CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(50),
            cache: CacheConfig::block_boundary(),
            ..CoordinatorConfig::default()
        });
        let mut rxs = Vec::new();
        for i in 0..8 {
            rxs.push(c.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: format!("Q: {i}+2=?"),
                policy: "static:0.9".into(),
                slo_ms: None,
            }));
        }
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert!(r.window_passes > 0, "cached path must use window passes");
        }
        let peak = c
            .metrics
            .gauge("batch_occupancy_peak")
            .load(Ordering::Relaxed);
        assert!(peak >= 2, "cache-on batching must form real batches (peak {peak})");
        assert!(c.metrics.counter_value("scheduler_steps") > 0);
        assert!(
            c.metrics.counter_value("scheduled_seq_steps")
                > c.metrics.counter_value("scheduler_steps"),
            "mean occupancy must exceed 1"
        );
        c.shutdown();
    }

    #[test]
    fn batched_responses_match_solo_responses() {
        // continuous batching must not change decoded tokens: run the same
        // prompts through a batching coordinator and a solo engine
        let cfg = tiny_config();
        let m = SimModel::math_like(5);
        let engine = Engine::with_kv_cache(&m);
        let tok = Tokenizer::from_config(&cfg).unwrap();
        let c = start_sim(CoordinatorConfig {
            workers: 1,
            max_batch: 4,
            batch_wait: Duration::from_millis(50),
            cache: CacheConfig::block_boundary(),
            ..CoordinatorConfig::default()
        });
        let prompts: Vec<String> = (0..4).map(|i| format!("Q: {i}+3=?")).collect();
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| {
                c.submit(Request {
                    id: 0,
                    task: "synth-math".into(),
                    prompt: p.clone(),
                    policy: "static:0.9".into(),
                    slo_ms: None,
                })
            })
            .collect();
        for (p, rx) in prompts.iter().zip(rxs) {
            let served = rx.recv().unwrap();
            assert!(served.error.is_none(), "{:?}", served.error);
            let layout = tok.layout_prompt(&cfg, p).unwrap();
            let solo = engine
                .decode(layout, &StaticThreshold::new(0.9))
                .unwrap();
            assert_eq!(
                served.completion,
                tok.decode_until_eos(solo.gen_tokens(&cfg)),
                "batched completion differs for {p}"
            );
            assert_eq!(served.steps, solo.steps, "{p}");
        }
        c.shutdown();
    }

    #[test]
    fn sequential_policy_spec_works_end_to_end() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 2+2=?", "sequential:1").unwrap();
        assert_eq!(r.steps, tiny_config().gen_len);
        c.shutdown();
    }

    /// A queued job with a hand-set forecast cost, for queue-level tests.
    fn queued_job(id: u64, cost: usize, enqueued: Instant) -> Job {
        let (tx, _rx) = channel();
        let mut forecast = CostModel::worst_case(&tiny_config());
        forecast.total_passes = cost;
        Job {
            req: Request {
                id,
                task: "synth-math".into(),
                prompt: "Q: 1+1=?".into(),
                policy: "static:0.9".into(),
                slo_ms: None,
            },
            resp: tx,
            enqueued,
            forecast,
        }
    }

    fn pop_id(q: &JobQueue) -> u64 {
        match q.try_pop() {
            Popped::Job(j) => j.req.id,
            _ => panic!("queue drained early"),
        }
    }

    #[test]
    fn predictive_pop_prefers_short_jobs_with_fifo_tiebreak() {
        let q = JobQueue::new(true);
        let now = Instant::now();
        for (id, cost) in [(0, 30), (1, 5), (2, 5), (3, 80)] {
            assert!(q.push(queued_job(id, cost, now)));
        }
        // cheapest first; the two cost-5 jobs keep their arrival order
        // (a strictly-less scan, not min_by, which keeps the last minimum)
        let order: Vec<u64> = (0..4).map(|_| pop_id(&q)).collect();
        assert_eq!(order, vec![1, 2, 0, 3]);
    }

    #[test]
    fn fifo_queue_preserves_arrival_order() {
        let q = JobQueue::new(false);
        let now = Instant::now();
        for (id, cost) in [(0, 30), (1, 5), (2, 80)] {
            assert!(q.push(queued_job(id, cost, now)));
        }
        let order: Vec<u64> = (0..3).map(|_| pop_id(&q)).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn aged_spjf_bounds_starvation() {
        // the starvation bound: a job of cost C is guaranteed the front
        // slot once C - age·AGING_PASSES_PER_SEC drops below any fresh
        // job's cost, i.e. within C / AGING_PASSES_PER_SEC seconds of
        // waiting. Pre-age a long job past that bound and verify no swarm
        // of fresh short jobs outranks it.
        let q = JobQueue::new(true);
        let aged = Instant::now()
            .checked_sub(Duration::from_secs_f64(100.0 / AGING_PASSES_PER_SEC))
            .expect("monotonic clock shorter than the aging bound");
        assert!(q.push(queued_job(7, 100, aged)));
        for id in 0..8 {
            assert!(q.push(queued_job(id, 1, Instant::now())));
        }
        assert_eq!(pop_id(&q), 7, "aged long job must schedule first");
    }

    #[test]
    fn shedding_rejects_with_finite_retry_and_never_cancels_inflight() {
        // watermark fits exactly one worst-case tiny_config request
        // (3 blocks × 32 passes + 3 refreshes = 99 predicted passes): the
        // first submit is admitted, the burst behind it sheds at admission
        let c = start_sim(CoordinatorConfig {
            workers: 1,
            shed_watermark: 120,
            ..CoordinatorConfig::default()
        });
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                c.submit(Request {
                    id: 0,
                    task: "synth-math".into(),
                    prompt: format!("Q: {i}+1=?"),
                    policy: "static:0.9".into(),
                    slo_ms: None,
                })
            })
            .collect();
        let mut shed = 0u64;
        let mut completed = 0u64;
        for rx in rxs {
            let r = rx.recv().unwrap();
            match r.retry_after_ms {
                Some(retry) => {
                    assert!(retry.is_finite() && retry > 0.0, "retry {retry}");
                    assert!(
                        r.error.as_deref().unwrap_or("").contains("shed"),
                        "{:?}",
                        r.error
                    );
                    shed += 1;
                }
                None => {
                    // admitted requests are never cancelled: they complete
                    assert!(r.error.is_none(), "{:?}", r.error);
                    assert!(r.steps > 0);
                    completed += 1;
                }
            }
        }
        assert!(completed >= 1, "first admitted request must complete");
        assert!(shed >= 1, "backlog over the watermark must shed");
        assert_eq!(c.metrics.counter_value("requests_shed"), shed);
        assert_eq!(c.metrics.counter_value("requests_completed"), completed);
        c.shutdown();
    }

    #[test]
    fn slo_budget_sheds_unmeetable_requests() {
        let c = start_sim(CoordinatorConfig {
            slo_ms: 0.5, // far below 99 predicted passes at the ms prior
            ..CoordinatorConfig::default()
        });
        let r = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
        assert!(r.error.as_deref().unwrap_or("").contains("slo"), "{:?}", r.error);
        let retry = r.retry_after_ms.expect("slo shed must carry a retry hint");
        assert!(retry.is_finite() && retry > 0.0);
        // an explicit generous per-request budget overrides the default
        let rx = c.submit(Request {
            id: 0,
            task: "synth-math".into(),
            prompt: "Q: 3+4=?".into(),
            policy: "static:0.9".into(),
            slo_ms: Some(60_000.0),
        });
        let ok = rx.recv().unwrap();
        assert!(ok.error.is_none(), "{:?}", ok.error);
        assert_eq!(c.metrics.counter_value("requests_shed"), 1);
        c.shutdown();
    }

    #[test]
    fn predicted_backlog_settles_to_zero() {
        let c = start_sim(CoordinatorConfig::default());
        let r = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            if c.metrics.gauge("predicted_backlog").load(Ordering::Relaxed) == 0 {
                break;
            }
            assert!(Instant::now() < deadline, "predicted_backlog never drained");
            std::thread::sleep(Duration::from_millis(10));
        }
        c.shutdown();
    }

    #[test]
    fn shutdown_serves_already_queued_jobs() {
        let c = start_sim(CoordinatorConfig::default());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                c.submit(Request {
                    id: 0,
                    task: "synth-math".into(),
                    prompt: format!("Q: {i}+4=?"),
                    policy: "static:0.9".into(),
                    slo_ms: None,
                })
            })
            .collect();
        c.shutdown(); // closes the queue; queued jobs must still be served
        for rx in rxs {
            let r = rx.recv().expect("queued job dropped at shutdown");
            assert!(r.error.is_none(), "{:?}", r.error);
        }
    }
}
