//! Standalone HTTP `/metrics` endpoint serving the strict Prometheus
//! exposition ([`super::expo`]).
//!
//! Deliberately minimal: std::net + threads (same constraints as
//! `server::Server` — the offline registry has no tokio and no HTTP
//! crates), answering exactly one request per connection with
//! `Connection: close`. Prometheus scrapers, `curl`, and load balancer
//! health checks all speak this subset. Anything that is not
//! `GET /metrics` gets a 404/405 so misconfigured scrape targets fail
//! loudly instead of silently graphing nothing.
//!
//! The endpoint owns a small registry of its own (scrape counter), merged
//! into the exposition after the caller-provided sources.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{expo, Registry};

/// A running metrics endpoint; dropping/`stop()` halts the accept loop.
pub struct MetricsServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 for ephemeral) and serve `GET /metrics` over
    /// `sources` until stopped. Sources render in order; the first one
    /// also provides process uptime, so pass the coordinator registry
    /// first.
    pub fn start(addr: &str, sources: Vec<Arc<Registry>>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding metrics endpoint {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let own = Arc::new(Registry::new());
        let handle = std::thread::Builder::new()
            .name("osdt-metrics-accept".into())
            .spawn(move || {
                log::info!("metrics endpoint listening on http://{local}/metrics");
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::debug!("metrics scrape from {peer}");
                            let sources = sources.clone();
                            let own = own.clone();
                            let _ = std::thread::Builder::new()
                                .name("osdt-metrics-conn".into())
                                .spawn(move || {
                                    if let Err(e) =
                                        handle_conn(stream, &sources, &own)
                                    {
                                        log::debug!("metrics conn ended: {e:#}");
                                    }
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            log::warn!("metrics accept error: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(MetricsServer { addr: local, stop, accept_handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    sources: &[Arc<Registry>],
    own: &Arc<Registry>,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    // Drain headers to the blank line so well-behaved clients aren't cut
    // off mid-send by our response + close.
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 || line.trim_end().is_empty() {
            break;
        }
    }

    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let path = path.split('?').next().unwrap_or(path);

    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "only GET is supported\n".to_string())
    } else if path != "/metrics" {
        ("404 Not Found", "text/plain", "try /metrics\n".to_string())
    } else {
        own.add("metrics_scrapes", 1);
        let mut refs: Vec<&Registry> =
            sources.iter().map(Arc::as_ref).collect();
        refs.push(own);
        ("200 OK", expo::CONTENT_TYPE, expo::render_prometheus(&refs))
    };

    let mut w = stream;
    write!(
        w,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, target: &str) -> (String, String) {
        request(addr, &format!("GET {target} HTTP/1.1"))
    }

    fn request(addr: SocketAddr, request_line: &str) -> (String, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "{request_line}\r\nHost: test\r\n\r\n").unwrap();
        let mut buf = String::new();
        use std::io::Read;
        s.read_to_string(&mut buf).unwrap();
        let (head, body) = buf.split_once("\r\n\r\n").unwrap();
        (head.to_string(), body.to_string())
    }

    #[test]
    fn serves_prometheus_exposition() {
        let r = Arc::new(Registry::new());
        r.add("tokens_generated", 9);
        r.observe_us("request_latency", 50_000.0);
        let srv = MetricsServer::start("127.0.0.1:0", vec![r]).unwrap();

        let (head, body) = http_get(srv.addr, "/metrics");
        assert!(head.starts_with("HTTP/1.1 200 OK"), "{head}");
        assert!(head.contains(expo::CONTENT_TYPE), "{head}");
        assert!(body.contains("osdt_tokens_generated_total 9\n"), "{body}");
        assert!(body.contains("# TYPE osdt_request_latency_seconds histogram"), "{body}");
        assert!(body.contains("osdt_process_uptime_seconds"), "{body}");

        // the endpoint counts its own scrapes; the first scrape's increment
        // is visible by the second
        let (_, body) = http_get(srv.addr, "/metrics");
        assert!(body.contains("osdt_metrics_scrapes_total 2\n"), "{body}");
        srv.stop();
    }

    #[test]
    fn rejects_wrong_path_and_method() {
        let srv =
            MetricsServer::start("127.0.0.1:0", vec![Arc::new(Registry::new())])
                .unwrap();
        let (head, _) = http_get(srv.addr, "/");
        assert!(head.starts_with("HTTP/1.1 404"), "{head}");
        let (head, _) = request(srv.addr, "POST /metrics HTTP/1.1");
        assert!(head.starts_with("HTTP/1.1 405"), "{head}");
        srv.stop();
    }

    #[test]
    fn query_string_is_ignored() {
        let srv =
            MetricsServer::start("127.0.0.1:0", vec![Arc::new(Registry::new())])
                .unwrap();
        let (head, _) = http_get(srv.addr, "/metrics?format=prometheus");
        assert!(head.starts_with("HTTP/1.1 200"), "{head}");
        srv.stop();
    }
}
