//! Serving metrics substrate: counters, gauges, latency histograms, and
//! two text expositions — the legacy human-oriented summary ([`Registry::
//! render`], served over the TCP `{"cmd":"metrics"}` command) and the
//! strict Prometheus format ([`expo`], served by the standalone HTTP
//! [`http::MetricsServer`]). Shared across coordinator threads via
//! `Arc<Registry>`; histograms sit behind a mutex (recording is off the
//! per-token hot path — it happens once per request / per step batch).
//!
//! Every metric the serving stack emits is declared in [`catalog`], which
//! carries its exposed Prometheus name, type, unit normalization, and
//! operational help text. `METRICS.md` at the repository root documents
//! the same set; `rust/tests/observability.rs` cross-checks catalog ↔
//! exposition ↔ document in both directions so none of the three can rot.

pub mod expo;
pub mod http;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<AtomicI64>>>,
    /// name -> (histogram, unit suffix rendered after each statistic;
    /// "us" for latencies, "" for unitless series like batch occupancy)
    histograms: Mutex<BTreeMap<String, (std::sync::Arc<Mutex<Histogram>>, &'static str)>>,
    start: Option<Instant>,
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            start: Some(Instant::now()),
            ..Default::default()
        }
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<AtomicI64> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    /// Monotonic high-water gauge: keeps the maximum of all reported values.
    pub fn max_gauge(&self, name: &str, v: i64) {
        self.gauge(name).fetch_max(v, Ordering::Relaxed);
    }

    fn histogram_with_unit(
        &self,
        name: &str,
        unit: &'static str,
    ) -> std::sync::Arc<Mutex<Histogram>> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                (std::sync::Arc::new(Mutex::new(Histogram::latency())), unit)
            })
            .0
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Mutex<Histogram>> {
        self.histogram_with_unit(name, "us")
    }

    /// Record a latency observation in microseconds.
    pub fn observe_us(&self, name: &str, us: f64) {
        self.histogram_with_unit(name, "us").lock().unwrap().record(us);
    }

    /// Record a unitless observation (queue depth, batch occupancy, ...).
    pub fn observe(&self, name: &str, v: f64) {
        self.histogram_with_unit(name, "").lock().unwrap().record(v);
    }

    pub fn uptime_secs(&self) -> f64 {
        self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Prometheus-ish text exposition (counters, gauges, histogram
    /// mean/p50/p95/p99/max).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!(
                "osdt_{name}_total {}\n",
                c.load(Ordering::Relaxed)
            ));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("osdt_{name} {}\n", g.load(Ordering::Relaxed)));
        }
        for (name, (h, unit)) in self.histograms.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            if h.n == 0 {
                continue;
            }
            let suffix = if unit.is_empty() {
                String::new()
            } else {
                format!("_{unit}")
            };
            out.push_str(&format!("osdt_{name}_count {}\n", h.n));
            out.push_str(&format!("osdt_{name}_mean{suffix} {:.1}\n", h.mean()));
            for (q, label) in [(0.5, "p50"), (0.95, "p95"), (0.99, "p99")] {
                out.push_str(&format!(
                    "osdt_{name}_{label}{suffix} {:.1}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("osdt_{name}_max{suffix} {:.1}\n", h.max));
        }
        out
    }
}

/// RAII latency scope: records elapsed microseconds into `registry` at drop.
pub struct LatencyScope<'a> {
    registry: &'a Registry,
    name: &'a str,
    start: Instant,
}

impl<'a> LatencyScope<'a> {
    pub fn new(registry: &'a Registry, name: &'a str) -> Self {
        LatencyScope {
            registry,
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for LatencyScope<'_> {
    fn drop(&mut self) {
        self.registry
            .observe_us(self.name, self.start.elapsed().as_secs_f64() * 1e6);
    }
}

// ---------------------------------------------------------------------------
// Metric catalog
// ---------------------------------------------------------------------------

/// Metric family kind in the Prometheus exposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One declared metric: the bridge between an internal registry key and
/// its strict-Prometheus exposition (name, unit normalization, buckets).
#[derive(Clone, Copy, Debug)]
pub struct MetricSpec {
    /// Internal registry key (what `add` / `observe*` are called with).
    pub name: &'static str,
    /// Exposed Prometheus family name (`_total` suffix included for
    /// counters; base units — seconds, bytes — per Prometheus convention).
    pub exposed: &'static str,
    pub kind: MetricKind,
    /// Divisor applied to recorded values at exposition time (1e6 for
    /// microsecond series exposed as seconds; 1.0 otherwise). Internal
    /// recording is never touched — normalization happens on render only.
    pub per: f64,
    /// Emitting module (documentation key in METRICS.md).
    pub module: &'static str,
    pub help: &'static str,
    /// Histogram `le` upper bounds, in *exposed* units. Empty for
    /// counters/gauges.
    pub buckets: &'static [f64],
}

/// Request-scale latency bounds in seconds (1ms .. 10s).
pub const LATENCY_BUCKETS_S: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
    10.0,
];

/// Small-count bounds (occupancy, tokens per step).
pub const COUNT_BUCKETS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0];

/// Cosine-similarity bounds, dense near 1.0 where drift decisions live.
pub const COSINE_BUCKETS: &[f64] =
    &[0.5, 0.8, 0.9, 0.95, 0.98, 0.99, 0.995, 1.0];

const fn counter(
    name: &'static str,
    exposed: &'static str,
    module: &'static str,
    help: &'static str,
) -> MetricSpec {
    MetricSpec {
        name,
        exposed,
        kind: MetricKind::Counter,
        per: 1.0,
        module,
        help,
        buckets: &[],
    }
}

const fn seconds_counter(
    name: &'static str,
    exposed: &'static str,
    module: &'static str,
    help: &'static str,
) -> MetricSpec {
    MetricSpec {
        name,
        exposed,
        kind: MetricKind::Counter,
        per: 1e6,
        module,
        help,
        buckets: &[],
    }
}

const fn gauge(
    name: &'static str,
    exposed: &'static str,
    module: &'static str,
    help: &'static str,
) -> MetricSpec {
    MetricSpec {
        name,
        exposed,
        kind: MetricKind::Gauge,
        per: 1.0,
        module,
        help,
        buckets: &[],
    }
}

const fn histogram(
    name: &'static str,
    exposed: &'static str,
    per: f64,
    buckets: &'static [f64],
    module: &'static str,
    help: &'static str,
) -> MetricSpec {
    MetricSpec {
        name,
        exposed,
        kind: MetricKind::Histogram,
        per,
        module,
        help,
        buckets,
    }
}

/// Every metric the serving stack exports, in exposition order. The
/// observability test suite asserts this list, the rendered exposition,
/// and METRICS.md agree.
pub fn catalog() -> &'static [MetricSpec] {
    const CATALOG: &[MetricSpec] = &[
        // -- process (emitted by the HTTP metrics endpoint) ----------------
        gauge(
            "process_uptime_seconds",
            "osdt_process_uptime_seconds",
            "metrics/http",
            "Seconds since the primary metrics registry was created.",
        ),
        counter(
            "metrics_scrapes",
            "osdt_metrics_scrapes_total",
            "metrics/http",
            "Successful GET /metrics scrapes served.",
        ),
        // -- coordinator request lifecycle ---------------------------------
        counter(
            "requests_submitted",
            "osdt_requests_submitted_total",
            "coordinator",
            "Requests accepted into the job queue.",
        ),
        counter(
            "requests_completed",
            "osdt_requests_completed_total",
            "coordinator",
            "Requests answered with a completion.",
        ),
        counter(
            "requests_failed",
            "osdt_requests_failed_total",
            "coordinator",
            "Requests answered with an error (bad policy, oversized \
             prompt, failed calibration, poisoned scheduler step).",
        ),
        counter(
            "requests_shed",
            "osdt_requests_shed_total",
            "coordinator",
            "Requests rejected at admission by the predictive-scheduling \
             guardrails (predicted backlog over --shed-watermark, or a \
             forecast that cannot meet the request's SLO budget); each \
             carried a finite retry_after_ms. In-flight decodes are never \
             shed (DESIGN.md \u{a7}15).",
        ),
        counter(
            "tokens_generated",
            "osdt_tokens_generated_total",
            "coordinator",
            "Generated-region tokens committed across completed requests.",
        ),
        counter(
            "decode_steps",
            "osdt_decode_steps_total",
            "coordinator",
            "Policy decision steps summed over completed requests.",
        ),
        // -- calibration lifecycle (worker-local view) ---------------------
        counter(
            "calibrations",
            "osdt_calibrations_total",
            "coordinator",
            "Phase-1 calibration decodes run by this coordinator's workers.",
        ),
        counter(
            "calibrations_deferred",
            "osdt_calibrations_deferred_total",
            "coordinator",
            "Local calibrations parked to protect co-scheduled peers.",
        ),
        counter(
            "calibrations_awaited",
            "osdt_calibrations_awaited_total",
            "coordinator",
            "Requests parked behind a peer's in-flight calibration lease.",
        ),
        // -- scheduler -----------------------------------------------------
        counter(
            "scheduler_steps",
            "osdt_scheduler_steps_total",
            "coordinator",
            "Continuous-batching scheduler steps executed.",
        ),
        counter(
            "scheduled_seq_steps",
            "osdt_scheduled_seq_steps_total",
            "coordinator",
            "Per-sequence steps summed over scheduler steps; divided by \
             osdt_scheduler_steps_total this is the mean batch occupancy.",
        ),
        counter(
            "scheduler_step_failures",
            "osdt_scheduler_step_failures_total",
            "coordinator",
            "Scheduler steps that failed (a forward pass errored); every \
             in-flight sequence on the worker is failed and the scheduler \
             is rebuilt.",
        ),
        counter(
            "full_passes",
            "osdt_full_passes_total",
            "coordinator",
            "Per-sequence full forward passes (fwd_conf rows + fwd_full_kv).",
        ),
        counter(
            "window_passes",
            "osdt_window_passes_total",
            "coordinator",
            "Per-sequence in-block window passes (fused + host rows).",
        ),
        counter(
            "fused_window_passes",
            "osdt_fused_window_passes_total",
            "coordinator",
            "Window passes whose acceptance decision ran on device \
             (DESIGN.md \u{a7}11); divided by osdt_window_passes_total this \
             is the fused-pass fraction.",
        ),
        // -- paged KV pool + prefix sharing (DESIGN.md §13) ----------------
        counter(
            "prefix_sharing_saved_full_passes",
            "osdt_prefix_sharing_saved_full_passes_total",
            "coordinator",
            "Block-0 fwd_full_kv refreshes skipped because an identical \
             prompt layout was already in the prefix index (its pages and \
             conf/argmax rows were reused instead).",
        ),
        counter(
            "kv_page_reuse",
            "osdt_kv_page_reuse_total",
            "coordinator",
            "KV pages reused by reference across prefix-index hits \
             (pages per hit times hits; excludes the per-hit COW'd first \
             decode page).",
        ),
        counter(
            "window_padding_rows",
            "osdt_window_padding_rows_total",
            "coordinator",
            "Padding rows implied by bucket selection across window/fused \
             groups (chosen bucket minus live rows, summed) — the waste \
             side of the bucket ladder.",
        ),
        counter(
            "prefix_sharing_skipped_device",
            "osdt_prefix_sharing_skipped_device_total",
            "coordinator",
            "Block-0 refreshes whose KV stayed device-resident so the \
             prefix index could not adopt them (sharing needs host pages); \
             persistent growth under --prefix-sharing on means the \
             residency setting is defeating the share (RUNBOOK.md).",
        ),
        // -- profile-guided step elision (DESIGN.md §14) -------------------
        counter(
            "steps_elided",
            "osdt_steps_elided_total",
            "coordinator",
            "Window passes skipped by the elision planner because the \
             profile's acceptance trajectory predicted zero acceptances \
             (schedule jumped ahead; the steps were never executed).",
        ),
        counter(
            "elision_mispredictions",
            "osdt_elision_mispredictions_total",
            "coordinator",
            "Elision jumps whose landing step fell back to argmax — the \
             trajectory promised acceptances that did not materialise. \
             Fed to the profile registry as drift evidence; a storm marks \
             the profile stale (RUNBOOK.md).",
        ),
        counter(
            "blocks_retired_early",
            "osdt_blocks_retired_early_total",
            "coordinator",
            "Blocks committed with at least one elided step — retired in \
             fewer window passes than their threshold schedule prescribed.",
        ),
        // -- transfer ledger (workers with a stats-reporting runtime) ------
        seconds_counter(
            "model_exec_us",
            "osdt_model_exec_seconds_total",
            "coordinator",
            "Cumulative device execution time reported by the runtime.",
        ),
        seconds_counter(
            "model_transfer_us",
            "osdt_model_transfer_seconds_total",
            "coordinator",
            "Cumulative host\u{2194}device transfer time reported by the \
             runtime.",
        ),
        counter(
            "bytes_uploaded",
            "osdt_uploaded_bytes_total",
            "coordinator",
            "Host\u{2192}device bytes uploaded by worker runtimes.",
        ),
        counter(
            "bytes_downloaded",
            "osdt_downloaded_bytes_total",
            "coordinator",
            "Device\u{2192}host bytes downloaded by worker runtimes.",
        ),
        counter(
            "cache_bytes_uploaded",
            "osdt_cache_uploaded_bytes_total",
            "coordinator",
            "K/V-cache share of uploaded bytes; pinned at 0 on the \
             device-resident cache path (DESIGN.md \u{a7}10).",
        ),
        counter(
            "cache_bytes_downloaded",
            "osdt_cache_downloaded_bytes_total",
            "coordinator",
            "K/V-cache share of downloaded bytes.",
        ),
        // -- gauges --------------------------------------------------------
        gauge(
            "queue_depth",
            "osdt_queue_depth",
            "coordinator",
            "Jobs waiting in the coordinator queue right now.",
        ),
        gauge(
            "predicted_backlog",
            "osdt_predicted_backlog",
            "coordinator",
            "Sum of forecast total passes across queued and active \
             requests — the load signal the --shed-watermark guardrail \
             compares against (DESIGN.md \u{a7}15).",
        ),
        gauge(
            "batch_occupancy",
            "osdt_batch_occupancy",
            "coordinator",
            "Sequences sharing the most recent scheduler step (0 when a \
             worker drains).",
        ),
        gauge(
            "batch_occupancy_peak",
            "osdt_batch_occupancy_peak",
            "coordinator",
            "High-water batch occupancy since start.",
        ),
        gauge(
            "kv_pages_in_use",
            "osdt_kv_pages_in_use",
            "coordinator",
            "Live pages in the paged KV pool after the most recent \
             scheduler step (0 when prefix sharing is off).",
        ),
        // -- histograms ----------------------------------------------------
        histogram(
            "batch_occupancy",
            "osdt_batch_occupancy_per_step",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "Distribution of batch occupancy over scheduler steps.",
        ),
        histogram(
            "accepted_per_step",
            "osdt_accepted_tokens_per_step",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "Tokens committed per advanced sequence per step — the \
             parallelism each policy actually buys. Only live rows are \
             observed; bucket padding rows never appear.",
        ),
        histogram(
            "window_bucket_occupancy",
            "osdt_window_bucket_occupancy",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "Live rows per co-executed window/fused group — how full the \
             chosen buckets run (compare osdt_window_padding_rows_total \
             for the padding complement).",
        ),
        histogram(
            "request_latency",
            "osdt_request_latency_seconds",
            1e6,
            LATENCY_BUCKETS_S,
            "coordinator",
            "Scheduler admission \u{2192} response, per completed request.",
        ),
        histogram(
            "admission_wait",
            "osdt_admission_wait_seconds",
            1e6,
            LATENCY_BUCKETS_S,
            "coordinator",
            "Enqueue \u{2192} scheduler admission, per request.",
        ),
        histogram(
            "ttft",
            "osdt_request_ttft_seconds",
            1e6,
            LATENCY_BUCKETS_S,
            "coordinator",
            "Time to first committed token: enqueue \u{2192} first \
             scheduler step that committed tokens for the request. \
             Calibration responses report their full decode latency (the \
             decode runs inline, outside the scheduler).",
        ),
        // -- predictive scheduling (DESIGN.md §15) -------------------------
        histogram(
            "predicted_steps",
            "osdt_predicted_steps",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "Forecast total passes per submitted request, stamped at \
             admission (worst-case prior until the task calibrates).",
        ),
        histogram(
            "forecast_error",
            "osdt_forecast_error",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "|forecast total passes \u{2212} executed passes| per retired \
             decode — the cost model's accuracy; a rising p95 means \
             profiles have drifted from real acceptance behaviour.",
        ),
        histogram(
            "group_alignment_drag",
            "osdt_group_alignment_drag",
            1.0,
            COUNT_BUCKETS,
            "coordinator",
            "Per co-executed window/fused group with \u{2265} 2 forecast \
             rows: spread (max \u{2212} min) of predicted remaining passes \
             — how badly grouped rows will retire apart. --align-band \
             drives this toward 0.",
        ),
        // -- profile registry (fleet-wide) ---------------------------------
        counter(
            "profile_hits",
            "osdt_profile_hits_total",
            "policy/registry",
            "Acquires resolved from a fresh calibrated profile.",
        ),
        counter(
            "profile_misses",
            "osdt_profile_misses_total",
            "policy/registry",
            "Acquires that found no profile and took the calibration lease.",
        ),
        counter(
            "profile_waits",
            "osdt_profile_waits_total",
            "policy/registry",
            "Acquires told to wait on a peer's in-flight calibration.",
        ),
        counter(
            "profile_stale_serves",
            "osdt_profile_stale_serves_total",
            "policy/registry",
            "Acquires served from a stale profile while its recalibration \
             is in flight (drift never stops the fleet).",
        ),
        counter(
            "profile_warm_starts",
            "osdt_profile_warm_starts_total",
            "policy/registry",
            "Profiles loaded from the on-disk store at construction.",
        ),
        counter(
            "profile_invalidations",
            "osdt_profile_invalidations_total",
            "policy/registry",
            "Profiles marked stale by the admin invalidate command.",
        ),
        counter(
            "profile_persist_errors",
            "osdt_profile_persist_errors_total",
            "policy/registry",
            "Failed profile writes to the on-disk store (serving continues \
             from memory).",
        ),
        counter(
            "profile_ema_updates",
            "osdt_profile_ema_updates_total",
            "policy/registry",
            "EMA threshold refinements folded in from observed decodes.",
        ),
        counter(
            "leases_granted",
            "osdt_leases_granted_total",
            "policy/registry",
            "Calibration leases handed out (first acquire per key, plus \
             recalibrations).",
        ),
        counter(
            "leases_abandoned",
            "osdt_leases_abandoned_total",
            "policy/registry",
            "Leases dropped unfulfilled (failed or crashed calibration); \
             the key is released for a peer to retry.",
        ),
        counter(
            "leases_superseded",
            "osdt_leases_superseded_total",
            "policy/registry",
            "Stale lease resolutions that arrived after the lease had been \
             stolen; ignored so they cannot re-open single-flight.",
        ),
        counter(
            "lease_takeovers",
            "osdt_lease_takeovers_total",
            "policy/registry",
            "Leases stolen from a holder outstanding past the caller's \
             patience (the liveness escape hatch).",
        ),
        counter(
            "calibrations_completed",
            "osdt_calibrations_completed_total",
            "policy/registry",
            "Fulfilled calibration leases, fleet-wide.",
        ),
        counter(
            "recalibrations",
            "osdt_recalibrations_total",
            "policy/registry",
            "Fulfilled leases that replaced an existing profile.",
        ),
        counter(
            "drift_events",
            "osdt_drift_events_total",
            "policy/registry",
            "Profiles marked stale because an observed decode's signature \
             cosine fell below the drift floor.",
        ),
        counter(
            "observations_superseded",
            "osdt_observations_superseded_total",
            "policy/registry",
            "Decode observations dropped because the profile was \
             recalibrated while the decode was in flight.",
        ),
        histogram(
            "profile_signature_cosine",
            "osdt_profile_signature_cosine",
            1.0,
            COSINE_BUCKETS,
            "policy/registry",
            "Cosine similarity of each observed decode's confidence \
             signature against the profile's drift reference.",
        ),
        // -- cross-process profile coordination (policy/registry) ----------
        counter(
            "profile_cross_adoptions",
            "osdt_profile_cross_adoptions_total",
            "policy/registry",
            "Profiles adopted from the shared ProfileStore because a peer \
             process calibrated (or recalibrated) them first.",
        ),
        counter(
            "cross_lease_conflicts",
            "osdt_cross_lease_conflicts_total",
            "policy/registry",
            "Cross-process calibration leases lost to a peer that already \
             holds the store-level lease file (the loser waits and adopts).",
        ),
        counter(
            "cross_lease_takeovers",
            "osdt_cross_lease_takeovers_total",
            "policy/registry",
            "Expired cross-process lease files broken and taken over \
             (holder crashed without releasing).",
        ),
        // -- server front-end ----------------------------------------------
        counter(
            "connection_timeouts",
            "osdt_connection_timeouts_total",
            "server",
            "Client connections closed because a read or write exceeded \
             the per-connection timeout (--conn-timeout-ms).",
        ),
        // -- fleet router --------------------------------------------------
        counter(
            "fleet_requests_routed",
            "osdt_fleet_requests_routed_total",
            "fleet/router",
            "Requests forwarded to a replica and answered (including \
             answers that carry an application-level error).",
        ),
        counter(
            "fleet_request_retries",
            "osdt_fleet_request_retries_total",
            "fleet/router",
            "Transport-level forward failures retried on a surviving \
             replica after jittered backoff.",
        ),
        counter(
            "fleet_requests_shed",
            "osdt_fleet_requests_shed_total",
            "fleet/router",
            "Requests shed at the router (no healthy replica, retry \
             budget exhausted, or backlog over the fleet watermark) with \
             a finite retry_after_ms hint.",
        ),
        counter(
            "fleet_replica_failures",
            "osdt_fleet_replica_failures_total",
            "fleet/router",
            "Healthy-to-unhealthy transitions: a replica stopped \
             answering probes or dropped a forwarded request.",
        ),
        gauge(
            "fleet_replicas_healthy",
            "osdt_fleet_replicas_healthy",
            "fleet/router",
            "Replicas currently answering health probes.",
        ),
        gauge(
            "fleet_replicas_draining",
            "osdt_fleet_replicas_draining",
            "fleet/router",
            "Replicas administratively drained (serving in-flight work \
             but receiving no new requests).",
        ),
        // -- fleet supervisor ----------------------------------------------
        counter(
            "fleet_respawns",
            "osdt_fleet_respawns_total",
            "fleet/supervisor",
            "Worker processes (replicas or the router) respawned after a \
             death, a hung heartbeat, or a rolling restart.",
        ),
        counter(
            "fleet_stale_states_recovered",
            "osdt_fleet_stale_states_recovered_total",
            "fleet/supervisor",
            "Startups that found a stale state.json (dead supervisor \
             PID), probed its recorded replicas, and adopted the \
             survivors.",
        ),
        counter(
            "fleet_rolling_restarts",
            "osdt_fleet_rolling_restarts_total",
            "fleet/supervisor",
            "Orchestrated rolling restarts started (each drains, kills, \
             respawns, and re-verifies every replica in turn).",
        ),
    ];
    CATALOG
}

/// Catalog entry for an internal name + kind, if declared.
pub fn spec_for(name: &str, kind: MetricKind) -> Option<&'static MetricSpec> {
    catalog().iter().find(|s| s.name == name && s.kind == kind)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.add("requests", 2);
        r.add("requests", 3);
        assert_eq!(r.counter_value("requests"), 5);
        assert_eq!(r.counter_value("other"), 0);
    }

    #[test]
    fn gauges_set() {
        let r = Registry::new();
        r.set_gauge("queue_depth", 7);
        r.set_gauge("queue_depth", 3);
        assert_eq!(r.gauge("queue_depth").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn max_gauge_keeps_high_water() {
        let r = Registry::new();
        r.max_gauge("batch_occupancy_peak", 2);
        r.max_gauge("batch_occupancy_peak", 4);
        r.max_gauge("batch_occupancy_peak", 1);
        assert_eq!(r.gauge("batch_occupancy_peak").load(Ordering::Relaxed), 4);
    }

    #[test]
    fn unitless_histograms_render_without_us_suffix() {
        let r = Registry::new();
        r.observe("batch_occupancy", 2.0);
        r.observe("batch_occupancy", 4.0);
        r.observe_us("step", 1500.0);
        let text = r.render();
        assert!(text.contains("osdt_batch_occupancy_count 2"), "{text}");
        assert!(text.contains("osdt_batch_occupancy_p50 "), "{text}");
        assert!(!text.contains("osdt_batch_occupancy_p50_us"), "{text}");
        assert!(text.contains("osdt_step_p50_us"), "{text}");
    }

    #[test]
    fn histogram_and_render() {
        let r = Registry::new();
        for i in 1..=100 {
            r.observe_us("step", i as f64 * 100.0);
        }
        r.add("tokens", 42);
        let text = r.render();
        assert!(text.contains("osdt_tokens_total 42"), "{text}");
        assert!(text.contains("osdt_step_count 100"), "{text}");
        assert!(text.contains("osdt_step_p50_us"), "{text}");
    }

    #[test]
    fn latency_scope_records() {
        let r = Registry::new();
        {
            let _s = LatencyScope::new(&r, "op");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = r.histogram("op");
        let h = h.lock().unwrap();
        assert_eq!(h.n, 1);
        assert!(h.mean() >= 1000.0, "mean {}", h.mean());
    }

    #[test]
    fn shared_across_threads() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.add("n", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter_value("n"), 8000);
    }
}
