//! Serving metrics substrate: counters, gauges, latency histograms, and a
//! Prometheus-style text exposition. Shared across coordinator threads via
//! `Arc<Registry>`; histograms sit behind a mutex (recording is off the
//! per-token hot path — it happens once per request / per step batch).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<AtomicI64>>>,
    /// name -> (histogram, unit suffix rendered after each statistic;
    /// "us" for latencies, "" for unitless series like batch occupancy)
    histograms: Mutex<BTreeMap<String, (std::sync::Arc<Mutex<Histogram>>, &'static str)>>,
    start: Option<Instant>,
}

impl Registry {
    pub fn new() -> Self {
        Registry {
            start: Some(Instant::now()),
            ..Default::default()
        }
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn counter_value(&self, name: &str) -> u64 {
        self.counter(name).load(Ordering::Relaxed)
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<AtomicI64> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    /// Monotonic high-water gauge: keeps the maximum of all reported values.
    pub fn max_gauge(&self, name: &str, v: i64) {
        self.gauge(name).fetch_max(v, Ordering::Relaxed);
    }

    fn histogram_with_unit(
        &self,
        name: &str,
        unit: &'static str,
    ) -> std::sync::Arc<Mutex<Histogram>> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| {
                (std::sync::Arc::new(Mutex::new(Histogram::latency())), unit)
            })
            .0
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Mutex<Histogram>> {
        self.histogram_with_unit(name, "us")
    }

    /// Record a latency observation in microseconds.
    pub fn observe_us(&self, name: &str, us: f64) {
        self.histogram_with_unit(name, "us").lock().unwrap().record(us);
    }

    /// Record a unitless observation (queue depth, batch occupancy, ...).
    pub fn observe(&self, name: &str, v: f64) {
        self.histogram_with_unit(name, "").lock().unwrap().record(v);
    }

    pub fn uptime_secs(&self) -> f64 {
        self.start.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Prometheus-ish text exposition (counters, gauges, histogram
    /// mean/p50/p95/p99/max).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!(
                "osdt_{name}_total {}\n",
                c.load(Ordering::Relaxed)
            ));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("osdt_{name} {}\n", g.load(Ordering::Relaxed)));
        }
        for (name, (h, unit)) in self.histograms.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            if h.n == 0 {
                continue;
            }
            let suffix = if unit.is_empty() {
                String::new()
            } else {
                format!("_{unit}")
            };
            out.push_str(&format!("osdt_{name}_count {}\n", h.n));
            out.push_str(&format!("osdt_{name}_mean{suffix} {:.1}\n", h.mean()));
            for (q, label) in [(0.5, "p50"), (0.95, "p95"), (0.99, "p99")] {
                out.push_str(&format!(
                    "osdt_{name}_{label}{suffix} {:.1}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("osdt_{name}_max{suffix} {:.1}\n", h.max));
        }
        out
    }
}

/// RAII latency scope: records elapsed microseconds into `registry` at drop.
pub struct LatencyScope<'a> {
    registry: &'a Registry,
    name: &'a str,
    start: Instant,
}

impl<'a> LatencyScope<'a> {
    pub fn new(registry: &'a Registry, name: &'a str) -> Self {
        LatencyScope {
            registry,
            name,
            start: Instant::now(),
        }
    }
}

impl Drop for LatencyScope<'_> {
    fn drop(&mut self) {
        self.registry
            .observe_us(self.name, self.start.elapsed().as_secs_f64() * 1e6);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.add("requests", 2);
        r.add("requests", 3);
        assert_eq!(r.counter_value("requests"), 5);
        assert_eq!(r.counter_value("other"), 0);
    }

    #[test]
    fn gauges_set() {
        let r = Registry::new();
        r.set_gauge("queue_depth", 7);
        r.set_gauge("queue_depth", 3);
        assert_eq!(r.gauge("queue_depth").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn max_gauge_keeps_high_water() {
        let r = Registry::new();
        r.max_gauge("batch_occupancy_peak", 2);
        r.max_gauge("batch_occupancy_peak", 4);
        r.max_gauge("batch_occupancy_peak", 1);
        assert_eq!(r.gauge("batch_occupancy_peak").load(Ordering::Relaxed), 4);
    }

    #[test]
    fn unitless_histograms_render_without_us_suffix() {
        let r = Registry::new();
        r.observe("batch_occupancy", 2.0);
        r.observe("batch_occupancy", 4.0);
        r.observe_us("step", 1500.0);
        let text = r.render();
        assert!(text.contains("osdt_batch_occupancy_count 2"), "{text}");
        assert!(text.contains("osdt_batch_occupancy_p50 "), "{text}");
        assert!(!text.contains("osdt_batch_occupancy_p50_us"), "{text}");
        assert!(text.contains("osdt_step_p50_us"), "{text}");
    }

    #[test]
    fn histogram_and_render() {
        let r = Registry::new();
        for i in 1..=100 {
            r.observe_us("step", i as f64 * 100.0);
        }
        r.add("tokens", 42);
        let text = r.render();
        assert!(text.contains("osdt_tokens_total 42"), "{text}");
        assert!(text.contains("osdt_step_count 100"), "{text}");
        assert!(text.contains("osdt_step_p50_us"), "{text}");
    }

    #[test]
    fn latency_scope_records() {
        let r = Registry::new();
        {
            let _s = LatencyScope::new(&r, "op");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let h = r.histogram("op");
        let h = h.lock().unwrap();
        assert_eq!(h.n, 1);
        assert!(h.mean() >= 1000.0, "mean {}", h.mean());
    }

    #[test]
    fn shared_across_threads() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    r.add("n", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter_value("n"), 8000);
    }
}
