//! Strict Prometheus text exposition (format 0.0.4) over one or more
//! [`Registry`] sources.
//!
//! This is the wire format behind the standalone HTTP `/metrics` endpoint
//! ([`super::http::MetricsServer`]). It differs from the legacy
//! [`Registry::render`] summary in three ways:
//!
//! * every family carries `# HELP` / `# TYPE` lines sourced from
//!   [`super::catalog`];
//! * units are normalized to Prometheus base units at exposition time —
//!   internally-microsecond series divide by 1e6 and expose `_seconds`
//!   names; internal recording is untouched;
//! * histograms render as cumulative `_bucket{le="..."}` series (via
//!   [`Histogram::cumulative_le`]) plus `_sum` / `_count`, instead of
//!   pre-digested quantiles.
//!
//! Metrics recorded under a name missing from the catalog still render —
//! with a derived family name and a help line flagging them — so the
//! endpoint never hides data; the METRICS.md cross-check test is what
//! turns an undeclared name into a CI failure.

use std::collections::HashSet;
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

use crate::util::stats::Histogram;

use super::{
    spec_for, MetricKind, Registry, COUNT_BUCKETS, LATENCY_BUCKETS_S,
};

/// Content-Type for the exposition, per the Prometheus text format spec.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

const UNDECLARED: &str =
    "Undeclared metric; add it to metrics::catalog() and METRICS.md.";

/// Shortest clean rendering of a sample value: integral values drop the
/// trailing `.0` (Prometheus treats `5` and `5.0` identically).
fn fmt_val(v: f64) -> String {
    if v == v.trunc() && v.is_finite() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn header(out: &mut String, exposed: &str, kind: MetricKind, help: &str) {
    let help = help.replace('\\', "\\\\").replace('\n', " ");
    let _ = writeln!(out, "# HELP {exposed} {help}");
    let _ = writeln!(out, "# TYPE {exposed} {}", kind.as_str());
}

fn counter_family(name: &str) -> (String, f64, &'static str) {
    if let Some(s) = spec_for(name, MetricKind::Counter) {
        return (s.exposed.to_string(), s.per, s.help);
    }
    match name.strip_suffix("_us") {
        Some(base) => (format!("osdt_{base}_seconds_total"), 1e6, UNDECLARED),
        None => (format!("osdt_{name}_total"), 1.0, UNDECLARED),
    }
}

fn gauge_family(name: &str) -> (String, &'static str) {
    match spec_for(name, MetricKind::Gauge) {
        Some(s) => (s.exposed.to_string(), s.help),
        None => (format!("osdt_{name}"), UNDECLARED),
    }
}

fn histogram_family(
    name: &str,
    unit: &str,
) -> (String, f64, &'static [f64], &'static str) {
    if let Some(s) = spec_for(name, MetricKind::Histogram) {
        return (s.exposed.to_string(), s.per, s.buckets, s.help);
    }
    if unit == "us" {
        (format!("osdt_{name}_seconds"), 1e6, LATENCY_BUCKETS_S, UNDECLARED)
    } else {
        (format!("osdt_{name}"), 1.0, COUNT_BUCKETS, UNDECLARED)
    }
}

fn render_histogram(
    out: &mut String,
    exposed: &str,
    per: f64,
    bounds: &[f64],
    h: &Histogram,
) {
    // `bounds` are in exposed units; the histogram recorded internal units.
    let internal: Vec<f64> = bounds.iter().map(|b| b * per).collect();
    let cum = h.cumulative_le(&internal);
    for (b, c) in bounds.iter().zip(&cum) {
        let _ = writeln!(out, "{exposed}_bucket{{le=\"{b}\"}} {c}");
    }
    let _ = writeln!(out, "{exposed}_bucket{{le=\"+Inf\"}} {}", h.n);
    let _ = writeln!(out, "{exposed}_sum {}", fmt_val(h.sum / per));
    let _ = writeln!(out, "{exposed}_count {}", h.n);
}

/// Render every metric from `sources` as one Prometheus exposition.
///
/// The synthetic `osdt_process_uptime_seconds` gauge is emitted once, from
/// the first source. If two sources carry the same family name the first
/// wins and later occurrences are skipped — Prometheus rejects duplicate
/// families, and the serving stack's sources (coordinator + profile
/// registry + endpoint-local) use disjoint names by construction.
pub fn render_prometheus(sources: &[&Registry]) -> String {
    let mut out = String::new();
    let mut seen: HashSet<String> = HashSet::new();

    if let Some(first) = sources.first() {
        let spec =
            spec_for("process_uptime_seconds", MetricKind::Gauge).unwrap();
        header(&mut out, spec.exposed, spec.kind, spec.help);
        let _ =
            writeln!(out, "{} {}", spec.exposed, fmt_val(first.uptime_secs()));
        seen.insert(spec.exposed.to_string());
    }

    for src in sources {
        for (name, c) in src.counters.lock().unwrap().iter() {
            let (exposed, per, help) = counter_family(name);
            if !seen.insert(exposed.clone()) {
                continue;
            }
            header(&mut out, &exposed, MetricKind::Counter, help);
            let v = c.load(Ordering::Relaxed);
            if per == 1.0 {
                let _ = writeln!(out, "{exposed} {v}");
            } else {
                let _ = writeln!(out, "{exposed} {}", fmt_val(v as f64 / per));
            }
        }
        for (name, g) in src.gauges.lock().unwrap().iter() {
            let (exposed, help) = gauge_family(name);
            if !seen.insert(exposed.clone()) {
                continue;
            }
            header(&mut out, &exposed, MetricKind::Gauge, help);
            let _ = writeln!(out, "{exposed} {}", g.load(Ordering::Relaxed));
        }
        for (name, (h, unit)) in src.histograms.lock().unwrap().iter() {
            let (exposed, per, bounds, help) = histogram_family(name, unit);
            if !seen.insert(exposed.clone()) {
                continue;
            }
            header(&mut out, &exposed, MetricKind::Histogram, help);
            render_histogram(&mut out, &exposed, per, bounds, &h.lock().unwrap());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::catalog;

    /// The satellite bugfix pin: a histogram recorded in microseconds must
    /// expose seconds, normalized by exact division (2_500_000 us -> 2.5).
    #[test]
    fn us_histograms_expose_exact_seconds() {
        let r = Registry::new();
        r.observe_us("request_latency", 2_500_000.0);
        let text = render_prometheus(&[&r]);
        assert!(
            text.contains("# TYPE osdt_request_latency_seconds histogram"),
            "{text}"
        );
        assert!(text.contains("osdt_request_latency_seconds_sum 2.5\n"), "{text}");
        assert!(text.contains("osdt_request_latency_seconds_count 1\n"), "{text}");
        // 2.5s cannot land at or below the 1s bound, and must be counted
        // by 5s (log-bucket edges make the exact 2.5 bound resolution-
        // dependent, so pin the neighbours).
        assert!(
            text.contains("osdt_request_latency_seconds_bucket{le=\"1\"} 0"),
            "{text}"
        );
        assert!(
            text.contains("osdt_request_latency_seconds_bucket{le=\"5\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("osdt_request_latency_seconds_bucket{le=\"+Inf\"} 1"),
            "{text}"
        );
    }

    #[test]
    fn us_counters_expose_seconds() {
        let r = Registry::new();
        r.add("model_exec_us", 3_250_000);
        let text = render_prometheus(&[&r]);
        assert!(text.contains("osdt_model_exec_seconds_total 3.25\n"), "{text}");
        assert!(!text.contains("model_exec_us"), "{text}");
    }

    #[test]
    fn unknown_names_get_derived_families() {
        let r = Registry::new();
        r.add("mystery", 3);
        r.add("mystery_time_us", 2_000_000);
        r.set_gauge("mystery_depth", -2);
        r.observe_us("mystery_wait", 1.0);
        let text = render_prometheus(&[&r]);
        assert!(text.contains("osdt_mystery_total 3\n"), "{text}");
        assert!(text.contains("osdt_mystery_time_seconds_total 2\n"), "{text}");
        assert!(text.contains("osdt_mystery_depth -2\n"), "{text}");
        assert!(text.contains("# TYPE osdt_mystery_wait_seconds histogram"), "{text}");
        assert!(text.contains(UNDECLARED), "{text}");
    }

    #[test]
    fn batch_occupancy_gauge_and_histogram_are_distinct_families() {
        let r = Registry::new();
        r.set_gauge("batch_occupancy", 3);
        r.observe("batch_occupancy", 3.0);
        let text = render_prometheus(&[&r]);
        assert!(text.contains("# TYPE osdt_batch_occupancy gauge"), "{text}");
        assert!(
            text.contains("# TYPE osdt_batch_occupancy_per_step histogram"),
            "{text}"
        );
        assert!(
            text.contains("osdt_batch_occupancy_per_step_bucket{le=\"4\"} 1"),
            "{text}"
        );
    }

    #[test]
    fn multi_source_emits_each_family_once() {
        let a = Registry::new();
        let b = Registry::new();
        a.add("tokens_generated", 5);
        b.add("tokens_generated", 7);
        b.add("profile_hits", 1);
        let text = render_prometheus(&[&a, &b]);
        let uptime_lines = text
            .lines()
            .filter(|l| l.starts_with("osdt_process_uptime_seconds"))
            .count();
        assert_eq!(uptime_lines, 1, "{text}");
        assert_eq!(
            text.matches("# TYPE osdt_tokens_generated_total counter").count(),
            1,
            "{text}"
        );
        assert!(text.contains("osdt_tokens_generated_total 5\n"), "{text}");
        assert!(!text.contains("osdt_tokens_generated_total 7"), "{text}");
        assert!(text.contains("osdt_profile_hits_total 1\n"), "{text}");
    }

    #[test]
    fn catalog_is_well_formed() {
        let mut seen = std::collections::HashSet::new();
        for s in catalog() {
            assert!(seen.insert(s.exposed), "duplicate family {}", s.exposed);
            assert!(s.exposed.starts_with("osdt_"), "{}", s.exposed);
            match s.kind {
                MetricKind::Counter => {
                    assert!(s.exposed.ends_with("_total"), "{}", s.exposed)
                }
                _ => assert!(!s.exposed.ends_with("_total"), "{}", s.exposed),
            }
            if s.kind == MetricKind::Histogram {
                assert!(!s.buckets.is_empty(), "{}", s.exposed);
                for w in s.buckets.windows(2) {
                    assert!(w[1] > w[0], "{} buckets not ascending", s.exposed);
                }
            } else {
                assert!(s.buckets.is_empty(), "{}", s.exposed);
            }
            assert!(s.per == 1.0 || s.per == 1e6, "{}", s.exposed);
            // seconds-normalized families must say so in the name
            if s.per == 1e6 {
                assert!(s.exposed.contains("_seconds"), "{}", s.exposed);
            }
        }
    }
}
