//! Workload substrate: loading the synthetic eval datasets emitted by the
//! python build, and open/closed-loop request generation for the serving
//! benches.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{parse_jsonl, Json};
use crate::util::rng::Rng;

pub const TASKS: [&str; 3] = ["synth-qa", "synth-math", "synth-code"];

/// One evaluation example (mirror of data.py's JSONL schema).
#[derive(Clone, Debug)]
pub struct Example {
    pub task: String,
    pub prompt: String,
    /// Ground-truth final answer (task-specific interpretation; see eval/).
    pub answer: String,
    /// synth-code: operation + input for functional evaluation.
    pub code_op: Option<(String, String)>,
}

impl Example {
    pub fn from_json(j: &Json) -> Result<Example> {
        let s = |k: &str| -> Result<String> {
            j.req(k)
                .map_err(anyhow::Error::msg)?
                .as_str()
                .map(str::to_string)
                .with_context(|| format!("{k} not a string"))
        };
        let task = s("task")?;
        let code_op = if task == "synth-code" {
            let meta = j.req("meta").map_err(anyhow::Error::msg)?;
            let g = |k: &str| -> Result<String> {
                meta.req(k)
                    .map_err(anyhow::Error::msg)?
                    .as_str()
                    .map(str::to_string)
                    .with_context(|| format!("meta.{k} not a string"))
            };
            Some((g("op")?, g("input")?))
        } else {
            None
        };
        Ok(Example { task, prompt: s("prompt")?, answer: s("answer")?, code_op })
    }
}

/// A task's eval split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: String,
    pub examples: Vec<Example>,
}

impl Dataset {
    /// Load `<dir>/<task>.eval.jsonl`.
    pub fn load(data_dir: impl AsRef<Path>, task: &str) -> Result<Dataset> {
        if !TASKS.contains(&task) {
            bail!("unknown task {task:?} (expected one of {TASKS:?})");
        }
        let path = data_dir.as_ref().join(format!("{task}.eval.jsonl"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let examples = parse_jsonl(&text)?
            .iter()
            .map(Example::from_json)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("parsing {}", path.display()))?;
        if examples.is_empty() {
            bail!("dataset {task} is empty");
        }
        for e in &examples {
            if e.task != task {
                bail!("example task {:?} != dataset {task:?}", e.task);
            }
        }
        Ok(Dataset { task: task.to_string(), examples })
    }

    pub fn load_all(data_dir: impl AsRef<Path>) -> Result<Vec<Dataset>> {
        TASKS
            .iter()
            .map(|t| Dataset::load(data_dir.as_ref(), t))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }
}

/// A timed request for the serving benches.
#[derive(Clone, Debug)]
pub struct TimedRequest {
    /// Offset from trace start, seconds.
    pub at: f64,
    pub task: String,
    pub prompt: String,
}

/// Open-loop Poisson arrival trace over a dataset (rate = requests/sec).
pub fn poisson_trace(ds: &Dataset, rate: f64, n: usize, seed: u64) -> Vec<TimedRequest> {
    assert!(rate > 0.0 && n > 0);
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            let ex = rng.choose(&ds.examples);
            TimedRequest { at: t, task: ds.task.clone(), prompt: ex.prompt.clone() }
        })
        .collect()
}

/// Round-robin mixture trace across several datasets (multi-tenant load).
pub fn mixed_trace(
    datasets: &[Dataset],
    rate: f64,
    n: usize,
    seed: u64,
) -> Vec<TimedRequest> {
    assert!(!datasets.is_empty());
    let mut rng = Rng::new(seed ^ 0x5EED);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exponential(rate);
            let ds = &datasets[i % datasets.len()];
            let ex = rng.choose(&ds.examples);
            TimedRequest { at: t, task: ds.task.clone(), prompt: ex.prompt.clone() }
        })
        .collect()
}

/// Mixed-length heavy-tail trace (DESIGN.md §15): `heavy` requests drawn
/// from the `long` dataset land immediately behind the first `short`
/// arrival, and every other request draws from `short`. This is the
/// adversarial shape for FIFO admission — the tail jobs hit the queue just
/// as the backlog forms, so under FIFO the entire short class waits behind
/// them, while predicted-cost admission defers exactly the tail. Arrivals
/// are the same seeded Poisson process as `mixed_trace`.
pub fn heavy_tail_trace(
    short: &Dataset,
    long: &Dataset,
    rate: f64,
    n: usize,
    heavy: usize,
    seed: u64,
) -> Vec<TimedRequest> {
    assert!(heavy < n, "tail ({heavy}) must be smaller than the trace ({n})");
    let mut rng = Rng::new(seed ^ 0x7A11);
    let mut t = 0.0;
    (0..n)
        .map(|i| {
            t += rng.exponential(rate);
            let ds = if (1..=heavy).contains(&i) { long } else { short };
            let ex = rng.choose(&ds.examples);
            TimedRequest { at: t, task: ds.task.clone(), prompt: ex.prompt.clone() }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_dataset() -> Dataset {
        Dataset {
            task: "synth-math".into(),
            examples: (0..5)
                .map(|i| Example {
                    task: "synth-math".into(),
                    prompt: format!("Q: {i}+1=?"),
                    answer: format!("{}", i + 1),
                    code_op: None,
                })
                .collect(),
        }
    }

    #[test]
    fn example_from_json() {
        let j = Json::parse(
            r#"{"task":"synth-code","prompt":"op: rev | in: ab","completion":"out: ba",
                "answer":"ba","meta":{"op":"rev","input":"ab"}}"#,
        )
        .unwrap();
        let e = Example::from_json(&j).unwrap();
        assert_eq!(e.answer, "ba");
        assert_eq!(e.code_op, Some(("rev".into(), "ab".into())));
    }

    #[test]
    fn example_rejects_missing_fields() {
        let j = Json::parse(r#"{"task":"synth-qa"}"#).unwrap();
        assert!(Example::from_json(&j).is_err());
    }

    #[test]
    fn poisson_trace_monotone_and_rate() {
        let ds = demo_dataset();
        let trace = poisson_trace(&ds, 10.0, 2000, 1);
        assert_eq!(trace.len(), 2000);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        let total = trace.last().unwrap().at;
        let rate = 2000.0 / total;
        assert!((rate - 10.0).abs() < 1.0, "rate {rate}");
    }

    #[test]
    fn mixed_trace_alternates_tasks() {
        let mut qa = demo_dataset();
        qa.task = "synth-qa".into();
        for e in &mut qa.examples {
            e.task = "synth-qa".into();
        }
        let trace = mixed_trace(&[demo_dataset(), qa], 5.0, 10, 3);
        assert_eq!(trace[0].task, "synth-math");
        assert_eq!(trace[1].task, "synth-qa");
    }

    #[test]
    fn heavy_tail_trace_places_tail_behind_first_arrival() {
        let mut long = demo_dataset();
        long.task = "synth-long".into();
        let trace = heavy_tail_trace(&demo_dataset(), &long, 100.0, 10, 2, 7);
        assert_eq!(trace.len(), 10);
        for w in trace.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        for (i, r) in trace.iter().enumerate() {
            let want = if (1..=2).contains(&i) { "synth-long" } else { "synth-math" };
            assert_eq!(r.task, want, "request {i}");
        }
    }

    #[test]
    fn load_rejects_unknown_task() {
        assert!(Dataset::load("/nonexistent", "nope").is_err());
    }

    #[test]
    fn loads_real_datasets_when_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join("data");
        if !dir.exists() {
            eprintln!("skipping: artifacts/data absent");
            return;
        }
        for ds in Dataset::load_all(&dir).unwrap() {
            assert!(ds.len() >= 100, "{} too small", ds.task);
            for e in &ds.examples {
                assert!(!e.prompt.is_empty());
                assert!(!e.answer.is_empty());
                if ds.task == "synth-code" {
                    assert!(e.code_op.is_some());
                }
            }
        }
    }
}
