//! `osdt` — CLI for the OSDT diffusion-LM serving stack.
//!
//! Subcommands:
//!   generate     decode one prompt and print the completion
//!   serve        run the TCP JSON-line server (one replica process)
//!   serve-fleet  run the fleet router in front of replica processes
//!   fleet        supervise a fleet: start|run|status|stop|rolling-restart|smoke
//!   eval         accuracy/throughput of a policy over a task's eval split
//!   calibrate    run Phase-1 calibration for a task and persist the profile
//!   traces       dump confidence trajectories (Figure 1 raw data)
//!   info         print model/artifact metadata
//!
//! Common flags: --artifacts DIR (default "artifacts"), --policy SPEC,
//! --task NAME, --cache, --n N. Policy specs: see `config` module docs.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use osdt::bench::{self, RunOpts};
use osdt::cache::{CacheConfig, Residency};
use osdt::config::{Args, ServerConfig};
use osdt::coordinator::{Coordinator, CoordinatorConfig};
use osdt::decode::Engine;
use osdt::fleet::{
    FleetConfig, FleetRouter, FleetState, ReplicaSpec, RouterConfig,
    StaleState, Supervisor,
};
use osdt::model::ModelConfig;
use osdt::policy::{
    Calibrator, DynamicMode, Metric, ProfileRecord, ProfileRegistry, ProfileStore,
    RegistryConfig, StaticThreshold,
};
use osdt::runtime::ModelRuntime;
use osdt::server::{Client, RetryPolicy, Server};
use osdt::sim::{Chaos, SimModel};
use osdt::tokenizer::Tokenizer;
use osdt::util::json::Json;
use osdt::util::procfs::{pid_alive, send_signal};
use osdt::workload::Dataset;

const VALUE_FLAGS: &[&str] = &[
    "artifacts", "policy", "task", "prompt", "n", "addr", "workers",
    "max-batch", "batch-wait-ms", "mode", "metric", "profile-dir", "tau",
    "refresh-interval", "save", "drift-floor", "ema-alpha", "cache-residency",
    "metrics-addr", "kv-page-len", "prefix-sharing", "step-elision",
    "elide-floor", "admission", "align-band", "shed-watermark", "slo-ms",
    // serving robustness / fleet tier
    "backend", "sim-seed", "chaos-die-after", "fleet-locks",
    "conn-timeout-ms", "replica", "health-interval-ms", "request-timeout-ms",
    "max-retries", "shed-outstanding", "dir", "replicas", "router-addr",
    "control-addr", "heartbeat-ms", "replica-arg",
];

fn main() {
    osdt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, VALUE_FLAGS)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "serve-fleet" => cmd_serve_fleet(&args),
        "fleet" => cmd_fleet(&args),
        "eval" => cmd_eval(&args),
        "calibrate" => cmd_calibrate(&args),
        "traces" => cmd_traces(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `osdt help`"),
    }
}

const HELP: &str = "\
osdt — One-Shot Dynamic Thresholding serving stack

USAGE: osdt <COMMAND> [FLAGS]

COMMANDS:
  generate   --prompt 'Q: 3+4=?' [--policy static:0.9] [--cache]
  serve      [--addr 127.0.0.1:7474] [--workers 1] [--max-batch 4] [--cache]
             [--profile-dir DIR] [--drift-floor 0.95] [--ema-alpha 0]
             [--metrics-addr HOST:PORT] [--backend pjrt|sim]
             [--conn-timeout-ms 30000] [--fleet-locks on|off]
  serve-fleet --replica HOST:PORT [--replica ...] [--addr 127.0.0.1:7575]
             [--health-interval-ms 500] [--max-retries 3]
             [--request-timeout-ms 30000] [--shed-outstanding 0]
  fleet      start|run|status|stop|rolling-restart|smoke [--dir fleet-state]
             [--replicas 2] [--backend sim] [--heartbeat-ms 500] [--force]
  eval       --task synth-math [--policy osdt:block:q1:0.75:0.2] [--n 64]
  calibrate  --task synth-math [--mode block] [--metric q1] [--profile-dir profiles]
  traces     --task synth-math [--n 8] [--tau 0.9]
  info

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --cache           enable the Fast-dLLM dual KV cache path
  --refresh-interval N  cache staleness bound (window steps; 0 = block only)
  --cache-residency R   where K/V lives between refreshes: device (default,
                        zero per-step host round trip) or host (legacy A/B)
  --kv-page-len N       page the KV cache: N sequence positions per page
                        (0 = whole-sequence handles, the default)
  --prefix-sharing on|off  share block-0 refresh KV pages + outputs across
                        requests with identical prompts (implies paging)

PROFILE REGISTRY (serve):
  --profile-dir DIR    persist calibrated profiles; warm-start on restart
  --drift-floor F      signature-drift cosine floor for recalibration
  --ema-alpha A        registry-level EMA threshold refinement (0 = one-shot)

STEP ELISION (serve):
  --step-elision on|off  skip window passes the calibrated acceptance
                        trajectory predicts are empty; retire blocks early
                        (Phase-2 OSDT decodes only; default off)
  --elide-floor F      predicted acceptances below F count as an empty step

PREDICTIVE SCHEDULING (serve):
  --admission predictive|fifo  admission order: forecast-cost priority with
                        wait-time aging (default) or plain FIFO
  --align-band N       co-schedule rows whose predicted remaining window
                        passes are within N of each other (0 = off)
  --shed-watermark N   shed new requests once the predicted backlog (queue
                        + active, in forward passes) would exceed N (0 = off)
  --slo-ms MS          default per-request deadline budget; requests whose
                        forecast can't meet it are shed with retry_after_ms

FLEET TIER (serve-fleet / fleet, DESIGN.md §16):
  --backend sim|pjrt   replica model backend; `sim` needs no artifacts and
                        is what `fleet smoke` and the chaos tests use
  --sim-seed N         shared sim seed (replicas decode token-identically)
  --chaos-die-after N  abort this replica process on its N-th forward pass
                        (deterministic mid-decode death for chaos tests)
  --fleet-locks on|off cross-process calibration leases + generation-counter
                        invalidation through the shared --profile-dir
  --conn-timeout-ms MS per-connection socket timeout on `serve` (0 = off)
  --dir DIR            fleet home: state.json, shared profiles/, logs
  --replicas N         replica processes to supervise (default 2)
  --heartbeat-ms MS    supervisor heartbeat / dead-replica detection period
  --force              start even if state.json names a live supervisor

POLICY SPECS:
  sequential[:k] | static[:tau] | factor[:f] | osdt:MODE:METRIC:KAPPA:EPS
  e.g. osdt:step-block:q2:0.75:0.2
";

fn cache_residency(args: &Args) -> Result<Residency> {
    Residency::parse(args.get_or("cache-residency", Residency::default().as_str()))
}

fn load_stack(args: &Args) -> Result<(ModelConfig, ModelRuntime, Tokenizer)> {
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = ModelConfig::load(dir)
        .with_context(|| format!("loading artifacts from {dir} (run `make artifacts`?)"))?;
    let rt = ModelRuntime::load(&cfg)?;
    rt.set_residency(cache_residency(args)?);
    let tok = Tokenizer::from_config(&cfg)?;
    Ok((cfg, rt, tok))
}

fn cache_config(args: &Args) -> Result<CacheConfig> {
    if args.has("cache") {
        let r = args.get_parse::<usize>("refresh-interval", 0)?;
        let base = if r > 0 {
            CacheConfig::with_refresh_interval(r)
        } else {
            CacheConfig::block_boundary()
        };
        let sharing = match args.get_or("prefix-sharing", "off") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --prefix-sharing {other:?} (on|off)"),
        };
        Ok(base
            .paged(args.get_parse::<usize>("kv-page-len", 0)?)
            .with_prefix_sharing(sharing))
    } else {
        Ok(CacheConfig::disabled())
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.get("prompt").context("--prompt required")?.to_string();
    let policy_spec = args.get_or("policy", "static:0.9");
    let (cfg, rt, tok) = load_stack(args)?;
    let engine = Engine::with_cache(&rt, cache_config(args)?);
    let spec = osdt::config::parse_policy_spec(policy_spec)?;
    if spec.needs_profile() {
        bail!("`generate` decodes a single prompt; OSDT needs a profile — use `eval` or `serve`");
    }
    let layout = tok.layout_prompt(&cfg, &prompt)?;
    let t0 = std::time::Instant::now();
    let res = engine.decode(layout, spec.build()?.as_ref())?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", tok.decode_until_eos(res.gen_tokens(&cfg)));
    eprintln!(
        "steps={} full={} window={} latency={:.1}ms tokens/s={:.1}",
        res.steps,
        res.full_passes,
        res.window_passes,
        dt * 1e3,
        cfg.gen_len as f64 / dt
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let defaults = ServerConfig::default();
    let scfg = ServerConfig {
        addr: args.get_or("addr", &defaults.addr).to_string(),
        workers: args.get_parse("workers", defaults.workers)?,
        max_batch: args.get_parse("max-batch", defaults.max_batch)?,
        batch_wait_ms: args.get_parse("batch-wait-ms", defaults.batch_wait_ms)?,
        profile_dir: args.get("profile-dir").map(std::path::PathBuf::from),
        drift_floor: args.get_parse("drift-floor", defaults.drift_floor)?,
        ema_alpha: args.get_parse("ema-alpha", defaults.ema_alpha)?,
        metrics_addr: args.get("metrics-addr").map(String::from),
        step_elision: match args.get_or("step-elision", "off") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --step-elision {other:?} (on|off)"),
        },
        elide_floor: args.get_parse("elide-floor", defaults.elide_floor)?,
        predictive: match args.get_or("admission", "predictive") {
            "predictive" => true,
            "fifo" => false,
            other => bail!("unknown --admission {other:?} (predictive|fifo)"),
        },
        align_band: args.get_parse("align-band", defaults.align_band)?,
        shed_watermark: args.get_parse("shed-watermark", defaults.shed_watermark)?,
        slo_ms: args.get_parse("slo-ms", defaults.slo_ms)?,
        conn_timeout_ms: args.get_parse("conn-timeout-ms", defaults.conn_timeout_ms)?,
    };
    let ccfg = CoordinatorConfig {
        workers: scfg.workers,
        max_batch: scfg.max_batch,
        batch_wait: std::time::Duration::from_millis(scfg.batch_wait_ms),
        cache: cache_config(args)?,
        step_elision: scfg.step_elision,
        elide_floor: scfg.elide_floor,
        predictive: scfg.predictive,
        align_band: scfg.align_band,
        shed_watermark: scfg.shed_watermark,
        slo_ms: scfg.slo_ms,
        ..CoordinatorConfig::default()
    };
    let rcfg = RegistryConfig {
        drift_floor: scfg.drift_floor,
        ema_alpha: scfg.ema_alpha,
        // Fleet replicas share one --profile-dir: cross-process leases +
        // generation-counter invalidation (DESIGN.md §16).
        cross_process: match args.get_or("fleet-locks", "off") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --fleet-locks {other:?} (on|off)"),
        },
        ..RegistryConfig::default()
    };
    let registry = Arc::new(match &scfg.profile_dir {
        Some(pdir) => {
            let reg = ProfileRegistry::with_store(ProfileStore::new(pdir)?, rcfg)?;
            log::info!(
                "profile registry: {} profile(s) warm-started from {}",
                reg.len(),
                pdir.display()
            );
            reg
        }
        None => ProfileRegistry::with_config(rcfg),
    });
    let coord = match args.get_or("backend", "pjrt") {
        "pjrt" => {
            let dir = args.get_or("artifacts", "artifacts").to_string();
            let cfg = ModelConfig::load(&dir)?;
            let residency = cache_residency(args)?;
            Arc::new(Coordinator::start_with_registry(
                ccfg,
                cfg,
                registry,
                move |wid| {
                    log::info!("worker {wid}: loading runtime from {dir} ({residency:?} KV residency)");
                    let cfg = ModelConfig::load(&dir)?;
                    let rt = ModelRuntime::load(&cfg)?;
                    rt.set_residency(residency);
                    Ok(rt)
                },
            )?)
        }
        // Artifact-free simulator backend: the fleet smoke/chaos tests
        // run real replica *processes* without real model weights.
        "sim" => {
            let sim_seed = args.get_parse("sim-seed", 5u64)?;
            let die_after = args.get_parse("chaos-die-after", 0u64)?;
            let chaos = Chaos::new();
            if die_after > 0 {
                chaos.die_after(die_after);
                log::warn!("chaos armed: abort on forward pass #{die_after}");
            }
            Arc::new(Coordinator::start_with_registry(
                ccfg,
                osdt::model::fixtures::tiny_config(),
                registry,
                move |_wid| {
                    Ok(SimModel::math_like(sim_seed).with_chaos(chaos.clone()))
                },
            )?)
        }
        other => bail!("unknown --backend {other:?} (pjrt|sim)"),
    };
    // Prometheus exposition reads the same registries the coordinator and
    // profile registry mutate — clone the Arcs before `coord` moves into
    // the TCP server.
    let metric_sources = vec![coord.metrics.clone(), coord.registry.metrics().clone()];
    let server = Server::start_with_timeout(
        &scfg.addr,
        coord,
        Duration::from_millis(scfg.conn_timeout_ms),
    )?;
    println!("osdt serving on {}", server.addr);
    let _metrics = match &scfg.metrics_addr {
        Some(addr) => {
            let m = osdt::metrics::http::MetricsServer::start(addr, metric_sources)?;
            println!("metrics on http://{}/metrics", m.addr);
            Some(m)
        }
        None => None,
    };
    // serve until killed
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_serve_fleet(args: &Args) -> Result<()> {
    let replicas: Vec<ReplicaSpec> = args
        .get_all("replica")
        .into_iter()
        .enumerate()
        .map(|(id, addr)| ReplicaSpec { id, addr: addr.to_string() })
        .collect();
    ensure!(
        !replicas.is_empty(),
        "serve-fleet needs at least one --replica HOST:PORT"
    );
    let d = RouterConfig::default();
    let n = replicas.len();
    let router = FleetRouter::start(RouterConfig {
        addr: args.get_or("addr", "127.0.0.1:7575").to_string(),
        replicas,
        health_interval: Duration::from_millis(
            args.get_parse("health-interval-ms", 500u64)?,
        ),
        request_timeout: Duration::from_millis(
            args.get_parse("request-timeout-ms", 30_000u64)?,
        ),
        max_retries: args.get_parse("max-retries", d.max_retries)?,
        shed_outstanding: args
            .get_parse("shed-outstanding", d.shed_outstanding)?,
        ..d
    })?;
    println!("osdt fleet router on {} ({n} replicas)", router.addr);
    // route until killed (the router lives in background threads)
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

// ---------------------------------------------------------------------------
// fleet: supervise a router + N replica processes (DESIGN.md §16)
// ---------------------------------------------------------------------------

fn cmd_fleet(args: &Args) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("run") => fleet_run(args),
        Some("start") => fleet_start(args),
        Some("status") => fleet_status(args),
        Some("stop") => fleet_stop(args),
        Some("rolling-restart") => fleet_rolling_restart(args),
        Some("smoke") => fleet_smoke(args),
        other => bail!(
            "fleet needs a subcommand (got {other:?}): \
             start|run|status|stop|rolling-restart|smoke"
        ),
    }
}

fn fleet_config(args: &Args) -> Result<FleetConfig> {
    let d = FleetConfig::default();
    Ok(FleetConfig {
        dir: PathBuf::from(args.get_or("dir", "fleet-state")),
        replicas: args.get_parse("replicas", d.replicas)?,
        backend: args.get_or("backend", "sim").to_string(),
        sim_seed: args.get_parse("sim-seed", d.sim_seed)?,
        router_addr: args.get_or("router-addr", "127.0.0.1:0").to_string(),
        control_addr: args.get_or("control-addr", "127.0.0.1:0").to_string(),
        heartbeat: Duration::from_millis(
            args.get_parse("heartbeat-ms", d.heartbeat.as_millis() as u64)?,
        ),
        max_retries: args.get_parse("max-retries", d.max_retries)?,
        request_timeout: Duration::from_millis(args.get_parse(
            "request-timeout-ms",
            d.request_timeout.as_millis() as u64,
        )?),
        replica_args: args
            .get_all("replica-arg")
            .into_iter()
            .map(String::from)
            .collect(),
        force: args.has("force"),
        ..d
    })
}

/// Run the supervisor in the foreground (what `fleet start` detaches).
fn fleet_run(args: &Args) -> Result<()> {
    let sup = Supervisor::start(fleet_config(args)?)?;
    println!(
        "fleet supervisor up: control {} router {}",
        sup.control_addr, sup.router_addr
    );
    while !sup.stopped() {
        std::thread::sleep(Duration::from_millis(200));
    }
    sup.shutdown();
    println!("fleet supervisor stopped");
    Ok(())
}

/// Detach a `fleet run` supervisor and wait for its `state.json`.
fn fleet_start(args: &Args) -> Result<()> {
    use std::os::unix::process::CommandExt;
    let cfg = fleet_config(args)?;
    if matches!(FleetState::staleness(&cfg.dir)?, StaleState::Live) && !cfg.force
    {
        bail!(
            "a supervisor is already running for {} (fleet stop first, \
             or --force)",
            cfg.dir.display()
        );
    }
    std::fs::create_dir_all(&cfg.dir)?;
    let log = std::fs::File::options()
        .create(true)
        .append(true)
        .open(cfg.dir.join("supervisor.log"))?;
    let err = log.try_clone()?;
    let mut cmd = std::process::Command::new(std::env::current_exe()?);
    cmd.args([
        "fleet".to_string(),
        "run".to_string(),
        format!("--dir={}", cfg.dir.display()),
        format!("--replicas={}", cfg.replicas),
        format!("--backend={}", cfg.backend),
        format!("--sim-seed={}", cfg.sim_seed),
        format!("--router-addr={}", cfg.router_addr),
        format!("--control-addr={}", cfg.control_addr),
        format!("--heartbeat-ms={}", cfg.heartbeat.as_millis()),
        format!("--max-retries={}", cfg.max_retries),
        format!("--request-timeout-ms={}", cfg.request_timeout.as_millis()),
    ])
    .stdin(std::process::Stdio::null())
    .stdout(std::process::Stdio::from(log))
    .stderr(std::process::Stdio::from(err))
    .process_group(0);
    if cfg.force {
        cmd.arg("--force");
    }
    for ra in &cfg.replica_args {
        cmd.arg(format!("--replica-arg={ra}"));
    }
    let child = cmd.spawn().context("spawning fleet run")?;
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(Some(st)) = FleetState::load(&cfg.dir) {
            // Only trust a file written by *our* child (an older stale
            // file may still be sitting there).
            if st.supervisor_pid == child.id() {
                println!(
                    "fleet up: supervisor pid {} control {} router {}",
                    st.supervisor_pid, st.control_addr, st.router_addr
                );
                return Ok(());
            }
        }
        ensure!(
            std::time::Instant::now() < deadline,
            "supervisor did not come up (see {}/supervisor.log)",
            cfg.dir.display()
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Resolve the live control socket from `state.json`.
fn fleet_control_addr(args: &Args) -> Result<String> {
    let dir = PathBuf::from(args.get_or("dir", "fleet-state"));
    let st = FleetState::load(&dir)?.with_context(|| {
        format!("no state.json under {} (is the fleet running?)", dir.display())
    })?;
    ensure!(
        pid_alive(st.supervisor_pid),
        "state.json names a dead supervisor (pid {}) — stale state; \
         `fleet start` recovers it",
        st.supervisor_pid
    );
    Ok(st.control_addr)
}

fn fleet_status(args: &Args) -> Result<()> {
    let addr = fleet_control_addr(args)?;
    let j = osdt::fleet::roundtrip_line(
        &addr,
        r#"{"cmd":"fleet-status"}"#,
        Duration::from_secs(5),
    )?;
    if let Some(e) = j.get("error").and_then(Json::as_str) {
        bail!("supervisor error: {e}");
    }
    println!(
        "supervisor pid {}  profile generation {}",
        j.get("supervisor_pid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        j.get("profile_generation").and_then(Json::as_f64).unwrap_or(0.0)
            as u64,
    );
    if let Some(r) = j.get("router") {
        println!(
            "router   pid {:>7}  {}  alive={}",
            r.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            r.get("addr").and_then(Json::as_str).unwrap_or("?"),
            r.get("alive").and_then(Json::as_bool).unwrap_or(false),
        );
    }
    for row in j.get("replicas").and_then(Json::as_arr).unwrap_or(&[]) {
        println!(
            "replica {} pid {:>7}  {}  alive={} respawns={}",
            row.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            row.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            row.get("addr").and_then(Json::as_str).unwrap_or("?"),
            row.get("alive").and_then(Json::as_bool).unwrap_or(false),
            row.get("respawns").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        );
    }
    Ok(())
}

fn fleet_stop(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get_or("dir", "fleet-state"));
    let st = FleetState::load(&dir)?
        .with_context(|| format!("no state.json under {}", dir.display()))?;
    let addr = fleet_control_addr(args)?;
    let _ = osdt::fleet::roundtrip_line(
        &addr,
        r#"{"cmd":"stop"}"#,
        Duration::from_secs(5),
    )?;
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while pid_alive(st.supervisor_pid) {
        ensure!(
            std::time::Instant::now() < deadline,
            "supervisor pid {} did not exit",
            st.supervisor_pid
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    println!("fleet stopped");
    Ok(())
}

fn fleet_rolling_restart(args: &Args) -> Result<()> {
    let addr = fleet_control_addr(args)?;
    // Serialized drains can legitimately take a while under load.
    let j = osdt::fleet::roundtrip_line(
        &addr,
        r#"{"cmd":"rolling-restart"}"#,
        Duration::from_secs(300),
    )?;
    if let Some(e) = j.get("error").and_then(Json::as_str) {
        bail!("rolling restart failed: {e}");
    }
    println!(
        "rolling restart complete: {} replica(s) cycled",
        j.get("restarted").and_then(Json::as_f64).unwrap_or(0.0) as u64
    );
    Ok(())
}

/// Self-contained end-to-end check: start a 2-replica sim fleet in a
/// temp dir, SIGKILL one replica mid-service, assert transparent
/// failover and respawn, tear everything down. Exits non-zero on any
/// violated invariant — `scripts/check_rust.sh fleet-smoke` runs this.
fn fleet_smoke(args: &Args) -> Result<()> {
    let base = fleet_config(args)?;
    let dir = std::env::temp_dir()
        .join(format!("osdt-fleet-smoke-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = FleetConfig {
        dir: dir.clone(),
        backend: "sim".into(),
        replicas: base.replicas.max(2),
        heartbeat: Duration::from_millis(250),
        respawn_base: Duration::from_millis(100),
        respawn_max: Duration::from_millis(500),
        request_timeout: Duration::from_secs(10),
        ..base
    };
    println!(
        "fleet smoke: {} sim replicas under {}",
        cfg.replicas,
        dir.display()
    );
    let sup = Supervisor::start(cfg)?;
    let result = fleet_smoke_run(&sup, &dir);
    sup.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    result?;
    println!("fleet smoke: PASS");
    Ok(())
}

fn fleet_smoke_run(sup: &Supervisor, dir: &std::path::Path) -> Result<()> {
    ensure!(
        sup.wait_all_healthy(Duration::from_secs(30)),
        "fleet never became healthy"
    );
    let retry = RetryPolicy {
        max_retries: 5,
        backoff_base: Duration::from_millis(50),
        backoff_max: Duration::from_millis(400),
        seed: 1,
    };
    let mut c = Client::connect(sup.router_addr.as_str())?;
    let baseline =
        c.generate_with_retry("synth-math", "Q: 6+7=?", "static:0.9", &retry)?;
    ensure!(baseline.error.is_none(), "baseline: {:?}", baseline.error);
    let victim = FleetState::load(dir)?
        .context("state.json missing")?
        .replicas[0]
        .pid;
    println!("fleet smoke: SIGKILL replica 0 (pid {victim})");
    ensure!(send_signal(victim, "KILL"), "kill {victim} failed");
    // Failover: requests keep succeeding, tokens stay identical (shared
    // sim seed), because the router retries on the survivor.
    for i in 0..5 {
        let r = c.generate_with_retry(
            "synth-math",
            "Q: 6+7=?",
            "static:0.9",
            &retry,
        )?;
        ensure!(r.error.is_none(), "request {i} post-kill: {:?}", r.error);
        ensure!(
            r.completion == baseline.completion,
            "token corruption after failover (request {i})"
        );
    }
    println!("fleet smoke: failover OK (tokens identical)");
    // The supervisor must respawn replica 0 under a fresh pid.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let st = FleetState::load(dir)?.context("state.json missing")?;
        let r0 = st
            .replicas
            .iter()
            .find(|r| r.id == 0)
            .context("replica 0 missing from state.json")?;
        if r0.pid != victim && r0.pid != 0 && pid_alive(r0.pid) {
            println!(
                "fleet smoke: replica 0 respawned (pid {} -> {})",
                victim, r0.pid
            );
            return Ok(());
        }
        ensure!(
            std::time::Instant::now() < deadline,
            "replica 0 was never respawned"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let policy = args.get_or("policy", "osdt:block:q1:0.75:0.2");
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let opts = RunOpts {
        n: args.get_parse("n", 64usize)?,
        cache: cache_config(args)?,
        calibration_index: 0,
    };
    let row = bench::run_eval(&rt, &tok, &ds, policy, &opts)?;
    println!(
        "{}",
        bench::render_table(
            &["task", "policy", "n", "acc%", "tokens/s", "steps", "lat ms", "cal ms"],
            &[vec![
                row.task,
                row.policy,
                row.n.to_string(),
                format!("{:.2}", row.accuracy * 100.0),
                format!("{:.1}", row.tokens_per_sec),
                format!("{:.1}", row.mean_steps),
                format!("{:.1}", row.mean_latency_ms),
                format!("{:.1}", row.calibration_ms),
            ]],
        )
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let mode = match args.get_or("mode", "block") {
        "block" => DynamicMode::Block,
        "step-block" => DynamicMode::StepBlock,
        m => bail!("bad --mode {m:?}"),
    };
    let metric = Metric::parse(args.get_or("metric", "q1"))?;
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let engine = Engine::with_cache(&rt, cache_config(args)?);
    let layout = tok.layout_prompt(&cfg, &ds.examples[0].prompt)?;
    // calibration must see full per-step confidence vectors — force the
    // host decision path even when the fused window kernels are available
    let cal = engine.decode(
        layout,
        &osdt::policy::HostTraced(StaticThreshold::new(bench::CALIBRATION_TAU)),
    )?;
    let profile = Calibrator::calibrate(&cal.trace, mode, metric);
    let store = ProfileStore::new(args.get_or("profile-dir", "profiles"))?;
    let path =
        store.save(&ProfileRecord::new(task.as_str(), profile, cal.trace.signature()))?;
    println!("calibrated {task} ({} steps) -> {}", cal.steps, path.display());
    Ok(())
}

fn cmd_traces(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let n = args.get_parse("n", 8usize)?;
    let tau = args.get_parse("tau", bench::CALIBRATION_TAU)?;
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let traces = bench::collect_traces(&rt, &tok, &ds, n, tau)?;
    if let Some(path) = args.get("save") {
        let doc = osdt::util::json::Json::Arr(
            traces.iter().map(|t| t.to_json()).collect(),
        );
        std::fs::write(path, format!("{doc}\n"))?;
        eprintln!("saved {} traces -> {path}", traces.len());
    }
    let sig = bench::mean_signature(&traces);
    print!(
        "{}",
        bench::ascii_plot(&sig, 12, &format!("{task}: step-block mean confidence"))
    );
    let m = bench::cosine_matrix(&traces);
    print!(
        "{}",
        bench::ascii_heatmap(&m, 0.9, 1.0, &format!("{task}: pairwise cosine"))
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = ModelConfig::load(dir)?;
    println!("artifact dir : {}", cfg.artifact_dir.display());
    println!(
        "model        : d={} layers={} heads={} ff={} vocab={}",
        cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.vocab_size
    );
    println!(
        "sequence     : prompt {} + gen {} ({} blocks x {})",
        cfg.prompt_len, cfg.gen_len, cfg.num_blocks, cfg.block_len
    );
    println!("variants     :");
    for (name, v) in &cfg.variants {
        println!("  {name} (batch {})", v.batch);
    }
    Ok(())
}
