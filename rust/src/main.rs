//! `osdt` — CLI for the OSDT diffusion-LM serving stack.
//!
//! Subcommands:
//!   generate   decode one prompt and print the completion
//!   serve      run the TCP JSON-line server
//!   eval       accuracy/throughput of a policy over a task's eval split
//!   calibrate  run Phase-1 calibration for a task and persist the profile
//!   traces     dump confidence trajectories (Figure 1 raw data)
//!   info       print model/artifact metadata
//!
//! Common flags: --artifacts DIR (default "artifacts"), --policy SPEC,
//! --task NAME, --cache, --n N. Policy specs: see `config` module docs.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use osdt::bench::{self, RunOpts};
use osdt::cache::{CacheConfig, Residency};
use osdt::config::{Args, ServerConfig};
use osdt::coordinator::{Coordinator, CoordinatorConfig};
use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::{
    Calibrator, DynamicMode, Metric, ProfileRecord, ProfileRegistry, ProfileStore,
    RegistryConfig, StaticThreshold,
};
use osdt::runtime::ModelRuntime;
use osdt::server::Server;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

const VALUE_FLAGS: &[&str] = &[
    "artifacts", "policy", "task", "prompt", "n", "addr", "workers",
    "max-batch", "batch-wait-ms", "mode", "metric", "profile-dir", "tau",
    "refresh-interval", "save", "drift-floor", "ema-alpha", "cache-residency",
    "metrics-addr", "kv-page-len", "prefix-sharing", "step-elision",
    "elide-floor", "admission", "align-band", "shed-watermark", "slo-ms",
];

fn main() {
    osdt::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, VALUE_FLAGS)?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "calibrate" => cmd_calibrate(&args),
        "traces" => cmd_traces(&args),
        "info" => cmd_info(&args),
        "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; try `osdt help`"),
    }
}

const HELP: &str = "\
osdt — One-Shot Dynamic Thresholding serving stack

USAGE: osdt <COMMAND> [FLAGS]

COMMANDS:
  generate   --prompt 'Q: 3+4=?' [--policy static:0.9] [--cache]
  serve      [--addr 127.0.0.1:7474] [--workers 1] [--max-batch 4] [--cache]
             [--profile-dir DIR] [--drift-floor 0.95] [--ema-alpha 0]
             [--metrics-addr HOST:PORT]
  eval       --task synth-math [--policy osdt:block:q1:0.75:0.2] [--n 64]
  calibrate  --task synth-math [--mode block] [--metric q1] [--profile-dir profiles]
  traces     --task synth-math [--n 8] [--tau 0.9]
  info

COMMON FLAGS:
  --artifacts DIR   artifact directory (default: artifacts)
  --cache           enable the Fast-dLLM dual KV cache path
  --refresh-interval N  cache staleness bound (window steps; 0 = block only)
  --cache-residency R   where K/V lives between refreshes: device (default,
                        zero per-step host round trip) or host (legacy A/B)
  --kv-page-len N       page the KV cache: N sequence positions per page
                        (0 = whole-sequence handles, the default)
  --prefix-sharing on|off  share block-0 refresh KV pages + outputs across
                        requests with identical prompts (implies paging)

PROFILE REGISTRY (serve):
  --profile-dir DIR    persist calibrated profiles; warm-start on restart
  --drift-floor F      signature-drift cosine floor for recalibration
  --ema-alpha A        registry-level EMA threshold refinement (0 = one-shot)

STEP ELISION (serve):
  --step-elision on|off  skip window passes the calibrated acceptance
                        trajectory predicts are empty; retire blocks early
                        (Phase-2 OSDT decodes only; default off)
  --elide-floor F      predicted acceptances below F count as an empty step

PREDICTIVE SCHEDULING (serve):
  --admission predictive|fifo  admission order: forecast-cost priority with
                        wait-time aging (default) or plain FIFO
  --align-band N       co-schedule rows whose predicted remaining window
                        passes are within N of each other (0 = off)
  --shed-watermark N   shed new requests once the predicted backlog (queue
                        + active, in forward passes) would exceed N (0 = off)
  --slo-ms MS          default per-request deadline budget; requests whose
                        forecast can't meet it are shed with retry_after_ms

POLICY SPECS:
  sequential[:k] | static[:tau] | factor[:f] | osdt:MODE:METRIC:KAPPA:EPS
  e.g. osdt:step-block:q2:0.75:0.2
";

fn cache_residency(args: &Args) -> Result<Residency> {
    Residency::parse(args.get_or("cache-residency", Residency::default().as_str()))
}

fn load_stack(args: &Args) -> Result<(ModelConfig, ModelRuntime, Tokenizer)> {
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = ModelConfig::load(dir)
        .with_context(|| format!("loading artifacts from {dir} (run `make artifacts`?)"))?;
    let rt = ModelRuntime::load(&cfg)?;
    rt.set_residency(cache_residency(args)?);
    let tok = Tokenizer::from_config(&cfg)?;
    Ok((cfg, rt, tok))
}

fn cache_config(args: &Args) -> Result<CacheConfig> {
    if args.has("cache") {
        let r = args.get_parse::<usize>("refresh-interval", 0)?;
        let base = if r > 0 {
            CacheConfig::with_refresh_interval(r)
        } else {
            CacheConfig::block_boundary()
        };
        let sharing = match args.get_or("prefix-sharing", "off") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --prefix-sharing {other:?} (on|off)"),
        };
        Ok(base
            .paged(args.get_parse::<usize>("kv-page-len", 0)?)
            .with_prefix_sharing(sharing))
    } else {
        Ok(CacheConfig::disabled())
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.get("prompt").context("--prompt required")?.to_string();
    let policy_spec = args.get_or("policy", "static:0.9");
    let (cfg, rt, tok) = load_stack(args)?;
    let engine = Engine::with_cache(&rt, cache_config(args)?);
    let spec = osdt::config::parse_policy_spec(policy_spec)?;
    if spec.needs_profile() {
        bail!("`generate` decodes a single prompt; OSDT needs a profile — use `eval` or `serve`");
    }
    let layout = tok.layout_prompt(&cfg, &prompt)?;
    let t0 = std::time::Instant::now();
    let res = engine.decode(layout, spec.build()?.as_ref())?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", tok.decode_until_eos(res.gen_tokens(&cfg)));
    eprintln!(
        "steps={} full={} window={} latency={:.1}ms tokens/s={:.1}",
        res.steps,
        res.full_passes,
        res.window_passes,
        dt * 1e3,
        cfg.gen_len as f64 / dt
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let cfg = ModelConfig::load(&dir)?;
    let defaults = ServerConfig::default();
    let scfg = ServerConfig {
        addr: args.get_or("addr", &defaults.addr).to_string(),
        workers: args.get_parse("workers", defaults.workers)?,
        max_batch: args.get_parse("max-batch", defaults.max_batch)?,
        batch_wait_ms: args.get_parse("batch-wait-ms", defaults.batch_wait_ms)?,
        profile_dir: args.get("profile-dir").map(std::path::PathBuf::from),
        drift_floor: args.get_parse("drift-floor", defaults.drift_floor)?,
        ema_alpha: args.get_parse("ema-alpha", defaults.ema_alpha)?,
        metrics_addr: args.get("metrics-addr").map(String::from),
        step_elision: match args.get_or("step-elision", "off") {
            "on" => true,
            "off" => false,
            other => bail!("unknown --step-elision {other:?} (on|off)"),
        },
        elide_floor: args.get_parse("elide-floor", defaults.elide_floor)?,
        predictive: match args.get_or("admission", "predictive") {
            "predictive" => true,
            "fifo" => false,
            other => bail!("unknown --admission {other:?} (predictive|fifo)"),
        },
        align_band: args.get_parse("align-band", defaults.align_band)?,
        shed_watermark: args.get_parse("shed-watermark", defaults.shed_watermark)?,
        slo_ms: args.get_parse("slo-ms", defaults.slo_ms)?,
    };
    let ccfg = CoordinatorConfig {
        workers: scfg.workers,
        max_batch: scfg.max_batch,
        batch_wait: std::time::Duration::from_millis(scfg.batch_wait_ms),
        cache: cache_config(args)?,
        step_elision: scfg.step_elision,
        elide_floor: scfg.elide_floor,
        predictive: scfg.predictive,
        align_band: scfg.align_band,
        shed_watermark: scfg.shed_watermark,
        slo_ms: scfg.slo_ms,
        ..CoordinatorConfig::default()
    };
    let rcfg = RegistryConfig {
        drift_floor: scfg.drift_floor,
        ema_alpha: scfg.ema_alpha,
        ..RegistryConfig::default()
    };
    let registry = Arc::new(match &scfg.profile_dir {
        Some(pdir) => {
            let reg = ProfileRegistry::with_store(ProfileStore::new(pdir)?, rcfg)?;
            log::info!(
                "profile registry: {} profile(s) warm-started from {}",
                reg.len(),
                pdir.display()
            );
            reg
        }
        None => ProfileRegistry::with_config(rcfg),
    });
    let residency = cache_residency(args)?;
    let coord = Arc::new(Coordinator::start_with_registry(
        ccfg,
        cfg,
        registry,
        move |wid| {
            log::info!("worker {wid}: loading runtime from {dir} ({residency:?} KV residency)");
            let cfg = ModelConfig::load(&dir)?;
            let rt = ModelRuntime::load(&cfg)?;
            rt.set_residency(residency);
            Ok(rt)
        },
    )?);
    // Prometheus exposition reads the same registries the coordinator and
    // profile registry mutate — clone the Arcs before `coord` moves into
    // the TCP server.
    let metric_sources = vec![coord.metrics.clone(), coord.registry.metrics().clone()];
    let server = Server::start(&scfg.addr, coord)?;
    println!("osdt serving on {}", server.addr);
    let _metrics = match &scfg.metrics_addr {
        Some(addr) => {
            let m = osdt::metrics::http::MetricsServer::start(addr, metric_sources)?;
            println!("metrics on http://{}/metrics", m.addr);
            Some(m)
        }
        None => None,
    };
    // serve until killed
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let policy = args.get_or("policy", "osdt:block:q1:0.75:0.2");
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let opts = RunOpts {
        n: args.get_parse("n", 64usize)?,
        cache: cache_config(args)?,
        calibration_index: 0,
    };
    let row = bench::run_eval(&rt, &tok, &ds, policy, &opts)?;
    println!(
        "{}",
        bench::render_table(
            &["task", "policy", "n", "acc%", "tokens/s", "steps", "lat ms", "cal ms"],
            &[vec![
                row.task,
                row.policy,
                row.n.to_string(),
                format!("{:.2}", row.accuracy * 100.0),
                format!("{:.1}", row.tokens_per_sec),
                format!("{:.1}", row.mean_steps),
                format!("{:.1}", row.mean_latency_ms),
                format!("{:.1}", row.calibration_ms),
            ]],
        )
    );
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let mode = match args.get_or("mode", "block") {
        "block" => DynamicMode::Block,
        "step-block" => DynamicMode::StepBlock,
        m => bail!("bad --mode {m:?}"),
    };
    let metric = Metric::parse(args.get_or("metric", "q1"))?;
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let engine = Engine::with_cache(&rt, cache_config(args)?);
    let layout = tok.layout_prompt(&cfg, &ds.examples[0].prompt)?;
    // calibration must see full per-step confidence vectors — force the
    // host decision path even when the fused window kernels are available
    let cal = engine.decode(
        layout,
        &osdt::policy::HostTraced(StaticThreshold::new(bench::CALIBRATION_TAU)),
    )?;
    let profile = Calibrator::calibrate(&cal.trace, mode, metric);
    let store = ProfileStore::new(args.get_or("profile-dir", "profiles"))?;
    let path =
        store.save(&ProfileRecord::new(task.as_str(), profile, cal.trace.signature()))?;
    println!("calibrated {task} ({} steps) -> {}", cal.steps, path.display());
    Ok(())
}

fn cmd_traces(args: &Args) -> Result<()> {
    let task = args.get("task").context("--task required")?.to_string();
    let n = args.get_parse("n", 8usize)?;
    let tau = args.get_parse("tau", bench::CALIBRATION_TAU)?;
    let (cfg, rt, tok) = load_stack(args)?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), &task)?;
    let traces = bench::collect_traces(&rt, &tok, &ds, n, tau)?;
    if let Some(path) = args.get("save") {
        let doc = osdt::util::json::Json::Arr(
            traces.iter().map(|t| t.to_json()).collect(),
        );
        std::fs::write(path, format!("{doc}\n"))?;
        eprintln!("saved {} traces -> {path}", traces.len());
    }
    let sig = bench::mean_signature(&traces);
    print!(
        "{}",
        bench::ascii_plot(&sig, 12, &format!("{task}: step-block mean confidence"))
    );
    let m = bench::cosine_matrix(&traces);
    print!(
        "{}",
        bench::ascii_heatmap(&m, 0.9, 1.0, &format!("{task}: pairwise cosine"))
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = ModelConfig::load(dir)?;
    println!("artifact dir : {}", cfg.artifact_dir.display());
    println!(
        "model        : d={} layers={} heads={} ff={} vocab={}",
        cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.vocab_size
    );
    println!(
        "sequence     : prompt {} + gen {} ({} blocks x {})",
        cfg.prompt_len, cfg.gen_len, cfg.num_blocks, cfg.block_len
    );
    println!("variants     :");
    for (name, v) in &cfg.variants {
        println!("  {name} (batch {})", v.batch);
    }
    Ok(())
}
