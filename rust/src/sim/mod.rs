//! Analytic confidence simulator — a deterministic [`ForwardModel`] with
//! the *structure* the paper observes (Figures 1–2): per-block confidence
//! that starts low, peaks mid-denoising and dips near block completion, and
//! trajectories that are near-identical across inputs of the same task.
//!
//! Used by unit/property tests of the decode engine and policies (no
//! artifacts needed) and by the policy-only benches, where thousands of
//! decodes per second matter. The real-model benches use the PJRT runtime.
//!
//! For resilience testing the model carries an optional [`Chaos`] hook
//! ([`SimModel::with_chaos`]): an atomic fail-budget that makes the next N
//! forward passes error, from any entry point — which is how
//! `rust/tests/chaos.rs` kills workers mid-decode and crashes calibrations
//! mid-lease without touching scheduler or coordinator internals.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::cache::{CacheHandle, CachePool};
use crate::decode::ForwardModel;
use crate::model::{fixtures::tiny_config, ModelConfig};
use crate::runtime::ConfOut;

/// Fault-injection hook shared between a test and the [`SimModel`]s it
/// built (clones of a model share the same hook). Arm it with
/// [`Chaos::fail_next`]; the next `n` forward passes — full, full-KV, or
/// window, across every clone — return an error instead of confidences.
#[derive(Debug, Default)]
pub struct Chaos {
    fail_budget: AtomicU64,
    injected: AtomicU64,
    die_budget: AtomicU64,
}

impl Chaos {
    pub fn new() -> Arc<Self> {
        Arc::new(Chaos::default())
    }

    /// Arm the hook: the next `n` forward passes fail.
    pub fn fail_next(&self, n: u64) {
        self.fail_budget.store(n, Ordering::SeqCst);
    }

    /// How many failures have actually been injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// Arm a *process-level* fault: the `n`-th forward pass from now
    /// aborts the whole process (SIGABRT), mimicking a replica dying
    /// mid-decode. `serve --chaos-die-after N` arms this in a child
    /// replica so fleet chaos tests can kill one deterministically.
    pub fn die_after(&self, n: u64) {
        self.die_budget.store(n, Ordering::SeqCst);
    }

    /// Remaining forward passes before the armed process death fires
    /// (0 = disarmed). Lets tests verify the countdown without dying.
    pub fn die_budget(&self) -> u64 {
        self.die_budget.load(Ordering::SeqCst)
    }

    /// Countdown toward the armed process death, if any.
    fn maybe_die(&self) {
        let mut cur = self.die_budget.load(Ordering::SeqCst);
        while cur > 0 {
            match self.die_budget.compare_exchange(
                cur,
                cur - 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => {
                    if cur == 1 {
                        // SIGKILL-grade exit: no unwinding, no cleanup
                        // — exactly what the supervisor must tolerate.
                        std::process::abort();
                    }
                    return;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Decrement-if-positive on the budget; true means "fail this pass".
    fn should_fail(&self) -> bool {
        let mut cur = self.fail_budget.load(Ordering::SeqCst);
        while cur > 0 {
            match self.fail_budget.compare_exchange(
                cur,
                cur - 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => {
                    self.injected.fetch_add(1, Ordering::SeqCst);
                    return true;
                }
                Err(seen) => cur = seen,
            }
        }
        false
    }
}

/// Task-level confidence signature parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimTask {
    /// confidence at the start of a block
    pub base: f64,
    /// peak amplitude above base (mid-denoising)
    pub amp: f64,
    /// per-position noise amplitude (the instance-level variation; small,
    /// matching the paper's cosine ≈ 1 observation)
    pub noise: f64,
    /// per-block additive offset (blocks differ — the "block-wise
    /// fluctuation" observation)
    pub block_offsets: [f64; 3],
}

/// Deterministic stand-in for the mask predictor. Mints pooled host
/// [`CacheHandle`]s, so the cache-handle lifecycle (mint → install → drop →
/// recycle) is exercised by every simulator-backed test.
#[derive(Clone, Debug)]
pub struct SimModel {
    cfg: ModelConfig,
    task: SimTask,
    seed: u64,
    pool: CachePool,
    chaos: Option<Arc<Chaos>>,
    /// Stable-confidence mode ([`SimModel::plateau_like`]): each position's
    /// confidence is a pure function of the position alone, independent of
    /// the block's masked count. Calibrated acceptance trajectories are
    /// then *faithful* at decode time — the raw material for step-elision
    /// tests and the elision bench rows.
    stable_conf: bool,
    /// Cumulative `fwd_full_kv` invocations (clones share it) — lets
    /// prefix-sharing tests counter-assert skipped refreshes.
    full_kv_calls: Arc<AtomicU64>,
}

fn hash2(a: u64, b: u64) -> u64 {
    let mut x = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 31;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 29)
}

impl SimModel {
    pub fn new(task: SimTask, seed: u64) -> Self {
        let cfg = tiny_config();
        let dims = [cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.head_dim];
        // clones share the pool (it is the model's recycler, not state)
        SimModel {
            cfg,
            task,
            seed,
            pool: CachePool::new(dims, 8),
            chaos: None,
            stable_conf: false,
            full_kv_calls: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Attach a fault-injection hook; see [`Chaos`].
    pub fn with_chaos(mut self, chaos: Arc<Chaos>) -> Self {
        self.chaos = Some(chaos);
        self
    }

    /// Swap in a different (self-consistent) model configuration — e.g. a
    /// single-block layout, where prefix-sharing tests can assert executed
    /// full refreshes < requests. Re-sizes the handle pool; the shared
    /// `full_kv_calls` counter carries over.
    pub fn with_config(mut self, cfg: ModelConfig) -> Self {
        self.pool = CachePool::new(
            [cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.head_dim],
            8,
        );
        self.cfg = cfg;
        self
    }

    /// Fail this pass (or abort the process) if the chaos hook is armed.
    fn trip(&self) -> Result<()> {
        if let Some(c) = &self.chaos {
            c.maybe_die();
            if c.should_fail() {
                bail!("chaos: injected forward failure");
            }
        }
        Ok(())
    }

    /// The cache-storage recycler backing this model's handles.
    pub fn pool(&self) -> &CachePool {
        &self.pool
    }

    /// `fwd_full_kv` calls executed so far (shared across clones).
    pub fn full_kv_calls(&self) -> u64 {
        self.full_kv_calls.load(Ordering::Relaxed)
    }

    /// GSM8K-analog signature: high peak, moderate base.
    pub fn math_like(seed: u64) -> Self {
        SimModel::new(
            SimTask {
                base: 0.55,
                amp: 0.42,
                noise: 0.03,
                block_offsets: [0.0, -0.05, -0.1],
            },
            seed,
        )
    }

    /// GPQA-analog: lower confidence overall, stronger step structure.
    pub fn qa_like(seed: u64) -> Self {
        SimModel::new(
            SimTask {
                base: 0.4,
                amp: 0.5,
                noise: 0.04,
                block_offsets: [0.05, -0.08, -0.02],
            },
            seed,
        )
    }

    /// HumanEval-analog: sharp, high-confidence once context builds.
    pub fn code_like(seed: u64) -> Self {
        SimModel::new(
            SimTask {
                base: 0.5,
                amp: 0.48,
                noise: 0.02,
                block_offsets: [-0.03, 0.0, -0.12],
            },
            seed,
        )
    }

    /// Plateau-analog: a bimodal per-position confidence landscape that
    /// does NOT move with denoising progress — a stable high subset
    /// (≈ 0.92) over a low band (0.30–0.45). Under a step-block OSDT
    /// schedule this yields the trajectory the elision planner feeds on:
    /// one productive opening step, a run of fallback-only steps, and one
    /// productive closing step — and because the landscape is progress-
    /// independent, the calibrated trajectory holds exactly at decode
    /// time (predictions-hold regime).
    pub fn plateau_like(seed: u64) -> Self {
        let mut m = SimModel::new(
            SimTask {
                base: 0.5,
                amp: 0.0,
                noise: 0.0,
                block_offsets: [0.0, 0.0, 0.0],
            },
            seed,
        );
        m.stable_conf = true;
        m
    }

    /// A fully-masked layout whose prompt region varies with `seed`
    /// (different "inputs" of the same task).
    pub fn layout_from_seed(&self, seed: u64) -> Vec<u32> {
        let cfg = &self.cfg;
        let mut t = vec![cfg.bos_id];
        for i in 1..cfg.prompt_len / 2 {
            // chars live at ids >= 4
            t.push(4 + (hash2(seed, i as u64) % 60) as u32);
        }
        t.resize(cfg.prompt_len, cfg.pad_id);
        t.resize(cfg.seq_len, cfg.mask_id);
        t
    }

    /// Confidence of `pos` given the masked count of its block — the pure
    /// function both the full and window paths evaluate (which is what
    /// makes the dual-cache path exact for the simulator).
    fn conf_at(&self, block: usize, masked_in_block: usize, pos: usize) -> f32 {
        if self.stable_conf {
            // pure function of pos: dual-cache exact AND progress-stable
            let n = hash2(self.seed ^ 0x009A_7EA0, pos as u64);
            return if n % 3 == 0 {
                0.92
            } else {
                (0.30 + (n % 1000) as f64 / 1000.0 * 0.15) as f32
            };
        }
        let progress = 1.0 - masked_in_block as f64 / self.cfg.block_len as f64;
        let curve = self.task.base
            + self.task.amp * (std::f64::consts::PI * progress).sin()
            + self.task.block_offsets[block.min(2)];
        let n = hash2(self.seed, (pos as u64) << 20 | masked_in_block as u64);
        let noise = ((n % 10_000) as f64 / 10_000.0 - 0.5) * 2.0 * self.task.noise;
        (curve + noise).clamp(0.01, 0.999) as f32
    }

    fn candidate(&self, pos: usize) -> u32 {
        4 + (hash2(self.seed ^ 0xC0FFEE, pos as u64) % 60) as u32
    }

    /// conf/argmax over an index range, reading block structure from the
    /// provided tokens (offset = absolute position of `tokens[0]`).
    fn score(&self, tokens: &[u32], offset: usize) -> (Vec<f32>, Vec<u32>) {
        let cfg = &self.cfg;
        // masked counts per block, computed from whatever slice we see
        let mut masked = vec![0usize; cfg.num_blocks];
        for (i, &t) in tokens.iter().enumerate() {
            let pos = offset + i;
            if t == cfg.mask_id && pos >= cfg.prompt_len {
                masked[(pos - cfg.prompt_len) / cfg.block_len] += 1;
            }
        }
        let mut conf = Vec::with_capacity(tokens.len());
        let mut arg = Vec::with_capacity(tokens.len());
        for i in 0..tokens.len() {
            let pos = offset + i;
            if pos < cfg.prompt_len {
                conf.push(0.99);
            } else {
                let b = (pos - cfg.prompt_len) / cfg.block_len;
                conf.push(self.conf_at(b, masked[b], pos));
            }
            arg.push(self.candidate(pos));
        }
        (conf, arg)
    }
}

impl ForwardModel for SimModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn max_batch(&self) -> usize {
        4
    }

    fn window_buckets(&self) -> Vec<usize> {
        // mirror the compiled variant ladder so scheduler bucket/padding
        // behaviour is testable without artifacts
        vec![1, 2, 4, 8, 16, 32]
    }

    fn fwd_conf(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut> {
        self.trip()?;
        let mut out = ConfOut::with_capacity(self.cfg.seq_len, batch_tokens.len());
        for seq in batch_tokens {
            let (c, a) = self.score(seq, 0);
            out.push_row(&c, &a);
        }
        Ok(out)
    }

    fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, CacheHandle)> {
        self.trip()?;
        self.full_kv_calls.fetch_add(1, Ordering::Relaxed);
        let (c, a) = self.score(tokens, 0);
        let mut out = ConfOut::with_capacity(self.cfg.seq_len, 1);
        out.push_row(&c, &a);
        // the simulator's "cache" carries no information — its conf is a
        // pure function of visible tokens — but it goes through the pooled
        // handle lifecycle so tests exercise mint/recycle for real
        let mut kv = self.pool.take_host_storage();
        let n: usize = kv.dims.iter().product();
        kv.k.resize(n, 0.0);
        kv.v.resize(n, 0.0);
        Ok((out, self.pool.wrap_host(kv)))
    }

    fn fwd_window(
        &self,
        window: &[u32],
        start: usize,
        _cache: &CacheHandle,
    ) -> Result<ConfOut> {
        self.trip()?;
        let (c, a) = self.score(window, start);
        let mut out = ConfOut::with_capacity(window.len(), 1);
        out.push_row(&c, &a);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::Engine;
    use crate::policy::{Calibrator, DynamicMode, Metric, StaticThreshold};
    use crate::util::stats::cosine;

    #[test]
    fn deterministic() {
        let m = SimModel::math_like(3);
        let l = m.layout_from_seed(5);
        let a = m.fwd_conf(&[l.as_slice()]).unwrap();
        let b = m.fwd_conf(&[l.as_slice()]).unwrap();
        assert_eq!(a.conf_row(0), b.conf_row(0));
        assert_eq!(a.argmax_row(0), b.argmax_row(0));
    }

    #[test]
    fn chaos_fails_exactly_the_budget() {
        let chaos = Chaos::new();
        let m = SimModel::math_like(2).with_chaos(chaos.clone());
        let l = m.layout_from_seed(0);
        assert!(m.fwd_conf(&[l.as_slice()]).is_ok(), "unarmed hook is inert");
        chaos.fail_next(2);
        assert!(m.fwd_conf(&[l.as_slice()]).is_err());
        assert!(m.fwd_full_kv(&l).is_err());
        assert!(m.fwd_conf(&[l.as_slice()]).is_ok(), "budget exhausted");
        assert_eq!(chaos.injected(), 2);
    }

    #[test]
    fn die_budget_counts_down_per_forward_pass() {
        // Can't cross the abort in-process; verify the countdown wiring
        // and that a disarmed hook never decrements.
        let chaos = Chaos::new();
        let m = SimModel::math_like(2).with_chaos(chaos.clone());
        let l = m.layout_from_seed(0);
        assert!(m.fwd_conf(&[l.as_slice()]).is_ok());
        assert_eq!(chaos.die_budget(), 0, "disarmed hook stays at zero");
        chaos.die_after(5);
        m.fwd_conf(&[l.as_slice()]).unwrap();
        m.fwd_full_kv(&l).unwrap();
        assert_eq!(chaos.die_budget(), 3, "each forward pass counts down");
    }

    #[test]
    fn u_shaped_trajectory() {
        // decode sequentially and look at the block-0 step means: the mid
        // region must exceed both ends (paper Figure 1 structure)
        let m = SimModel::math_like(3);
        let eng = Engine::new(&m);
        let res = eng
            .decode(m.layout_from_seed(1), &crate::policy::SequentialTopK::new(1))
            .unwrap();
        let sig = res.trace.signature();
        let b0 = &sig[..m.config().block_len];
        let first = b0[0];
        let mid = b0[b0.len() / 2];
        let last = b0[b0.len() - 1];
        assert!(mid > first + 0.1, "mid {mid} !> first {first}");
        assert!(mid > last + 0.1, "mid {mid} !> last {last}");
    }

    #[test]
    fn signatures_near_identical_across_inputs() {
        // the paper's Figure 2 observation, reproduced in the simulator:
        // cosine similarity of step-block signatures across inputs ~ 1
        let m = SimModel::qa_like(9);
        let eng = Engine::new(&m);
        let p = StaticThreshold::new(0.9);
        let sigs: Vec<Vec<f64>> = (0..4)
            .map(|s| {
                eng.decode(m.layout_from_seed(s), &p)
                    .unwrap()
                    .trace
                    .signature()
            })
            .collect();
        for i in 0..sigs.len() {
            for j in (i + 1)..sigs.len() {
                let n = sigs[i].len().min(sigs[j].len());
                let c = cosine(&sigs[i][..n], &sigs[j][..n]).unwrap();
                assert!(c > 0.99, "cosine {c} between {i},{j}");
            }
        }
    }

    #[test]
    fn calibration_transfers_across_inputs() {
        // calibrate on input 0; OSDT on input 1 must not be slower than
        // sequential and must finish (liveness under transferred taus)
        let m = SimModel::math_like(11);
        let eng = Engine::new(&m);
        let cal = eng
            .decode(m.layout_from_seed(0), &StaticThreshold::new(0.9))
            .unwrap();
        let profile =
            Calibrator::calibrate(&cal.trace, DynamicMode::Block, Metric::Q1);
        let osdt = crate::policy::Osdt::from_profile(profile, 0.9, 0.1);
        let res = eng.decode(m.layout_from_seed(1), &osdt).unwrap();
        assert!(res.steps <= m.config().gen_len);
        assert!(res.steps >= m.config().num_blocks);
    }

    #[test]
    fn plateau_confidence_is_progress_independent() {
        let m = SimModel::plateau_like(5);
        let l = m.layout_from_seed(0);
        let cfg = m.config().clone();
        // full-layout scoring vs a partially-committed layout: unmasked
        // positions elsewhere must not move any masked position's conf
        let a = m.fwd_conf(&[l.as_slice()]).unwrap();
        let mut committed = l.clone();
        // commit half of block 0
        for p in cfg.block_range(0).take(cfg.block_len / 2) {
            committed[p] = 9;
        }
        let b = m.fwd_conf(&[committed.as_slice()]).unwrap();
        for p in cfg.block_range(0).skip(cfg.block_len / 2) {
            assert_eq!(a.conf_row(0)[p], b.conf_row(0)[p], "pos {p}");
        }
        // bimodal: both the high plateau and the low band are present
        let highs = cfg
            .gen_range()
            .filter(|&p| a.conf_row(0)[p] > 0.9)
            .count();
        let lows = cfg
            .gen_range()
            .filter(|&p| a.conf_row(0)[p] < 0.5)
            .count();
        assert!(highs > 0 && lows > 0, "highs {highs} lows {lows}");
        assert_eq!(highs + lows, cfg.gen_len);
    }

    #[test]
    fn tasks_have_distinct_signatures() {
        let eng_cfgs = [
            SimModel::math_like(1),
            SimModel::qa_like(1),
            SimModel::code_like(1),
        ];
        let p = crate::policy::SequentialTopK::new(1);
        let mut means = vec![];
        for m in &eng_cfgs {
            let eng = Engine::new(m);
            let res = eng.decode(m.layout_from_seed(0), &p).unwrap();
            let sig = res.trace.signature();
            means.push(sig.iter().sum::<f64>() / sig.len() as f64);
        }
        // the three tasks must be pairwise separated (distinct signatures)
        for i in 0..3 {
            for j in (i + 1)..3 {
                assert!(
                    (means[i] - means[j]).abs() > 0.01,
                    "tasks {i},{j} indistinct: {means:?}"
                );
            }
        }
    }
}
