//! Character tokenizer — the Rust mirror of `python/compile/vocab.py`,
//! constructed from the vocab table in `model_config.json` so the two sides
//! cannot drift.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::model::ModelConfig;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    char_to_id: HashMap<char, u32>,
    id_to_char: Vec<Option<char>>,
    pub pad_id: u32,
    pub mask_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn from_config(cfg: &ModelConfig) -> Result<Self> {
        let mut char_to_id = HashMap::new();
        let mut id_to_char = vec![None; cfg.vocab.len()];
        for (id, surf) in cfg.vocab.iter().enumerate() {
            if surf.starts_with('[') && surf.ends_with(']') && surf.len() > 2 {
                continue; // special token
            }
            let mut chars = surf.chars();
            let c = match (chars.next(), chars.next()) {
                (Some(c), None) => c,
                _ => bail!("non-special vocab entry {surf:?} is not one char"),
            };
            if char_to_id.insert(c, id as u32).is_some() {
                bail!("duplicate vocab char {c:?}");
            }
            id_to_char[id] = Some(c);
        }
        Ok(Tokenizer {
            char_to_id,
            id_to_char,
            pad_id: cfg.pad_id,
            mask_id: cfg.mask_id,
            bos_id: cfg.bos_id,
            eos_id: cfg.eos_id,
            vocab_size: cfg.vocab.len(),
        })
    }

    /// Encode text; errors on characters outside the frozen charset.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        text.chars()
            .map(|c| {
                self.char_to_id
                    .get(&c)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("character {c:?} not in vocab"))
            })
            .collect()
    }

    /// Decode ids, dropping special tokens (PAD/MASK/BOS/EOS and anything
    /// else without a surface char).
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter_map(|&id| self.id_to_char.get(id as usize).copied().flatten())
            .collect()
    }

    /// Decode ids, stopping at the first EOS (the visible answer text).
    pub fn decode_until_eos(&self, ids: &[u32]) -> String {
        let end = ids
            .iter()
            .position(|&id| id == self.eos_id)
            .unwrap_or(ids.len());
        self.decode(&ids[..end])
    }

    /// Build the full fixed-layout sequence for a prompt:
    /// `[BOS] prompt [PAD]... || [MASK] * gen_len` (mirrors
    /// `data.encode_example`, with the gen region masked for decoding).
    pub fn layout_prompt(&self, cfg: &ModelConfig, prompt: &str) -> Result<Vec<u32>> {
        let mut ids = vec![self.bos_id];
        ids.extend(self.encode(prompt)?);
        if ids.len() > cfg.prompt_len {
            bail!(
                "prompt too long: {} tokens > {}",
                ids.len(),
                cfg.prompt_len
            );
        }
        ids.resize(cfg.prompt_len, self.pad_id);
        ids.resize(cfg.seq_len, self.mask_id);
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;

    fn tok() -> Tokenizer {
        Tokenizer::from_config(&tiny_config()).unwrap()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tok();
        let text = "Q: 17+5-9=? A: ok! (B) <x|y>";
        let ids = t.encode(text).unwrap();
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn rejects_unknown_chars() {
        assert!(tok().encode("héllo").is_err());
        assert!(tok().encode("\n").is_err());
    }

    #[test]
    fn decode_skips_specials() {
        let t = tok();
        let mut ids = vec![t.bos_id];
        ids.extend(t.encode("ab").unwrap());
        ids.push(t.eos_id);
        ids.push(t.pad_id);
        assert_eq!(t.decode(&ids), "ab");
    }

    #[test]
    fn decode_until_eos_stops() {
        let t = tok();
        let mut ids = t.encode("yes").unwrap();
        ids.push(t.eos_id);
        ids.extend(t.encode("garbage").unwrap());
        assert_eq!(t.decode_until_eos(&ids), "yes");
    }

    #[test]
    fn layout_prompt_shape() {
        let cfg = tiny_config();
        let t = tok();
        let ids = t.layout_prompt(&cfg, "Q: 1+1=?").unwrap();
        assert_eq!(ids.len(), cfg.seq_len);
        assert_eq!(ids[0], t.bos_id);
        // padding after prompt
        assert_eq!(ids[cfg.prompt_len - 1], t.pad_id);
        // gen region fully masked
        assert!(ids[cfg.prompt_len..].iter().all(|&i| i == t.mask_id));
    }

    #[test]
    fn layout_prompt_too_long_rejected() {
        let cfg = tiny_config();
        let t = tok();
        let long = "x".repeat(cfg.prompt_len);
        assert!(t.layout_prompt(&cfg, &long).is_err());
    }

    #[test]
    fn vocab_matches_python_size() {
        // python vocab.py: 4 specials + 83 chars = 87
        assert_eq!(tok().vocab_size, 87);
    }
}
