//! [`DecodeTask`]: one sequence's decode state as a resumable step machine
//! (DESIGN.md §4).
//!
//! A task advances one policy decision at a time. Between decisions it is
//! inert data, so any driver — the batch-1 [`super::Engine`] loop, the
//! continuous-batching [`super::StepScheduler`], a test harness — can hold
//! thousands of tasks and interleave them freely. The contract per step:
//!
//! 1. ask [`DecodeTask::needs`] which forward pass the task requires;
//! 2. run that pass (batching compatible passes across tasks);
//! 3. for [`PassKind::FullKv`], [`DecodeTask::install_cache`] the fresh
//!    K/V first;
//! 4. feed the task's output row to [`DecodeTask::apply`].
//!
//! The task owns its per-sequence dual KV cache, which is what lets cached
//! and uncached execution share one driver loop: the cache is just another
//! piece of per-task state that `needs()` consults.

use anyhow::{bail, Result};

use crate::cache::{CacheConfig, CacheHandle};
use crate::model::ModelConfig;
use crate::policy::{CalibrationTrace, Policy, StepContext};

use super::DecodeResult;

/// The forward pass a task requires for its next step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PassKind {
    /// Full uncached forward over the whole sequence; batchable across
    /// tasks via [`super::ForwardModel::fwd_conf`].
    Full,
    /// Block-boundary (or staleness-triggered) full forward that also
    /// refreshes this task's dual KV cache (`fwd_full_kv`, batch 1).
    FullKv,
    /// Window forward over the active block at absolute position `start`,
    /// attending against the installed cache; batchable across tasks via
    /// [`super::ForwardModel::fwd_window_batch`].
    Window { start: usize },
    /// Sequence complete — retire the task.
    Done,
}

/// Resumable per-sequence decode state (public successor of the engine's
/// old private `SeqState`, which was locked inside two run-to-completion
/// loops). Not `Clone`: the owned [`CacheHandle`] is a single-owner,
/// pool-reclaiming resource.
#[derive(Debug)]
pub struct DecodeTask {
    tokens: Vec<u32>,
    block: usize,
    step_in_block: usize,
    steps: usize,
    full_passes: usize,
    window_passes: usize,
    fallback_steps: usize,
    /// Schedule steps jumped over by the elision planner (DESIGN.md §14) —
    /// never executed, so they appear in no pass count and no trace entry.
    steps_elided: usize,
    /// Elided-over runs whose jumped-to re-check accepted nothing beyond
    /// the liveness fallback — the profile's prediction was wrong.
    elision_mispredictions: usize,
    /// Blocks that completed with at least one elided step: retired early
    /// instead of draining the calibrated schedule.
    blocks_retired_early: usize,
    /// Set by [`DecodeTask::elide`] when the jumped-to step is expected to
    /// accept by rule; consumed by the next executed pass to detect a
    /// misprediction (that pass falling back to argmax).
    pending_jump_check: bool,
    elided_in_block: usize,
    trace: CalibrationTrace,
    done: bool,
    cache_cfg: CacheConfig,
    /// Per-sequence dual KV cache (opaque residency-aware handle); `None`
    /// until the first block-boundary refresh, and dropped again — which
    /// recycles its storage into the minting model's pool — whenever the
    /// active block changes.
    cache: Option<CacheHandle>,
    /// Window steps since the last cache refresh (staleness bound).
    since_refresh: usize,
}

impl DecodeTask {
    /// Build a task from a full-sequence layout (prompt ‖ gen region).
    /// Blocks that arrive with no masked positions are skipped immediately,
    /// so a fully-committed layout is born `Done`.
    pub fn new(tokens: Vec<u32>, cfg: &ModelConfig, cache_cfg: CacheConfig) -> Result<Self> {
        if tokens.len() != cfg.seq_len {
            bail!("layout length {} != seq_len {}", tokens.len(), cfg.seq_len);
        }
        let mut task = DecodeTask {
            tokens,
            block: 0,
            step_in_block: 0,
            steps: 0,
            full_passes: 0,
            window_passes: 0,
            fallback_steps: 0,
            steps_elided: 0,
            elision_mispredictions: 0,
            blocks_retired_early: 0,
            pending_jump_check: false,
            elided_in_block: 0,
            trace: CalibrationTrace::new(cfg.num_blocks),
            done: false,
            cache_cfg,
            cache: None,
            since_refresh: 0,
        };
        while task.block < cfg.num_blocks && task.masked(cfg).is_empty() {
            task.block += 1;
        }
        if task.block >= cfg.num_blocks {
            task.done = true;
        }
        Ok(task)
    }

    /// Which forward pass this task needs next.
    pub fn needs(&self, cfg: &ModelConfig) -> PassKind {
        if self.done {
            return PassKind::Done;
        }
        if !self.cache_cfg.enabled {
            return PassKind::Full;
        }
        let stale = self.cache_cfg.refresh_interval > 0
            && self.since_refresh >= self.cache_cfg.refresh_interval;
        if self.cache.is_none() || stale {
            return PassKind::FullKv;
        }
        PassKind::Window { start: cfg.block_range(self.block).start }
    }

    /// Full token sequence (prompt region + committed + remaining masks).
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// The active block's token window (input of a [`PassKind::Window`]).
    pub fn window(&self, cfg: &ModelConfig) -> &[u32] {
        &self.tokens[cfg.block_range(self.block)]
    }

    /// The installed dual KV cache handle, if any.
    pub fn cache(&self) -> Option<&CacheHandle> {
        self.cache.as_ref()
    }

    /// Install a freshly refreshed cache handle (after a `FullKv` pass,
    /// before the matching [`DecodeTask::apply`]). Any previous handle is
    /// dropped, recycling its storage.
    pub fn install_cache(&mut self, cache: CacheHandle) {
        self.cache = Some(cache);
        self.since_refresh = 0;
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Active gen block (meaningful while `!is_done()`).
    pub fn block(&self) -> usize {
        self.block
    }

    /// Policy decisions taken so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Denoising step index within the active block (what `Policy::plan`
    /// decides on, together with [`DecodeTask::block`]). With elision this
    /// is the *schedule* index, which can run ahead of the executed-pass
    /// count — the trace records at executed indices.
    pub fn step_in_block(&self) -> usize {
        self.step_in_block
    }

    /// Schedule steps jumped over by the elision planner so far.
    pub fn steps_elided(&self) -> usize {
        self.steps_elided
    }

    /// Elision mispredictions detected so far (see field docs).
    pub fn elision_mispredictions(&self) -> usize {
        self.elision_mispredictions
    }

    /// Blocks retired early (completed with elided steps) so far.
    pub fn blocks_retired_early(&self) -> usize {
        self.blocks_retired_early
    }

    /// Jump the schedule `k` steps ahead without running a pass — the
    /// scheduler calls this when the policy's plan advertises
    /// `skip_ahead = k` (DESIGN.md §14). `expect_accept` marks whether the
    /// jumped-to step's rule is expected to accept on its own (true for a
    /// productive threshold/factor target); the next executed pass then
    /// verifies the prediction — falling back to argmax there counts as an
    /// elision misprediction. Elided steps don't advance `since_refresh`:
    /// cache staleness is bounded in *executed* window passes.
    pub fn elide(&mut self, k: usize, expect_accept: bool) {
        debug_assert!(!self.done, "elide on a finished task");
        self.step_in_block += k;
        self.steps_elided += k;
        self.elided_in_block += k;
        self.pending_jump_check = expect_accept;
    }

    /// Masked positions (absolute) of the current block.
    fn masked(&self, cfg: &ModelConfig) -> Vec<usize> {
        cfg.block_range(self.block)
            .filter(|&p| self.tokens[p] == cfg.mask_id)
            .collect()
    }

    /// Run one policy decision on fresh conf/argmax produced by a `kind`
    /// pass (`Full`/`FullKv` rows cover the whole sequence; `Window` rows
    /// cover the active block). Returns the number of committed tokens.
    pub fn apply(
        &mut self,
        cfg: &ModelConfig,
        policy: &dyn Policy,
        kind: PassKind,
        conf: &[f32],
        argmax: &[u32],
    ) -> usize {
        debug_assert!(!self.done, "apply on a finished task");
        let offset = match kind {
            PassKind::Window { start } => start,
            _ => 0,
        };
        let masked = self.masked(cfg);
        debug_assert!(!masked.is_empty(), "apply on completed block");
        let local_conf: Vec<f32> = masked.iter().map(|&p| conf[p - offset]).collect();
        // record at the *executed*-step index: elision can jump
        // `step_in_block` ahead of the pass count, and drift signatures
        // compare executed steps only (clamp-extended alignment covers the
        // resulting length mismatch, DESIGN.md §9/§14)
        let executed = self.trace.steps_recorded(self.block);
        self.trace.record(self.block, executed, &local_conf);
        let ctx = StepContext {
            block: self.block,
            step: self.step_in_block,
            conf: &local_conf,
        };
        let (sel, fell_back) = policy.select_explain(&ctx);
        if fell_back {
            self.fallback_steps += 1;
        }
        self.check_jump(fell_back);
        debug_assert!(!sel.is_empty(), "policy liveness violated");
        for &i in &sel {
            let pos = masked[i];
            self.tokens[pos] = argmax[pos - offset];
        }
        self.steps += 1;
        self.step_in_block += 1;
        match kind {
            PassKind::Full | PassKind::FullKv => self.full_passes += 1,
            PassKind::Window { .. } => {
                self.window_passes += 1;
                self.since_refresh += 1;
            }
            PassKind::Done => {}
        }
        self.finish_step(cfg);
        sel.len()
    }

    /// Fast path for a fused window step (DESIGN.md §11): the policy
    /// decision already ran on device — `accepted` holds the committed
    /// (window-local position, token) pairs, `step_mean` the masked-mean
    /// confidence, `fell_back` whether the argmax liveness fallback fired.
    /// The trace records the single mean instead of the full confidence
    /// vector (which never crossed the host boundary): signature-grade
    /// resolution — exact for drift detection, insufficient for
    /// `Calibrator`'s quantile metrics, which is why calibration decodes
    /// force the host path via `policy::HostTraced`.
    pub fn apply_accept(
        &mut self,
        cfg: &ModelConfig,
        start: usize,
        accepted: &[(u32, u32)],
        step_mean: f32,
        fell_back: bool,
    ) -> usize {
        debug_assert!(!self.done, "apply_accept on a finished task");
        debug_assert!(!accepted.is_empty(), "fused acceptance liveness violated");
        let executed = self.trace.steps_recorded(self.block);
        self.trace.record(self.block, executed, &[step_mean]);
        if fell_back {
            self.fallback_steps += 1;
        }
        self.check_jump(fell_back);
        for &(pos, tok) in accepted {
            let p = start + pos as usize;
            debug_assert_eq!(
                self.tokens[p], cfg.mask_id,
                "fused accept committed a non-masked position"
            );
            self.tokens[p] = tok;
        }
        self.steps += 1;
        self.step_in_block += 1;
        self.window_passes += 1;
        self.since_refresh += 1;
        self.finish_step(cfg);
        accepted.len()
    }

    /// Consume a pending jump verification: the first executed pass after
    /// an elision falling back to argmax means the jumped-to step accepted
    /// nothing by rule — the trajectory's prediction was wrong.
    fn check_jump(&mut self, fell_back: bool) {
        if self.pending_jump_check {
            self.pending_jump_check = false;
            if fell_back {
                self.elision_mispredictions += 1;
            }
        }
    }

    /// Shared step epilogue: roll over completed blocks and drop the dual
    /// cache at block boundaries (Fast-dLLM refreshes prefix and suffix
    /// K/V whenever the active block changes). A block that completes
    /// having elided steps retired early — it never drained the calibrated
    /// schedule.
    fn finish_step(&mut self, cfg: &ModelConfig) {
        let prev_block = self.block;
        while self.block < cfg.num_blocks && self.masked(cfg).is_empty() {
            self.block += 1;
            self.step_in_block = 0;
            if self.block == cfg.num_blocks {
                self.done = true;
                break;
            }
        }
        if self.block >= cfg.num_blocks {
            self.done = true;
        }
        if self.block != prev_block {
            if self.elided_in_block > 0 {
                self.blocks_retired_early += 1;
            }
            self.elided_in_block = 0;
            self.pending_jump_check = false;
            self.cache = None;
            self.since_refresh = 0;
        }
    }

    /// Consume the task into its final [`DecodeResult`].
    pub fn into_result(self) -> DecodeResult {
        DecodeResult {
            tokens: self.tokens,
            steps: self.steps,
            full_passes: self.full_passes,
            window_passes: self.window_passes,
            fallback_steps: self.fallback_steps,
            steps_elided: self.steps_elided,
            elision_mispredictions: self.elision_mispredictions,
            blocks_retired_early: self.blocks_retired_early,
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::ForwardModel;
    use crate::model::fixtures::tiny_config;
    use crate::policy::StaticThreshold;
    use crate::sim::SimModel;

    #[test]
    fn uncached_task_always_needs_full() {
        let cfg = tiny_config();
        let m = SimModel::math_like(1);
        let task =
            DecodeTask::new(m.layout_from_seed(1), &cfg, CacheConfig::disabled()).unwrap();
        assert_eq!(task.needs(&cfg), PassKind::Full);
    }

    #[test]
    fn cached_task_alternates_refresh_and_window() {
        let cfg = tiny_config();
        let m = SimModel::math_like(2);
        let mut task = DecodeTask::new(
            m.layout_from_seed(2),
            &cfg,
            CacheConfig::block_boundary(),
        )
        .unwrap();
        let p = StaticThreshold::new(0.95);
        // block start: refresh required
        assert_eq!(task.needs(&cfg), PassKind::FullKv);
        let (out, kv) = m.fwd_full_kv(task.tokens()).unwrap();
        task.install_cache(kv);
        task.apply(&cfg, &p, PassKind::FullKv, out.conf_row(0), out.argmax_row(0));
        // within the block: window passes against the installed cache
        if !task.is_done() && task.block() == 0 {
            match task.needs(&cfg) {
                PassKind::Window { start } => {
                    assert_eq!(start, cfg.block_range(0).start)
                }
                other => panic!("expected window pass, got {other:?}"),
            }
        }
    }

    #[test]
    fn block_rollover_drops_cache() {
        let cfg = tiny_config();
        let m = SimModel::math_like(3);
        let mut task = DecodeTask::new(
            m.layout_from_seed(3),
            &cfg,
            CacheConfig::block_boundary(),
        )
        .unwrap();
        let p = StaticThreshold::new(0.5); // lax: blocks finish in few steps
        let mut saw_second_refresh = false;
        for _ in 0..(4 * cfg.gen_len) {
            if task.is_done() {
                break;
            }
            match task.needs(&cfg) {
                PassKind::FullKv => {
                    if task.block() > 0 {
                        saw_second_refresh = true;
                    }
                    let (out, kv) = m.fwd_full_kv(task.tokens()).unwrap();
                    task.install_cache(kv);
                    task.apply(&cfg, &p, PassKind::FullKv, out.conf_row(0), out.argmax_row(0));
                }
                PassKind::Window { start } => {
                    let out = m
                        .fwd_window(task.window(&cfg), start, task.cache().unwrap())
                        .unwrap();
                    task.apply(
                        &cfg,
                        &p,
                        PassKind::Window { start },
                        out.conf_row(0),
                        out.argmax_row(0),
                    );
                }
                other => panic!("unexpected pass {other:?}"),
            }
        }
        assert!(task.is_done());
        assert!(saw_second_refresh, "every block boundary must refresh");
    }

    #[test]
    fn fully_committed_layout_is_born_done() {
        let cfg = tiny_config();
        let layout = vec![4u32; cfg.seq_len]; // no [MASK] anywhere
        let task = DecodeTask::new(layout, &cfg, CacheConfig::disabled()).unwrap();
        assert!(task.is_done());
        assert_eq!(task.needs(&cfg), PassKind::Done);
        assert_eq!(task.into_result().steps, 0);
    }

    #[test]
    fn rejects_wrong_length() {
        let cfg = tiny_config();
        assert!(DecodeTask::new(vec![0; 3], &cfg, CacheConfig::disabled()).is_err());
    }

    #[test]
    fn elide_jumps_schedule_but_traces_executed_steps() {
        let cfg = tiny_config();
        let m = SimModel::math_like(5);
        let mut task =
            DecodeTask::new(m.layout_from_seed(5), &cfg, CacheConfig::disabled()).unwrap();
        let p = StaticThreshold::new(0.0); // permissive: one pass per block
        let out = m.fwd_conf(&[task.tokens()]).unwrap();
        // jump the schedule 3 steps before the first executed pass
        task.elide(3, true);
        assert_eq!(task.step_in_block(), 3);
        assert_eq!(task.steps_elided(), 3);
        let block = task.block();
        task.apply(&cfg, &p, PassKind::Full, out.conf_row(0), out.argmax_row(0));
        // the trace holds ONE executed step for that block, recorded at
        // index 0 — not at the jumped schedule index 3
        let res_trace = &task.trace;
        assert_eq!(res_trace.steps_recorded(block), 1);
        // τ=0.0 accepts everything -> the expected-accept check passes
        assert_eq!(task.elision_mispredictions(), 0);
        // block completed with elided steps -> retired early
        assert_eq!(task.blocks_retired_early(), 1);
    }

    #[test]
    fn elide_misprediction_detected_on_fallback() {
        let cfg = tiny_config();
        let m = SimModel::math_like(6);
        let mut task =
            DecodeTask::new(m.layout_from_seed(6), &cfg, CacheConfig::disabled()).unwrap();
        // impossible τ: the jumped-to step is guaranteed to fall back
        let p = StaticThreshold::new(0.9999);
        let out = m.fwd_conf(&[task.tokens()]).unwrap();
        task.elide(2, true);
        task.apply(&cfg, &p, PassKind::Full, out.conf_row(0), out.argmax_row(0));
        assert_eq!(task.elision_mispredictions(), 1);
        // the check is one-shot: a later fallback is NOT a misprediction
        let out2 = m.fwd_conf(&[task.tokens()]).unwrap();
        if !task.is_done() {
            task.apply(&cfg, &p, PassKind::Full, out2.conf_row(0), out2.argmax_row(0));
            assert_eq!(task.elision_mispredictions(), 1);
        }
    }

    #[test]
    fn floor_mode_elide_expects_no_accept() {
        let cfg = tiny_config();
        let m = SimModel::math_like(7);
        let mut task =
            DecodeTask::new(m.layout_from_seed(7), &cfg, CacheConfig::disabled()).unwrap();
        let p = StaticThreshold::new(0.9999);
        let out = m.fwd_conf(&[task.tokens()]).unwrap();
        // expect_accept = false (argmax-floor target): fallback is expected
        task.elide(2, false);
        task.apply(&cfg, &p, PassKind::Full, out.conf_row(0), out.argmax_row(0));
        assert_eq!(task.elision_mispredictions(), 0);
    }

    #[test]
    fn into_result_carries_elision_counters() {
        let cfg = tiny_config();
        let m = SimModel::math_like(8);
        let mut task =
            DecodeTask::new(m.layout_from_seed(8), &cfg, CacheConfig::disabled()).unwrap();
        let p = StaticThreshold::new(0.0);
        let out = m.fwd_conf(&[task.tokens()]).unwrap();
        task.elide(2, true);
        task.apply(&cfg, &p, PassKind::Full, out.conf_row(0), out.argmax_row(0));
        while !task.is_done() {
            let out = m.fwd_conf(&[task.tokens()]).unwrap();
            task.apply(&cfg, &p, PassKind::Full, out.conf_row(0), out.argmax_row(0));
        }
        let res = task.into_result();
        assert_eq!(res.steps_elided, 2);
        assert_eq!(res.blocks_retired_early, 1);
        assert_eq!(res.elision_mispredictions, 0);
    }

    #[test]
    fn apply_accept_commits_pairs_and_rolls_over() {
        let cfg = tiny_config();
        let m = SimModel::math_like(4);
        let mut task = DecodeTask::new(
            m.layout_from_seed(4),
            &cfg,
            CacheConfig::block_boundary(),
        )
        .unwrap();
        let p = StaticThreshold::new(0.95);
        let (out, kv) = m.fwd_full_kv(task.tokens()).unwrap();
        task.install_cache(kv);
        task.apply(&cfg, &p, PassKind::FullKv, out.conf_row(0), out.argmax_row(0));
        assert_eq!(task.block(), 0);
        let start = cfg.block_range(0).start;
        // commit every remaining masked position of block 0 via the fused
        // path: the task must advance a step and roll into block 1
        let pairs: Vec<(u32, u32)> = cfg
            .block_range(0)
            .filter(|&pos| task.tokens()[pos] == cfg.mask_id)
            .map(|pos| ((pos - start) as u32, 7u32))
            .collect();
        assert!(!pairs.is_empty());
        let steps_before = task.steps();
        let n = task.apply_accept(&cfg, start, &pairs, 0.5, false);
        assert_eq!(n, pairs.len());
        assert_eq!(task.steps(), steps_before + 1);
        assert_eq!(task.block(), 1, "completed block must roll over");
        assert_eq!(task.step_in_block(), 0);
        assert!(task.cache().is_none(), "rollover must drop the cache");
        assert_eq!(
            task.needs(&cfg),
            PassKind::FullKv,
            "next block starts with a refresh"
        );
        // the fused trace point is the single step-mean scalar
        let res = task.into_result();
        assert_eq!(res.trace.per_block[0].last().unwrap(), &vec![0.5]);
    }
}
