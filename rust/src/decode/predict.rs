//! Cost forecasts from calibrated acceptance trajectories (DESIGN.md §15).
//!
//! A schema-3 [`Profile`] carries the calibration decode's per-(block, step)
//! acceptance trajectory — which determines, before a request ever runs, how
//! many window passes its decode is expected to need: the trajectory depth of
//! each block, shortened by the §14 elision jumps (elided runs execute no
//! pass, and a run that covers the rest of a block retires it early after one
//! landing pass). [`CostModel::forecast`] turns that into a [`StepForecast`]
//! the coordinator uses for shortest-predicted-job-first admission, the
//! scheduler for alignment-aware grouping, and the shedding watermark for an
//! honest `retry_after_ms`.
//!
//! Forecasts are **advisory only**: nothing in the decode path consults them,
//! so a wrong forecast can reorder or delay work but can never change a
//! single emitted token (pinned by the token-identity property tests in
//! `tests/predictive_scheduling.rs`).
//!
//! Calibration-pending fallback: with no profile (or a block with no
//! recorded trajectory) the prior is the layout-derived worst case — one
//! window pass per position in the block, the liveness bound (every pass
//! commits ≥ 1 position).

use crate::model::ModelConfig;
use crate::policy::Profile;

/// Predicted cost of one request, in units of forward passes.
#[derive(Clone, Debug, PartialEq)]
pub struct StepForecast {
    /// Predicted window passes still to run, per gen block.
    pub per_block: Vec<usize>,
    /// Sum of [`StepForecast::per_block`].
    pub remaining_window_passes: usize,
    /// Window passes plus one block-boundary refresh per block — the
    /// model-call count the backlog gauge and `retry_after_ms` scale by.
    pub total_passes: usize,
    /// False when the prior fell back to the layout-derived worst case
    /// (no profile, or a profile without an acceptance trajectory).
    pub calibrated: bool,
}

impl StepForecast {
    /// Predicted passes remaining once a decode has reached `block` /
    /// `step` (schedule index): full blocks still ahead plus what is left
    /// of the active block. Monotonically non-increasing as (block, step)
    /// advances — the scheduler's alignment signal.
    pub fn remaining_from(&self, block: usize, step: usize) -> usize {
        let ahead: usize = self.per_block.iter().skip(block + 1).sum();
        let current = self.per_block.get(block).copied().unwrap_or(0);
        ahead + current.saturating_sub(step)
    }
}

/// Forecasting rule: trajectory depth per block with elision jumps applied.
/// Holds the same floor the live planner runs with, so the forecast and the
/// execution walk the same predicted-empty runs.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// `Some(floor)` mirrors `--step-elision on --elide-floor F`; `None`
    /// forecasts the naive (un-elided) schedule.
    elide_floor: Option<f64>,
}

impl CostModel {
    pub fn new(elide_floor: Option<f64>) -> Self {
        CostModel { elide_floor }
    }

    /// Layout-derived worst case: `block_len` window passes per block
    /// (liveness commits ≥ 1 position per pass), marked uncalibrated.
    pub fn worst_case(cfg: &ModelConfig) -> StepForecast {
        let per_block = vec![cfg.block_len; cfg.num_blocks];
        Self::from_per_block(per_block, cfg, false)
    }

    /// Forecast a fresh request. `None` (or a profile without an
    /// acceptance trajectory) falls back to [`CostModel::worst_case`].
    pub fn forecast(&self, profile: Option<&Profile>, cfg: &ModelConfig) -> StepForecast {
        let Some(profile) = profile else {
            return Self::worst_case(cfg);
        };
        let any_data = (0..cfg.num_blocks).any(|b| profile.trajectory_steps(b) > 0);
        if !any_data {
            return Self::worst_case(cfg);
        }
        let per_block = (0..cfg.num_blocks)
            .map(|b| self.block_passes_from(profile, cfg, b, 0))
            .collect();
        Self::from_per_block(per_block, cfg, true)
    }

    /// Predicted window passes of block `b` from schedule step `start` on.
    /// Walks the trajectory exactly as the §14 planner would: a
    /// predicted-empty run is jumped (no pass); a run that reaches the end
    /// of the trajectory retires the block after one landing pass; every
    /// other step costs one pass. Blocks without trajectory data cost the
    /// worst case. The walk only ever skips steps, so the elision-aware
    /// count is ≤ the naive trajectory depth.
    fn block_passes_from(
        &self,
        profile: &Profile,
        cfg: &ModelConfig,
        block: usize,
        start: usize,
    ) -> usize {
        let depth = profile.trajectory_steps(block);
        if depth == 0 {
            return cfg.block_len.saturating_sub(start);
        }
        if start >= depth {
            return 0;
        }
        let Some(floor) = self.elide_floor else {
            return depth - start;
        };
        let mut s = start;
        let mut passes = 0usize;
        while s < depth {
            let run = profile.predict_empty_run(block, s, floor);
            if run > 0 {
                s += run;
                if s >= depth {
                    // rest of block predicted empty: one argmax landing
                    // pass retires it early (DESIGN.md §14)
                    passes += 1;
                    break;
                }
            } else {
                passes += 1;
                s += 1;
            }
        }
        passes
    }

    fn from_per_block(
        per_block: Vec<usize>,
        cfg: &ModelConfig,
        calibrated: bool,
    ) -> StepForecast {
        let remaining: usize = per_block.iter().sum();
        StepForecast {
            remaining_window_passes: remaining,
            // one fwd_full_kv refresh per block that has work to do
            total_passes: remaining + cfg.num_blocks,
            per_block,
            calibrated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;
    use crate::policy::Metric;

    fn profile_with(accepts: Vec<Vec<f64>>) -> Profile {
        let taus = accepts.iter().map(|row| vec![0.9; row.len()]).collect();
        Profile::step_block(taus, Metric::Mean).with_accepts(accepts)
    }

    #[test]
    fn worst_case_prior_is_layout_derived() {
        let cfg = tiny_config();
        let f = CostModel::new(None).forecast(None, &cfg);
        assert!(!f.calibrated);
        assert_eq!(f.per_block, vec![cfg.block_len; cfg.num_blocks]);
        assert_eq!(f.remaining_window_passes, cfg.block_len * cfg.num_blocks);
        assert_eq!(f.total_passes, f.remaining_window_passes + cfg.num_blocks);
    }

    #[test]
    fn naive_forecast_is_trajectory_depth() {
        let cfg = tiny_config();
        let p = profile_with(vec![vec![2.0, 1.0, 3.0]; cfg.num_blocks]);
        let f = CostModel::new(None).forecast(Some(&p), &cfg);
        assert!(f.calibrated);
        assert_eq!(f.per_block, vec![3; cfg.num_blocks]);
    }

    #[test]
    fn elision_jumps_shorten_forecast() {
        let cfg = tiny_config();
        // steps 1-2 predicted empty (< floor 1.5), step 3 productive
        let p = profile_with(vec![vec![2.0, 0.0, 1.0, 3.0]; cfg.num_blocks]);
        let naive = CostModel::new(None).forecast(Some(&p), &cfg);
        let elided = CostModel::new(Some(1.5)).forecast(Some(&p), &cfg);
        assert_eq!(naive.per_block, vec![4; cfg.num_blocks]);
        // pass at step 0, jump over 1-2, pass at step 3
        assert_eq!(elided.per_block, vec![2; cfg.num_blocks]);
        assert!(elided.remaining_window_passes < naive.remaining_window_passes);
    }

    #[test]
    fn trailing_empty_run_costs_one_landing_pass() {
        let cfg = tiny_config();
        // everything after step 0 predicted empty → early retirement
        let p = profile_with(vec![vec![4.0, 0.0, 0.0, 0.0, 1.0]; cfg.num_blocks]);
        let f = CostModel::new(Some(1.5)).forecast(Some(&p), &cfg);
        assert_eq!(f.per_block, vec![2; cfg.num_blocks]);
    }

    #[test]
    fn remaining_from_is_monotone_nonincreasing() {
        let cfg = tiny_config();
        let p = profile_with(vec![vec![2.0, 0.5, 1.0, 3.0, 2.0]; cfg.num_blocks]);
        for model in [CostModel::new(None), CostModel::new(Some(1.5))] {
            let f = model.forecast(Some(&p), &cfg);
            let mut prev = f.remaining_from(0, 0);
            assert_eq!(prev, f.remaining_window_passes);
            for b in 0..cfg.num_blocks {
                for s in 0..=cfg.block_len {
                    let now = f.remaining_from(b, s);
                    assert!(
                        now <= prev,
                        "forecast rose at block {b} step {s}: {now} > {prev}"
                    );
                    prev = now;
                }
            }
            assert_eq!(f.remaining_from(cfg.num_blocks, 0), 0);
        }
    }
}
