//! Continuous-batching step scheduler (DESIGN.md §5).
//!
//! A FIFO work queue over [`DecodeTask`]s in the style of a sequencer's
//! transaction pool: sequences are **admitted** at any step boundary, join
//! the shared forward passes on their next step, and **retire the moment
//! they finish** instead of holding the batch hostage for its slowest
//! member (the lockstep failure mode of the old `decode_batch`).
//!
//! One [`StepScheduler::step`] call advances every active sequence by
//! exactly one policy decision, grouping compatible passes:
//!
//! - `FullKv` refreshes run batch-1 (the runtime contract for
//!   `fwd_full_kv`) — they are rare, once per block per sequence;
//! - uncached `Full` passes share batched `fwd_conf` calls;
//! - in-block `Window` passes share batched `fwd_window_batch` calls.
//!
//! Because every task owns its state (including its KV cache) and each
//! batched pass row is computed from that task's tokens alone, scheduling
//! decisions never change per-sequence results: cached + batched decode is
//! token-identical to solo decode, which the integration tests assert.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::cache::{CacheConfig, CacheHandle};
use crate::policy::Policy;

use super::task::{DecodeTask, PassKind};
use super::{DecodeResult, ForwardModel};

/// Anything that can lend a `&dyn Policy` for a step decision. Lets the
/// scheduler hold owned policies (`Box<dyn Policy>` — the coordinator's
/// case) or borrowed ones (`&dyn Policy` — the [`super::Engine`] API).
pub trait PolicyRef {
    fn as_policy(&self) -> &dyn Policy;
}

impl PolicyRef for Box<dyn Policy> {
    fn as_policy(&self) -> &dyn Policy {
        &**self
    }
}

impl PolicyRef for &dyn Policy {
    fn as_policy(&self) -> &dyn Policy {
        *self
    }
}

struct Entry<P: PolicyRef> {
    id: u64,
    task: DecodeTask,
    policy: P,
}

/// What one scheduler step did.
#[derive(Debug, Default)]
pub struct StepReport {
    /// Sequences that finished this step, in active-slot order.
    pub retired: Vec<(u64, DecodeResult)>,
    /// Sequences that shared this step's forward passes.
    pub occupancy: usize,
    /// Forward-model invocations (batched calls count once).
    pub model_calls: usize,
    /// Per-sequence full passes executed (fwd_conf rows + fwd_full_kv).
    pub full_passes: usize,
    /// Per-sequence window passes executed (fwd_window_batch rows).
    pub window_passes: usize,
}

/// FIFO continuous-batching scheduler over one forward model.
pub struct StepScheduler<'m, M: ForwardModel, P: PolicyRef> {
    model: &'m M,
    cache: CacheConfig,
    max_active: usize,
    /// Admitted, waiting for a free slot (FIFO).
    waiting: VecDeque<Entry<P>>,
    /// Running sequences; at most `max_active`.
    active: Vec<Entry<P>>,
}

impl<'m, M: ForwardModel, P: PolicyRef> StepScheduler<'m, M, P> {
    /// `max_active` is clamped to `[1, model.max_batch()]`.
    pub fn new(model: &'m M, cache: CacheConfig, max_active: usize) -> Self {
        let max_active = max_active.clamp(1, model.max_batch().max(1));
        StepScheduler {
            model,
            cache,
            max_active,
            waiting: VecDeque::new(),
            active: Vec::new(),
        }
    }

    /// Admit a sequence; it joins the shared passes at the next step
    /// boundary (immediately if a slot is free). `id` must be unique among
    /// currently scheduled sequences. There is no admission cap — beyond
    /// `max_active`, sequences queue FIFO.
    pub fn admit(&mut self, id: u64, layout: Vec<u32>, policy: P) -> Result<()> {
        if self.waiting.iter().any(|e| e.id == id)
            || self.active.iter().any(|e| e.id == id)
        {
            bail!("sequence id {id} is already scheduled");
        }
        let task = DecodeTask::new(layout, self.model.config(), self.cache)?;
        self.waiting.push_back(Entry { id, task, policy });
        Ok(())
    }

    /// Sequences currently sharing forward passes.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Admitted sequences waiting for a slot.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Total scheduled sequences (active + waiting).
    pub fn scheduled_len(&self) -> usize {
        self.active.len() + self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    /// Fill free active slots from the waiting queue (FIFO).
    fn promote(&mut self) {
        while self.active.len() < self.max_active {
            match self.waiting.pop_front() {
                Some(e) => self.active.push(e),
                None => break,
            }
        }
    }

    /// Advance every active sequence by one policy decision, then retire
    /// whatever finished. Waiting sequences are promoted first, so a
    /// mid-flight admission joins here.
    pub fn step(&mut self) -> Result<StepReport> {
        self.promote();
        let mut report = StepReport {
            occupancy: self.active.len(),
            ..StepReport::default()
        };
        if self.active.is_empty() {
            return Ok(report);
        }
        let model = self.model;
        let cfg = model.config();

        let mut full: Vec<usize> = Vec::new();
        let mut full_kv: Vec<usize> = Vec::new();
        let mut window: Vec<usize> = Vec::new();
        for (i, e) in self.active.iter().enumerate() {
            match e.task.needs(cfg) {
                PassKind::Full => full.push(i),
                PassKind::FullKv => full_kv.push(i),
                PassKind::Window { .. } => window.push(i),
                PassKind::Done => {} // retired below without a pass
            }
        }

        // ---- block-boundary cache refreshes (batch-1 by runtime contract)
        for &i in &full_kv {
            let (out, kv) = model.fwd_full_kv(self.active[i].task.tokens())?;
            if out.is_empty() {
                bail!("fwd_full_kv returned no rows");
            }
            let e = &mut self.active[i];
            e.task.install_cache(kv);
            e.task.apply(
                cfg,
                e.policy.as_policy(),
                PassKind::FullKv,
                out.conf_row(0),
                out.argmax_row(0),
            );
            report.model_calls += 1;
            report.full_passes += 1;
        }

        // ---- batched uncached full passes
        for chunk in full.chunks(self.max_active) {
            let out = {
                let batch: Vec<&[u32]> = chunk
                    .iter()
                    .map(|&i| self.active[i].task.tokens())
                    .collect();
                model.fwd_conf(&batch)?
            };
            if out.len() < chunk.len() {
                bail!(
                    "fwd_conf returned {} rows for a batch of {}",
                    out.len(),
                    chunk.len()
                );
            }
            for (row, &i) in chunk.iter().enumerate() {
                let e = &mut self.active[i];
                e.task.apply(
                    cfg,
                    e.policy.as_policy(),
                    PassKind::Full,
                    out.conf_row(row),
                    out.argmax_row(row),
                );
            }
            report.model_calls += 1;
            report.full_passes += chunk.len();
        }

        // ---- batched in-block window passes
        for chunk in window.chunks(self.max_active) {
            let mut starts: Vec<usize> = Vec::with_capacity(chunk.len());
            let out = {
                let mut windows: Vec<&[u32]> = Vec::with_capacity(chunk.len());
                let mut caches: Vec<&CacheHandle> = Vec::with_capacity(chunk.len());
                for &i in chunk {
                    let t = &self.active[i].task;
                    let start = match t.needs(cfg) {
                        PassKind::Window { start } => start,
                        other => bail!("window group holds a {other:?} task"),
                    };
                    starts.push(start);
                    windows.push(t.window(cfg));
                    match t.cache() {
                        Some(c) => caches.push(c),
                        None => bail!("window pass without an installed cache"),
                    }
                }
                model.fwd_window_batch(&windows, &starts, &caches)?
            };
            if out.len() < chunk.len() {
                bail!(
                    "fwd_window_batch returned {} rows for a batch of {}",
                    out.len(),
                    chunk.len()
                );
            }
            for (row, &i) in chunk.iter().enumerate() {
                let e = &mut self.active[i];
                e.task.apply(
                    cfg,
                    e.policy.as_policy(),
                    PassKind::Window { start: starts[row] },
                    out.conf_row(row),
                    out.argmax_row(row),
                );
            }
            report.model_calls += 1;
            report.window_passes += chunk.len();
        }

        // ---- retire finished sequences immediately
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].task.is_done() {
                let e = self.active.remove(i);
                report.retired.push((e.id, e.task.into_result()));
            } else {
                i += 1;
            }
        }
        Ok(report)
    }

    /// Step until every scheduled sequence has retired; returns all results.
    pub fn drain(&mut self) -> Result<Vec<(u64, DecodeResult)>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            let mut report = self.step()?;
            out.append(&mut report.retired);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{SequentialTopK, StaticThreshold};
    use crate::sim::SimModel;

    fn sched(m: &SimModel, cache: CacheConfig) -> StepScheduler<'_, SimModel, &dyn Policy> {
        StepScheduler::new(m, cache, m.max_batch())
    }

    #[test]
    fn empty_scheduler_is_idle() {
        let m = SimModel::math_like(1);
        let mut s = sched(&m, CacheConfig::disabled());
        assert!(s.is_idle());
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 0);
        assert!(r.retired.is_empty());
    }

    #[test]
    fn duplicate_ids_rejected() {
        let m = SimModel::math_like(1);
        let p = StaticThreshold::new(0.9);
        let mut s = sched(&m, CacheConfig::disabled());
        s.admit(7, m.layout_from_seed(0), &p).unwrap();
        assert!(s.admit(7, m.layout_from_seed(1), &p).is_err());
    }

    #[test]
    fn waiting_sequences_promote_as_slots_free() {
        let m = SimModel::math_like(5);
        let p = SequentialTopK::new(1);
        let mut s = sched(&m, CacheConfig::disabled());
        let n = m.max_batch() + 2;
        for i in 0..n {
            s.admit(i as u64, m.layout_from_seed(i as u64), &p as &dyn Policy)
                .unwrap();
        }
        assert_eq!(s.scheduled_len(), n);
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, m.max_batch(), "slots cap the step batch");
        assert_eq!(s.waiting_len(), n - m.max_batch());
        let results = s.drain().unwrap();
        assert_eq!(results.len() + r.retired.len(), n);
        assert!(s.is_idle());
    }

    #[test]
    fn occupancy_counts_mixed_pass_kinds() {
        // cached sequences at different phases still share one step
        let m = SimModel::math_like(6);
        let p = StaticThreshold::new(0.9);
        let mut s = sched(&m, CacheConfig::block_boundary());
        s.admit(0, m.layout_from_seed(0), &p as &dyn Policy).unwrap();
        // one step: seq 0 moves past its block-boundary refresh
        assert_eq!(s.step().unwrap().full_passes, 1);
        s.admit(1, m.layout_from_seed(1), &p as &dyn Policy).unwrap();
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 2);
        // seq 0 is mid-block (window), seq 1 at its boundary (full_kv)
        assert_eq!(r.full_passes + r.window_passes, 2);
    }
}
