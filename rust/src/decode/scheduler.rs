//! Continuous-batching step scheduler (DESIGN.md §5).
//!
//! A FIFO work queue over [`DecodeTask`]s in the style of a sequencer's
//! transaction pool: sequences are **admitted** at any step boundary, join
//! the shared forward passes on their next step, and **retire the moment
//! they finish** instead of holding the batch hostage for its slowest
//! member (the lockstep failure mode of the old `decode_batch`).
//!
//! One [`StepScheduler::step`] call advances every active sequence by
//! exactly one policy decision, grouping compatible passes:
//!
//! - `FullKv` refreshes run batch-1 (the runtime contract for
//!   `fwd_full_kv`) — they are rare, once per block per sequence;
//! - uncached `Full` passes share batched `fwd_conf` calls;
//! - in-block `Window` passes share batched `fwd_window_batch` calls.
//!
//! Because every task owns its state (including its KV cache) and each
//! batched pass row is computed from that task's tokens alone, scheduling
//! decisions never change per-sequence results: cached + batched decode is
//! token-identical to solo decode, which the integration tests assert.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::cache::{CacheConfig, CacheHandle, PrefixHit, SharedKv};
use crate::policy::{PlanContext, Policy, StepRule};
use crate::runtime::AcceptRule;

use super::task::{DecodeTask, PassKind};
use super::{DecodeResult, ForwardModel, StepForecast};

/// Alignment-aware promotion passes over a waiting entry at most this many
/// times before force-promoting it regardless of band fit — the fairness
/// valve that bounds how long a misaligned row can wait behind aligned ones.
const MAX_PROMOTE_SKIPS: u32 = 8;

/// Anything that can lend a `&dyn Policy` for a step decision. Lets the
/// scheduler hold owned policies (`Box<dyn Policy>` — the coordinator's
/// case) or borrowed ones (`&dyn Policy` — the [`super::Engine`] API).
pub trait PolicyRef {
    fn as_policy(&self) -> &dyn Policy;
}

impl PolicyRef for Box<dyn Policy> {
    fn as_policy(&self) -> &dyn Policy {
        &**self
    }
}

impl PolicyRef for &dyn Policy {
    fn as_policy(&self) -> &dyn Policy {
        *self
    }
}

struct Entry<P: PolicyRef> {
    id: u64,
    task: DecodeTask,
    policy: P,
    /// Prefix-index hit stashed by the admission-time probe, consumed at
    /// this sequence's first block-boundary refresh instead of a model
    /// call (pages stay pinned while the sequence waits for a slot).
    prefix: Option<PrefixHit>,
    /// Admission-time cost forecast (DESIGN.md §15). Advisory only: it
    /// steers promotion order and grouping, never a decode decision.
    forecast: Option<StepForecast>,
    /// Times alignment-aware promotion passed over this waiting entry.
    skipped: u32,
}

impl<P: PolicyRef> Entry<P> {
    /// Predicted window passes left for this sequence at its current
    /// schedule position — the alignment signal promotion compares.
    fn predicted_remaining(&self) -> Option<usize> {
        self.forecast
            .as_ref()
            .map(|f| f.remaining_from(self.task.block(), self.task.step_in_block()))
    }
}

/// What one scheduler step did.
#[derive(Debug, Default)]
pub struct StepReport {
    /// Sequences that finished this step, in active-slot order.
    pub retired: Vec<(u64, DecodeResult)>,
    /// Sequences that shared this step's forward passes.
    pub occupancy: usize,
    /// Forward-model invocations (batched calls count once).
    pub model_calls: usize,
    /// Per-sequence full passes executed (fwd_conf rows + fwd_full_kv).
    pub full_passes: usize,
    /// Per-sequence window passes executed (fused + host rows).
    pub window_passes: usize,
    /// The subset of `window_passes` that ran through the fused
    /// `fwd_window_accept` path (device-side decision, compact download).
    pub fused_window_passes: usize,
    /// `(sequence id, tokens committed)` per advanced sequence this step,
    /// in processing order — the serving `accepted_per_step` histogram's
    /// raw material, and (via the id) the coordinator's TTFT anchor: a
    /// sequence's first entry with a non-zero count is its first token.
    /// Only live rows appear here: padding rows of a bucketed pass never
    /// report commits.
    pub accepted: Vec<(u64, usize)>,
    /// `fwd_full_kv` calls skipped via a prompt-prefix index hit (counted
    /// inside `full_passes` — the pass is attributed, not executed).
    pub saved_full_passes: usize,
    /// KV pages reused by reference across prefix hits this step.
    pub pages_reused: usize,
    /// Live pages in the paged pool after this step (0 without sharing).
    pub kv_pages_in_use: usize,
    /// Padding rows implied by bucket selection across this step's
    /// window/fused groups (bucket size minus live rows, summed). Elided
    /// schedule steps are NOT padding: they never enter a group at all
    /// (the live-rows-only invariant, DESIGN.md §13/§14).
    pub padding_rows: usize,
    /// `(live rows, chosen bucket)` per co-executed window/fused group —
    /// the bucket-occupancy histogram's raw material.
    pub window_groups: Vec<(usize, usize)>,
    /// Schedule steps jumped over by the elision planner this step
    /// (DESIGN.md §14) — no pass ran for them.
    pub steps_elided: usize,
    /// Elision mispredictions detected this step (an elided-over run whose
    /// jumped-to pass accepted nothing by rule).
    pub elision_mispredictions: usize,
    /// Blocks retired early this step (completed with elided steps).
    pub blocks_retired_early: usize,
    /// Sharable block-0 refreshes whose device-resident cache handle
    /// exposed no host K/V, so the prefix-sharing index could not be
    /// populated (DESIGN.md §13 limitation, observable via metrics).
    pub prefix_sharing_skipped_device: usize,
    /// Per co-executed window/fused group with ≥ 2 forecast-stamped rows:
    /// the spread (max − min) of predicted remaining passes across the
    /// group — the `group_alignment_drag` histogram's raw material. High
    /// values mean a near-done straggler shared buckets with fresh rows.
    pub alignment_drag: Vec<usize>,
}

/// FIFO continuous-batching scheduler over one forward model.
pub struct StepScheduler<'m, M: ForwardModel, P: PolicyRef> {
    model: &'m M,
    cache: CacheConfig,
    max_active: usize,
    /// The model's window/fused batch buckets, ascending and deduped.
    /// Window groups chunk at the widest bucket; each chunk runs in the
    /// smallest bucket that fits it, the rest is accounted padding.
    buckets: Vec<usize>,
    /// Prompt-prefix index (DESIGN.md §13), when sharing is active.
    shared: Option<SharedKv>,
    /// Route window steps of fusible-plan policies through the fused
    /// `fwd_window_accept` path (default). Drivers that need full per-step
    /// confidence traces from *every* policy — e.g. a registry running EMA
    /// refinement — switch this off.
    fused: bool,
    /// Admitted, waiting for a free slot (FIFO when `align_band == 0`).
    waiting: VecDeque<Entry<P>>,
    /// Running sequences; at most `max_active`.
    active: Vec<Entry<P>>,
    /// Alignment band for forecast-aware promotion (0 = plain FIFO):
    /// prefer filling a free slot with a waiting row whose predicted
    /// remaining passes land within `align_band` of the closest-to-done
    /// active row, so grouped rows retire together (DESIGN.md §15).
    align_band: usize,
}

impl<'m, M: ForwardModel, P: PolicyRef> StepScheduler<'m, M, P> {
    /// `max_active` is clamped to `[1, max(model.max_batch(), widest
    /// window bucket)]` — bucketed window variants let cached sequences
    /// co-execute wider than the conf-pass batch.
    pub fn new(model: &'m M, cache: CacheConfig, max_active: usize) -> Self {
        let mut buckets = model.window_buckets();
        buckets.sort_unstable();
        buckets.dedup();
        buckets.retain(|&b| b > 0);
        if buckets.is_empty() {
            buckets.push(model.max_batch().max(1));
        }
        let widest = *buckets.last().expect("non-empty above");
        let max_active = max_active.clamp(1, model.max_batch().max(widest));
        let shared = cache.sharing_active().then(|| {
            let c = model.config();
            SharedKv::new(
                [c.n_layers, c.n_heads, c.seq_len, c.head_dim],
                c.prompt_len,
                cache.kv_page_len,
                crate::cache::DEFAULT_MAX_KV_PAGES,
            )
        });
        StepScheduler {
            model,
            cache,
            max_active,
            buckets,
            shared,
            fused: true,
            waiting: VecDeque::new(),
            active: Vec::new(),
            align_band: 0,
        }
    }

    /// Replace the prefix index (engines inject their own so schedulers
    /// rebuilt after an error keep accumulated entries). `None` disables
    /// sharing for this scheduler.
    pub fn set_shared_kv(&mut self, shared: Option<SharedKv>) {
        self.shared = shared;
    }

    pub fn shared_kv(&self) -> Option<&SharedKv> {
        self.shared.as_ref()
    }

    /// The bucket ladder this scheduler groups window steps into.
    pub fn window_buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest bucket that fits `n` live rows (the dispatch rule,
    /// DESIGN.md §13); `n` itself when every bucket is smaller.
    fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(n)
    }

    /// Enable/disable the fused device-acceptance fast path (on by
    /// default). Disabling never changes tokens — only where the decision
    /// runs and how much of each step's confidences reach the trace.
    pub fn set_fusion(&mut self, enabled: bool) {
        self.fused = enabled;
    }

    pub fn fusion(&self) -> bool {
        self.fused
    }

    /// Set the alignment band for forecast-aware promotion. `0` (the
    /// default) restores plain FIFO promotion. Like fusion, the band only
    /// changes *when* sequences run, never what they decode.
    pub fn set_align_band(&mut self, band: usize) {
        self.align_band = band;
    }

    pub fn align_band(&self) -> usize {
        self.align_band
    }

    /// Admit a sequence; it joins the shared passes at the next step
    /// boundary (immediately if a slot is free). `id` must be unique among
    /// currently scheduled sequences. There is no admission cap — beyond
    /// `max_active`, sequences queue FIFO.
    pub fn admit(&mut self, id: u64, layout: Vec<u32>, policy: P) -> Result<()> {
        self.admit_with_forecast(id, layout, policy, None)
    }

    /// [`StepScheduler::admit`] with an admission-time cost forecast
    /// attached. The forecast feeds alignment-aware promotion and the
    /// per-group drag report; it is never consulted by the decode itself.
    pub fn admit_with_forecast(
        &mut self,
        id: u64,
        layout: Vec<u32>,
        policy: P,
        forecast: Option<StepForecast>,
    ) -> Result<()> {
        if self.waiting.iter().any(|e| e.id == id)
            || self.active.iter().any(|e| e.id == id)
        {
            bail!("sequence id {id} is already scheduled");
        }
        let task = DecodeTask::new(layout, self.model.config(), self.cache)?;
        // admission-time prefix probe: an admitted layout is exactly the
        // block-0 refresh input (prompt ‖ all-[MASK]), so a hit here pins
        // the template's pages for consumption at the first FullKv step
        let prefix = self
            .shared
            .as_ref()
            .and_then(|s| s.probe(task.tokens()));
        self.waiting.push_back(Entry {
            id,
            task,
            policy,
            prefix,
            forecast,
            skipped: 0,
        });
        Ok(())
    }

    /// Sequences currently sharing forward passes.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Admitted sequences waiting for a slot.
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    /// Total scheduled sequences (active + waiting).
    pub fn scheduled_len(&self) -> usize {
        self.active.len() + self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    /// Fill free active slots from the waiting queue. With `align_band ==
    /// 0` this is plain FIFO; otherwise each slot prefers the earliest
    /// waiting row whose predicted remaining passes land within the band
    /// of the closest-to-done active row, falling back to the queue front
    /// so a slot never idles while work waits. Passed-over rows accrue a
    /// skip count and are force-promoted at [`MAX_PROMOTE_SKIPS`].
    fn promote(&mut self) {
        while self.active.len() < self.max_active {
            let Some(idx) = self.next_waiting() else { break };
            for e in self.waiting.iter_mut().take(idx) {
                e.skipped += 1;
            }
            let e = self.waiting.remove(idx).expect("index from next_waiting");
            self.active.push(e);
        }
    }

    /// Index into `waiting` of the next row to promote, or `None` when
    /// the queue is empty.
    fn next_waiting(&self) -> Option<usize> {
        if self.waiting.is_empty() {
            return None;
        }
        if self.align_band == 0 {
            return Some(0);
        }
        // fairness valve: anything passed over too often goes first
        if let Some(i) = self
            .waiting
            .iter()
            .position(|e| e.skipped >= MAX_PROMOTE_SKIPS)
        {
            return Some(i);
        }
        // anchor on the active row closest to retirement; with no
        // forecast-stamped active rows there is nothing to align to
        let Some(anchor) = self
            .active
            .iter()
            .filter_map(Entry::predicted_remaining)
            .min()
        else {
            return Some(0);
        };
        let aligned = self.waiting.iter().position(|e| {
            e.predicted_remaining()
                .map_or(true, |p| p.abs_diff(anchor) <= self.align_band)
        });
        Some(aligned.unwrap_or(0))
    }

    /// Spread (max − min) of predicted remaining passes across a group's
    /// forecast-stamped rows; `None` below two data points (a singleton
    /// has no one to drag).
    fn group_drag(entries: &[Entry<P>], idxs: impl Iterator<Item = usize>) -> Option<usize> {
        let mut lo = usize::MAX;
        let mut hi = 0usize;
        let mut n = 0usize;
        for i in idxs {
            if let Some(p) = entries[i].predicted_remaining() {
                lo = lo.min(p);
                hi = hi.max(p);
                n += 1;
            }
        }
        (n >= 2).then(|| hi - lo)
    }

    /// Advance every active sequence by one policy decision, then retire
    /// whatever finished. Waiting sequences are promoted first, so a
    /// mid-flight admission joins here.
    pub fn step(&mut self) -> Result<StepReport> {
        self.promote();
        let mut report = StepReport {
            occupancy: self.active.len(),
            ..StepReport::default()
        };
        if self.active.is_empty() {
            return Ok(report);
        }
        let model = self.model;
        let cfg = model.config();

        // per-entry counter snapshot: elision mispredictions and early
        // block retirements accumulate inside the tasks during the apply
        // calls below; the report carries this step's deltas
        let pre_elision: Vec<(usize, usize)> = self
            .active
            .iter()
            .map(|e| {
                (
                    e.task.elision_mispredictions(),
                    e.task.blocks_retired_early(),
                )
            })
            .collect();

        let mut full: Vec<usize> = Vec::new();
        let mut full_kv: Vec<usize> = Vec::new();
        let mut window: Vec<usize> = Vec::new();
        // window steps whose policy advertised a device-fusible plan — the
        // per-row rules let threshold and factor-max rows share one fused
        // call, so a "mixed batch" splits only along fusible vs host-full
        let mut fused: Vec<(usize, AcceptRule)> = Vec::new();
        for i in 0..self.active.len() {
            match self.active[i].task.needs(cfg) {
                PassKind::Full => full.push(i),
                PassKind::FullKv => full_kv.push(i),
                PassKind::Window { .. } => {
                    // always consult the plan: elision applies on the host
                    // path too, only the *routing* depends on `self.fused`
                    let e = &mut self.active[i];
                    let plan = e.policy.as_policy().plan(&PlanContext {
                        block: e.task.block(),
                        step: e.task.step_in_block(),
                    });
                    if plan.skip_ahead > 0 {
                        // jump the schedule before grouping: the skipped
                        // steps never run a pass and never occupy bucket
                        // slots — only the jumped-to step executes below
                        let expect_accept = match plan.rule {
                            StepRule::Threshold { tau } => tau < 1.0,
                            StepRule::FactorMax { .. } => true,
                            StepRule::HostFull => false,
                        };
                        e.task.elide(plan.skip_ahead, expect_accept);
                        report.steps_elided += plan.skip_ahead;
                    }
                    if self.fused {
                        match plan.rule {
                            StepRule::Threshold { tau } => {
                                fused.push((i, AcceptRule::threshold(tau)))
                            }
                            StepRule::FactorMax { factor } => {
                                fused.push((i, AcceptRule::factor_max(factor)))
                            }
                            StepRule::HostFull => window.push(i),
                        }
                    } else {
                        window.push(i);
                    }
                }
                PassKind::Done => {} // retired below without a pass
            }
        }

        // ---- block-boundary cache refreshes (batch-1 by runtime contract)
        for &i in &full_kv {
            // prefix sharing applies only to the *first* refresh, where
            // the layout is the pure prompt template; later refreshes see
            // committed tokens and must run for real
            let sharable = self.shared.is_some()
                && self.active[i].task.block() == 0
                && self.active[i].task.step_in_block() == 0;
            let hit = if sharable {
                match self.active[i].prefix.take() {
                    stash @ Some(_) => stash,
                    // re-probe: a same-template sequence earlier in this
                    // very loop may have inserted since admission
                    None => self
                        .shared
                        .as_ref()
                        .and_then(|s| s.probe(self.active[i].task.tokens())),
                }
            } else {
                None
            };
            if let Some(hit) = hit {
                let e = &mut self.active[i];
                e.task.install_cache(CacheHandle::paged(hit.table));
                let n = e.task.apply(
                    cfg,
                    e.policy.as_policy(),
                    PassKind::FullKv,
                    &hit.conf,
                    &hit.argmax,
                );
                report.accepted.push((e.id, n));
                report.full_passes += 1; // attributed, not executed
                report.saved_full_passes += 1;
                report.pages_reused += hit.shared_pages;
                continue;
            }
            let (out, kv) = model.fwd_full_kv(self.active[i].task.tokens())?;
            if out.is_empty() {
                bail!("fwd_full_kv returned no rows");
            }
            // publish the refresh for followers of the same template (a
            // device-resident handle exposes no host KV and stays as-is —
            // counted so the silent index miss is observable, §13)
            let kv = match (sharable, &self.shared) {
                (true, Some(shared)) => match kv.host_kv() {
                    None => {
                        report.prefix_sharing_skipped_device += 1;
                        kv
                    }
                    Some(host) => match shared.insert(
                        self.active[i].task.tokens(),
                        out.conf_row(0),
                        out.argmax_row(0),
                        &host,
                    ) {
                        Some(table) => CacheHandle::paged(table),
                        None => kv,
                    },
                },
                _ => kv,
            };
            let e = &mut self.active[i];
            e.task.install_cache(kv);
            let n = e.task.apply(
                cfg,
                e.policy.as_policy(),
                PassKind::FullKv,
                out.conf_row(0),
                out.argmax_row(0),
            );
            report.accepted.push((e.id, n));
            report.model_calls += 1;
            report.full_passes += 1;
        }

        // ---- batched uncached full passes (conf variants top out at
        // max_batch even when window buckets let max_active run wider)
        for chunk in full.chunks(model.max_batch().max(1)) {
            let out = {
                let batch: Vec<&[u32]> = chunk
                    .iter()
                    .map(|&i| self.active[i].task.tokens())
                    .collect();
                model.fwd_conf(&batch)?
            };
            if out.len() < chunk.len() {
                bail!(
                    "fwd_conf returned {} rows for a batch of {}",
                    out.len(),
                    chunk.len()
                );
            }
            for (row, &i) in chunk.iter().enumerate() {
                let e = &mut self.active[i];
                let n = e.task.apply(
                    cfg,
                    e.policy.as_policy(),
                    PassKind::Full,
                    out.conf_row(row),
                    out.argmax_row(row),
                );
                report.accepted.push((e.id, n));
            }
            report.model_calls += 1;
            report.full_passes += chunk.len();
        }

        // ---- batched in-block window passes (host-full plans), grouped
        // up to the widest compiled bucket
        let widest = *self.buckets.last().expect("buckets non-empty");
        for chunk in window.chunks(widest) {
            let bucket = self.bucket_for(chunk.len());
            report.padding_rows += bucket - chunk.len();
            report.window_groups.push((chunk.len(), bucket));
            if let Some(drag) = Self::group_drag(&self.active, chunk.iter().copied()) {
                report.alignment_drag.push(drag);
            }
            let mut starts: Vec<usize> = Vec::with_capacity(chunk.len());
            let out = {
                let mut windows: Vec<&[u32]> = Vec::with_capacity(chunk.len());
                let mut caches: Vec<&CacheHandle> = Vec::with_capacity(chunk.len());
                for &i in chunk {
                    let t = &self.active[i].task;
                    let start = match t.needs(cfg) {
                        PassKind::Window { start } => start,
                        other => bail!("window group holds a {other:?} task"),
                    };
                    starts.push(start);
                    windows.push(t.window(cfg));
                    match t.cache() {
                        Some(c) => caches.push(c),
                        None => bail!("window pass without an installed cache"),
                    }
                }
                model.fwd_window_batch(&windows, &starts, &caches)?
            };
            if out.len() < chunk.len() {
                bail!(
                    "fwd_window_batch returned {} rows for a batch of {}",
                    out.len(),
                    chunk.len()
                );
            }
            for (row, &i) in chunk.iter().enumerate() {
                let e = &mut self.active[i];
                let n = e.task.apply(
                    cfg,
                    e.policy.as_policy(),
                    PassKind::Window { start: starts[row] },
                    out.conf_row(row),
                    out.argmax_row(row),
                );
                report.accepted.push((e.id, n));
            }
            report.model_calls += 1;
            report.window_passes += chunk.len();
        }

        // ---- fused window passes: the decision runs on device, only the
        // compact acceptance comes back (DESIGN.md §11)
        for chunk in fused.chunks(widest) {
            let bucket = self.bucket_for(chunk.len());
            report.padding_rows += bucket - chunk.len();
            report.window_groups.push((chunk.len(), bucket));
            if let Some(drag) = Self::group_drag(&self.active, chunk.iter().map(|&(i, _)| i)) {
                report.alignment_drag.push(drag);
            }
            let mut starts: Vec<usize> = Vec::with_capacity(chunk.len());
            let out = {
                let mut windows: Vec<&[u32]> = Vec::with_capacity(chunk.len());
                let mut caches: Vec<&CacheHandle> = Vec::with_capacity(chunk.len());
                let mut rules: Vec<AcceptRule> = Vec::with_capacity(chunk.len());
                for &(i, rule) in chunk {
                    let t = &self.active[i].task;
                    let start = match t.needs(cfg) {
                        PassKind::Window { start } => start,
                        other => bail!("fused group holds a {other:?} task"),
                    };
                    starts.push(start);
                    windows.push(t.window(cfg));
                    rules.push(rule);
                    match t.cache() {
                        Some(c) => caches.push(c),
                        None => bail!("fused window pass without an installed cache"),
                    }
                }
                model.fwd_window_accept(&windows, &starts, &caches, &rules)?
            };
            if out.len() < chunk.len() {
                bail!(
                    "fwd_window_accept returned {} rows for a batch of {}",
                    out.len(),
                    chunk.len()
                );
            }
            for (row, &(i, _)) in chunk.iter().enumerate() {
                let e = &mut self.active[i];
                let n = e.task.apply_accept(
                    cfg,
                    starts[row],
                    out.row(row),
                    out.step_mean(row),
                    out.fell_back(row),
                );
                report.accepted.push((e.id, n));
            }
            report.model_calls += 1;
            report.window_passes += chunk.len();
            report.fused_window_passes += chunk.len();
        }

        // ---- fold this step's per-task elision counter deltas into the
        // report (active order is stable until the retire loop below)
        for (e, &(m0, b0)) in self.active.iter().zip(&pre_elision) {
            report.elision_mispredictions += e.task.elision_mispredictions() - m0;
            report.blocks_retired_early += e.task.blocks_retired_early() - b0;
        }

        // ---- retire finished sequences immediately
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].task.is_done() {
                let e = self.active.remove(i);
                report.retired.push((e.id, e.task.into_result()));
            } else {
                i += 1;
            }
        }
        if let Some(shared) = &self.shared {
            report.kv_pages_in_use = shared.stats().pool.pages_in_use;
        }
        Ok(report)
    }

    /// Step until every scheduled sequence has retired; returns all results.
    pub fn drain(&mut self) -> Result<Vec<(u64, DecodeResult)>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            let mut report = self.step()?;
            out.append(&mut report.retired);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{SequentialTopK, StaticThreshold};
    use crate::sim::SimModel;

    fn sched(m: &SimModel, cache: CacheConfig) -> StepScheduler<'_, SimModel, &dyn Policy> {
        StepScheduler::new(m, cache, m.max_batch())
    }

    #[test]
    fn empty_scheduler_is_idle() {
        let m = SimModel::math_like(1);
        let mut s = sched(&m, CacheConfig::disabled());
        assert!(s.is_idle());
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 0);
        assert!(r.retired.is_empty());
    }

    #[test]
    fn duplicate_ids_rejected() {
        let m = SimModel::math_like(1);
        let p = StaticThreshold::new(0.9);
        let mut s = sched(&m, CacheConfig::disabled());
        s.admit(7, m.layout_from_seed(0), &p).unwrap();
        assert!(s.admit(7, m.layout_from_seed(1), &p).is_err());
    }

    #[test]
    fn waiting_sequences_promote_as_slots_free() {
        let m = SimModel::math_like(5);
        let p = SequentialTopK::new(1);
        let mut s = sched(&m, CacheConfig::disabled());
        let n = m.max_batch() + 2;
        for i in 0..n {
            s.admit(i as u64, m.layout_from_seed(i as u64), &p as &dyn Policy)
                .unwrap();
        }
        assert_eq!(s.scheduled_len(), n);
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, m.max_batch(), "slots cap the step batch");
        assert_eq!(s.waiting_len(), n - m.max_batch());
        let results = s.drain().unwrap();
        assert_eq!(results.len() + r.retired.len(), n);
        assert!(s.is_idle());
    }

    #[test]
    fn window_steps_split_fused_and_host_groups() {
        // a fusible policy (static) and a host-full one (top-k) share a
        // cached step: the scheduler must split the window group, running
        // one fused call and one host call
        let m = SimModel::math_like(8);
        let stat = StaticThreshold::new(0.9);
        let topk = SequentialTopK::new(2);
        let mut s = sched(&m, CacheConfig::block_boundary());
        s.admit(0, m.layout_from_seed(0), &stat as &dyn Policy).unwrap();
        s.admit(1, m.layout_from_seed(1), &topk as &dyn Policy).unwrap();
        let r0 = s.step().unwrap(); // both at their block-boundary refresh
        assert_eq!(r0.full_passes, 2);
        assert_eq!(r0.fused_window_passes, 0, "refreshes never fuse");
        assert_eq!(r0.accepted.len(), 2, "every advanced row reports commits");
        let r1 = s.step().unwrap(); // both in-block
        assert_eq!(r1.window_passes, 2);
        assert_eq!(r1.fused_window_passes, 1, "only the static row fuses");
        assert_eq!(r1.model_calls, 2, "fused and host groups are separate calls");
        assert!(r1.accepted.iter().all(|&(_, n)| n >= 1), "liveness per row");
        let mut ids: Vec<u64> = r1.accepted.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1], "each advanced sequence reports its id");
    }

    #[test]
    fn fusion_toggle_changes_path_not_tokens() {
        let m = SimModel::qa_like(9);
        let p = StaticThreshold::new(0.88);
        let run = |fusion: bool| {
            let mut s = sched(&m, CacheConfig::block_boundary());
            s.set_fusion(fusion);
            assert_eq!(s.fusion(), fusion);
            s.admit(0, m.layout_from_seed(3), &p as &dyn Policy).unwrap();
            let mut fused_passes = 0;
            let mut results = Vec::new();
            while !s.is_idle() {
                let r = s.step().unwrap();
                fused_passes += r.fused_window_passes;
                results.extend(r.retired);
            }
            (results.pop().unwrap().1, fused_passes)
        };
        let (on, fused_on) = run(true);
        let (off, fused_off) = run(false);
        assert!(fused_on > 0, "fusible policy must take the fused path");
        assert_eq!(fused_off, 0, "toggle must force the host path");
        assert_eq!(on.tokens, off.tokens, "fusion must not change tokens");
        assert_eq!(on.steps, off.steps);
        assert_eq!(on.fallback_steps, off.fallback_steps);
    }

    fn forecast(per_block: Vec<usize>) -> StepForecast {
        let remaining: usize = per_block.iter().sum();
        StepForecast {
            remaining_window_passes: remaining,
            total_passes: remaining + per_block.len(),
            per_block,
            calibrated: true,
        }
    }

    fn accepted_ids(r: &StepReport) -> Vec<u64> {
        let mut ids: Vec<u64> = r.accepted.iter().map(|&(id, _)| id).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    #[test]
    fn alignment_band_prefers_aligned_waiting_rows() {
        let m = SimModel::math_like(11);
        let p = StaticThreshold::new(0.9);
        let mut s = StepScheduler::new(&m, CacheConfig::disabled(), 2);
        s.set_align_band(8);
        assert_eq!(s.align_band(), 8);
        let long = || forecast(vec![32, 32, 32]);
        let short = forecast(vec![1, 1, 1]);
        s.admit_with_forecast(0, m.layout_from_seed(0), &p as &dyn Policy, Some(long()))
            .unwrap();
        s.admit_with_forecast(1, m.layout_from_seed(1), &p as &dyn Policy, Some(short))
            .unwrap();
        s.admit_with_forecast(2, m.layout_from_seed(2), &p as &dyn Policy, Some(long()))
            .unwrap();
        // two slots: seq 0 anchors, seq 1 (predicted 3 vs 96) is out of
        // band, seq 2 is aligned and jumps the queue
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 2);
        assert_eq!(accepted_ids(&r), vec![0, 2], "aligned row promoted first");
        assert_eq!(s.waiting_len(), 1);
        // the passed-over row still completes — no starvation
        let results = s.drain().unwrap();
        assert_eq!(results.len() + r.retired.len(), 3);
        assert!(s.is_idle());
    }

    #[test]
    fn misaligned_rows_never_idle_a_slot() {
        let m = SimModel::math_like(12);
        let p = StaticThreshold::new(0.9);
        let mut s = StepScheduler::new(&m, CacheConfig::disabled(), 2);
        s.set_align_band(1);
        s.admit_with_forecast(0, m.layout_from_seed(0), &p as &dyn Policy, Some(forecast(vec![32, 32, 32])))
            .unwrap();
        s.step().unwrap(); // seq 0 occupies a slot and advances
        s.admit_with_forecast(1, m.layout_from_seed(1), &p as &dyn Policy, Some(forecast(vec![1, 1, 1])))
            .unwrap();
        // seq 1 is far outside the band, but it is the only candidate and
        // a slot is free: promotion must fall back to the queue front
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 2, "a free slot never idles while work waits");
    }

    #[test]
    fn forced_promotion_caps_skips() {
        let m = SimModel::math_like(13);
        let p = StaticThreshold::new(0.9);
        let mut s = StepScheduler::new(&m, CacheConfig::disabled(), 2);
        s.set_align_band(1);
        s.admit_with_forecast(0, m.layout_from_seed(0), &p as &dyn Policy, Some(forecast(vec![32, 32, 32])))
            .unwrap();
        s.step().unwrap(); // seq 0 active, anchor ≈ 96 remaining
        s.admit_with_forecast(1, m.layout_from_seed(1), &p as &dyn Policy, Some(forecast(vec![1, 1, 1])))
            .unwrap();
        s.admit_with_forecast(2, m.layout_from_seed(2), &p as &dyn Policy, Some(forecast(vec![32, 32, 32])))
            .unwrap();
        // seq 1 has exhausted its skip budget: the fairness valve promotes
        // it ahead of the better-aligned seq 2
        s.waiting.get_mut(0).unwrap().skipped = MAX_PROMOTE_SKIPS;
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 2);
        assert!(
            accepted_ids(&r).contains(&1),
            "skip-capped row must be force-promoted"
        );
        assert_eq!(s.waiting_len(), 1, "aligned seq 2 waits its turn");
    }

    #[test]
    fn alignment_drag_reported_for_forecast_groups() {
        let m = SimModel::math_like(14);
        let p = StaticThreshold::new(0.9);
        let mut s = StepScheduler::new(&m, CacheConfig::block_boundary(), 2);
        s.admit_with_forecast(0, m.layout_from_seed(0), &p as &dyn Policy, Some(forecast(vec![32, 32, 32])))
            .unwrap();
        s.admit_with_forecast(1, m.layout_from_seed(1), &p as &dyn Policy, Some(forecast(vec![32, 32, 32])))
            .unwrap();
        let r0 = s.step().unwrap(); // batch-1 refreshes: no co-executed group
        assert!(r0.alignment_drag.is_empty(), "refreshes never group");
        let r1 = s.step().unwrap(); // both in-block: one fused group of two
        assert_eq!(r1.window_passes, 2);
        assert_eq!(
            r1.alignment_drag.len(),
            1,
            "a two-row forecast group reports its drag"
        );
        // plain admit (no forecast) contributes no drag samples
        let mut bare = StepScheduler::new(&m, CacheConfig::block_boundary(), 2);
        bare.admit(0, m.layout_from_seed(0), &p as &dyn Policy).unwrap();
        bare.admit(1, m.layout_from_seed(1), &p as &dyn Policy).unwrap();
        bare.step().unwrap();
        let b1 = bare.step().unwrap();
        assert_eq!(b1.window_passes, 2);
        assert!(b1.alignment_drag.is_empty());
    }

    #[test]
    fn occupancy_counts_mixed_pass_kinds() {
        // cached sequences at different phases still share one step
        let m = SimModel::math_like(6);
        let p = StaticThreshold::new(0.9);
        let mut s = sched(&m, CacheConfig::block_boundary());
        s.admit(0, m.layout_from_seed(0), &p as &dyn Policy).unwrap();
        // one step: seq 0 moves past its block-boundary refresh
        assert_eq!(s.step().unwrap().full_passes, 1);
        s.admit(1, m.layout_from_seed(1), &p as &dyn Policy).unwrap();
        let r = s.step().unwrap();
        assert_eq!(r.occupancy, 2);
        // seq 0 is mid-block (window), seq 1 at its boundary (full_kv)
        assert_eq!(r.full_passes + r.window_passes, 2);
    }
}
