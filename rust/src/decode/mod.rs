//! The semi-autoregressive block diffusion decode engine (DESIGN.md §4).
//!
//! Sequence = prompt ‖ gen region, gen region split into `num_blocks`
//! contiguous blocks decoded left-to-right. Within a block, denoising steps
//! repeat until no `[MASK]` remains: a forward pass produces per-position
//! greedy confidence + candidate token; the active [`Policy`] selects which
//! masked positions to commit (always ≥ 1 — liveness).
//!
//! Two execution paths:
//! - **no-cache**: every step is a full forward (`fwd_conf`), batchable
//!   across sequences (continuous batching happens in the coordinator);
//! - **dual KV cache** (Fast-dLLM): one `fwd_full_kv` at each block start
//!   refreshes the cache *and* provides the step-0 prediction; subsequent
//!   steps run the cheap `fwd_window` variant over the active block only.

use anyhow::{bail, Result};

use crate::model::ModelConfig;
use crate::policy::{CalibrationTrace, Policy, StepContext};
use crate::runtime::{ConfOut, KvCache};

/// Abstraction over the PJRT runtime so the engine, tests, and the analytic
/// simulator share one decode loop. `ModelRuntime` implements this; so does
/// `sim::SimModel`.
pub trait ForwardModel {
    fn config(&self) -> &ModelConfig;
    fn max_batch(&self) -> usize;
    fn fwd_conf(&self, batch_tokens: &[Vec<u32>]) -> Result<ConfOut>;
    fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, KvCache)>;
    fn fwd_window(&self, window: &[u32], start: usize, cache: &KvCache) -> Result<ConfOut>;
}

impl ForwardModel for crate::runtime::ModelRuntime {
    fn config(&self) -> &ModelConfig {
        self.config()
    }
    fn max_batch(&self) -> usize {
        self.max_batch()
    }
    fn fwd_conf(&self, batch_tokens: &[Vec<u32>]) -> Result<ConfOut> {
        crate::runtime::ModelRuntime::fwd_conf(self, batch_tokens)
    }
    fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, KvCache)> {
        crate::runtime::ModelRuntime::fwd_full_kv(self, tokens)
    }
    fn fwd_window(&self, window: &[u32], start: usize, cache: &KvCache) -> Result<ConfOut> {
        crate::runtime::ModelRuntime::fwd_window(self, window, start, cache)
    }
}

/// Outcome of decoding one sequence.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// Full final sequence (prompt region + committed gen region).
    pub tokens: Vec<u32>,
    /// Total denoising steps (policy decisions) across blocks.
    pub steps: usize,
    /// Forward passes, split by kind (full == fwd_conf or fwd_full_kv).
    pub full_passes: usize,
    pub window_passes: usize,
    /// Steps where the policy's raw rule selected nothing and the argmax
    /// fallback committed the single most confident position.
    pub fallback_steps: usize,
    /// Per-(block, step) masked-position confidences — calibration input
    /// and Figure 1/2 raw material. Always recorded (cheap: few KB).
    pub trace: CalibrationTrace,
}

impl DecodeResult {
    /// The gen-region tokens.
    pub fn gen_tokens(&self, cfg: &ModelConfig) -> &[u32] {
        &self.tokens[cfg.gen_range()]
    }
}

/// Per-sequence decode state (shared by the single and batched loops).
struct SeqState {
    tokens: Vec<u32>,
    block: usize,
    step_in_block: usize,
    steps: usize,
    fallback_steps: usize,
    trace: CalibrationTrace,
    done: bool,
}

impl SeqState {
    fn new(tokens: Vec<u32>, cfg: &ModelConfig) -> Result<Self> {
        if tokens.len() != cfg.seq_len {
            bail!("layout length {} != seq_len {}", tokens.len(), cfg.seq_len);
        }
        Ok(SeqState {
            tokens,
            block: 0,
            step_in_block: 0,
            steps: 0,
            fallback_steps: 0,
            trace: CalibrationTrace::new(cfg.num_blocks),
            done: false,
        })
    }

    /// Masked positions (absolute) of the current block.
    fn masked(&self, cfg: &ModelConfig) -> Vec<usize> {
        cfg.block_range(self.block)
            .filter(|&p| self.tokens[p] == cfg.mask_id)
            .collect()
    }

    /// Run one policy decision given fresh conf/argmax covering the whole
    /// sequence (`offset`=0) or the active window (`offset`=window start).
    /// Returns the number of committed tokens.
    fn advance(
        &mut self,
        cfg: &ModelConfig,
        policy: &dyn Policy,
        conf: &[f32],
        argmax: &[u32],
        offset: usize,
    ) -> usize {
        let masked = self.masked(cfg);
        debug_assert!(!masked.is_empty(), "advance on completed block");
        let local_conf: Vec<f32> = masked.iter().map(|&p| conf[p - offset]).collect();
        self.trace
            .record(self.block, self.step_in_block, &local_conf);
        let ctx = StepContext {
            block: self.block,
            step: self.step_in_block,
            conf: &local_conf,
        };
        let (sel, fell_back) = policy.select_explain(&ctx);
        if fell_back {
            self.fallback_steps += 1;
        }
        debug_assert!(!sel.is_empty(), "policy liveness violated");
        for &i in &sel {
            let pos = masked[i];
            self.tokens[pos] = argmax[pos - offset];
        }
        self.steps += 1;
        self.step_in_block += 1;
        // roll over completed blocks
        while self.block < cfg.num_blocks && self.masked(cfg).is_empty() {
            self.block += 1;
            self.step_in_block = 0;
            if self.block == cfg.num_blocks {
                self.done = true;
                break;
            }
        }
        if self.block >= cfg.num_blocks {
            self.done = true;
        }
        sel.len()
    }

    fn into_result(self, full_passes: usize, window_passes: usize) -> DecodeResult {
        DecodeResult {
            tokens: self.tokens,
            steps: self.steps,
            full_passes,
            window_passes,
            fallback_steps: self.fallback_steps,
            trace: self.trace,
        }
    }
}

/// The decode engine: one forward model + execution options.
pub struct Engine<'m, M: ForwardModel> {
    model: &'m M,
    /// Fast-dLLM dual KV cache behaviour.
    pub cache: crate::cache::CacheConfig,
}

impl<'m, M: ForwardModel> Engine<'m, M> {
    pub fn new(model: &'m M) -> Self {
        Engine { model, cache: crate::cache::CacheConfig::disabled() }
    }

    pub fn with_kv_cache(model: &'m M) -> Self {
        Engine { model, cache: crate::cache::CacheConfig::block_boundary() }
    }

    pub fn with_cache(model: &'m M, cache: crate::cache::CacheConfig) -> Self {
        Engine { model, cache }
    }

    pub fn model(&self) -> &M {
        self.model
    }

    /// Decode one sequence (batch 1 — the paper's serving setup).
    pub fn decode(&self, layout: Vec<u32>, policy: &dyn Policy) -> Result<DecodeResult> {
        if self.cache.enabled {
            self.decode_cached(layout, policy)
        } else {
            Ok(self
                .decode_batch(vec![layout], &[policy])?
                .pop()
                .expect("one result"))
        }
    }

    /// Lockstep-batched decode without KV cache: each iteration runs one
    /// batched forward over all unfinished sequences, then one policy
    /// decision per sequence. Sequences finish independently.
    pub fn decode_batch(
        &self,
        layouts: Vec<Vec<u32>>,
        policies: &[&dyn Policy],
    ) -> Result<Vec<DecodeResult>> {
        let cfg = self.model.config();
        if layouts.len() != policies.len() {
            bail!("{} layouts vs {} policies", layouts.len(), policies.len());
        }
        if layouts.len() > self.model.max_batch() {
            bail!(
                "batch {} exceeds model max batch {}",
                layouts.len(),
                self.model.max_batch()
            );
        }
        let mut states = layouts
            .into_iter()
            .map(|l| SeqState::new(l, cfg))
            .collect::<Result<Vec<_>>>()?;
        let mut full_passes = vec![0usize; states.len()];

        loop {
            let active: Vec<usize> = (0..states.len())
                .filter(|&i| !states[i].done)
                .collect();
            if active.is_empty() {
                break;
            }
            let batch: Vec<Vec<u32>> =
                active.iter().map(|&i| states[i].tokens.clone()).collect();
            let out = self.model.fwd_conf(&batch)?;
            for (bi, &i) in active.iter().enumerate() {
                states[i].advance(cfg, policies[i], &out.conf[bi], &out.argmax[bi], 0);
                full_passes[i] += 1;
            }
        }
        Ok(states
            .into_iter()
            .zip(full_passes)
            .map(|(s, fp)| s.into_result(fp, 0))
            .collect())
    }

    /// Dual-KV-cache decode (batch 1): full pass at each block start (cache
    /// refresh + step-0 prediction), window passes within the block, with
    /// optional staleness-bounded re-refresh (`cache.refresh_interval`).
    fn decode_cached(&self, layout: Vec<u32>, policy: &dyn Policy) -> Result<DecodeResult> {
        let cfg = self.model.config();
        let mut st = SeqState::new(layout, cfg)?;
        let mut full_passes = 0usize;
        let mut window_passes = 0usize;

        while !st.done {
            let block = st.block;
            let range = cfg.block_range(block);
            // block start: refresh cache, use its prediction for step 0
            let (out, mut cache) = self.model.fwd_full_kv(&st.tokens)?;
            full_passes += 1;
            st.advance(cfg, policy, &out.conf[0], &out.argmax[0], 0);
            let mut since_refresh = 0usize;
            // within-block steps on the window path
            while !st.done && st.block == block {
                if self.cache.refresh_interval > 0
                    && since_refresh >= self.cache.refresh_interval
                {
                    let (out, fresh) = self.model.fwd_full_kv(&st.tokens)?;
                    cache = fresh;
                    full_passes += 1;
                    since_refresh = 0;
                    st.advance(cfg, policy, &out.conf[0], &out.argmax[0], 0);
                } else {
                    let window: Vec<u32> = st.tokens[range.clone()].to_vec();
                    let out = self.model.fwd_window(&window, range.start, &cache)?;
                    window_passes += 1;
                    since_refresh += 1;
                    st.advance(cfg, policy, &out.conf[0], &out.argmax[0], range.start);
                }
            }
        }
        Ok(st.into_result(full_passes, window_passes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{SequentialTopK, StaticThreshold};
    use crate::sim::SimModel;

    fn sim() -> SimModel {
        SimModel::math_like(7)
    }

    fn masked_layout(m: &SimModel) -> Vec<u32> {
        m.layout_from_seed(1)
    }

    #[test]
    fn sequential_top1_takes_gen_len_steps() {
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &SequentialTopK::new(1))
            .unwrap();
        let cfg = m.config();
        assert_eq!(res.steps, cfg.gen_len, "one token per step");
        assert_eq!(res.full_passes, cfg.gen_len);
        // nothing masked remains
        assert!(res.tokens[cfg.gen_range()]
            .iter()
            .all(|&t| t != cfg.mask_id));
    }

    #[test]
    fn static_threshold_fewer_steps_than_sequential() {
        let m = sim();
        let eng = Engine::new(&m);
        let seq = eng
            .decode(masked_layout(&m), &SequentialTopK::new(1))
            .unwrap();
        let par = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.9))
            .unwrap();
        assert!(par.steps < seq.steps, "{} !< {}", par.steps, seq.steps);
    }

    #[test]
    fn trace_covers_every_step() {
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.9))
            .unwrap();
        assert_eq!(res.trace.total_steps(), res.steps);
    }

    #[test]
    fn blocks_decode_left_to_right() {
        // after decoding, every token is set; trace must show blocks in
        // order with no interleaving (block b only starts once b-1 done)
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.8))
            .unwrap();
        for b in 0..m.config().num_blocks {
            assert!(
                !res.trace.per_block[b].is_empty(),
                "block {b} has no steps"
            );
        }
    }

    #[test]
    fn batched_results_match_individual() {
        let m = sim();
        let eng = Engine::new(&m);
        let p = StaticThreshold::new(0.85);
        let l1 = m.layout_from_seed(10);
        let l2 = m.layout_from_seed(20);
        let solo1 = eng.decode(l1.clone(), &p).unwrap();
        let solo2 = eng.decode(l2.clone(), &p).unwrap();
        let both = eng
            .decode_batch(vec![l1, l2], &[&p, &p])
            .unwrap();
        assert_eq!(both[0].tokens, solo1.tokens);
        assert_eq!(both[1].tokens, solo2.tokens);
        assert_eq!(both[0].steps, solo1.steps);
        assert_eq!(both[1].steps, solo2.steps);
    }

    #[test]
    fn cached_and_uncached_agree_when_model_is_cache_exact() {
        // SimModel's window path reproduces its full path exactly, so the
        // cached decode must produce identical tokens & steps.
        let m = sim();
        let plain = Engine::new(&m);
        let cached = Engine::with_kv_cache(&m);
        let p = StaticThreshold::new(0.9);
        let a = plain.decode(masked_layout(&m), &p).unwrap();
        let b = cached.decode(masked_layout(&m), &p).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.steps, b.steps);
        // cache path must be cheaper in full passes
        assert_eq!(b.full_passes, m.config().num_blocks);
        assert_eq!(b.window_passes, b.steps - b.full_passes);
    }

    #[test]
    fn rejects_wrong_layout_len() {
        let m = sim();
        let eng = Engine::new(&m);
        assert!(eng.decode(vec![0; 3], &SequentialTopK::new(1)).is_err());
    }

    #[test]
    fn rejects_oversized_batch() {
        let m = sim();
        let eng = Engine::new(&m);
        let p = SequentialTopK::new(1);
        let layouts: Vec<Vec<u32>> = (0..m.max_batch() + 1)
            .map(|i| m.layout_from_seed(i as u64))
            .collect();
        let policies: Vec<&dyn crate::policy::Policy> =
            layouts.iter().map(|_| &p as &dyn crate::policy::Policy).collect();
        assert!(eng.decode_batch(layouts, &policies).is_err());
    }
}
