//! The semi-autoregressive block diffusion decode engine (DESIGN.md §4–§5).
//!
//! Sequence = prompt ‖ gen region, gen region split into `num_blocks`
//! contiguous blocks decoded left-to-right. Within a block, denoising steps
//! repeat until no `[MASK]` remains: a forward pass produces per-position
//! greedy confidence + candidate token; the active [`Policy`] selects which
//! masked positions to commit (always ≥ 1 — liveness).
//!
//! Execution is one loop over resumable per-sequence state machines:
//!
//! - [`DecodeTask`] holds one sequence's decode state — including its
//!   Fast-dLLM dual KV cache — and exposes a `needs() -> PassKind` /
//!   `apply(..)` step API;
//! - [`StepScheduler`] drives many tasks with continuous batching: FIFO
//!   admission at any step boundary, compatible passes grouped into shared
//!   forwards, finished sequences retired immediately;
//! - [`Engine`] is the convenience facade: `decode` / `decode_batch` build
//!   a scheduler, admit, and drain. Cached and uncached, solo and batched
//!   all run the same scheduler loop, so batching × KV cache × any policy
//!   compose.

pub mod predict;
pub mod scheduler;
pub mod task;

pub use predict::{CostModel, StepForecast};
pub use scheduler::{PolicyRef, StepReport, StepScheduler};
pub use task::{DecodeTask, PassKind};

use anyhow::{bail, Context, Result};

use crate::cache::CacheHandle;
use crate::model::ModelConfig;
use crate::policy::{CalibrationTrace, Policy};
use crate::runtime::{accept_rows, AcceptOut, AcceptRule, ConfOut, RuntimeStats};

/// Abstraction over the PJRT runtime so the engine, tests, and the analytic
/// simulator share one decode loop. `ModelRuntime` implements this; so does
/// `sim::SimModel`.
///
/// The KV-cache contract is **handle-based** (DESIGN.md §10): a model mints
/// an opaque [`CacheHandle`] from `fwd_full_kv` and is the only party that
/// looks inside it when the window passes hand it back. The decode layer
/// just carries handles, so a device-resident cache never forces a host
/// round trip through the scheduler.
pub trait ForwardModel {
    fn config(&self) -> &ModelConfig;
    fn max_batch(&self) -> usize;
    /// Window/fused-accept batch sizes the backend executes natively
    /// (ascending, deduped). The scheduler groups window steps up to the
    /// widest bucket and accounts padding against the smallest bucket
    /// that fits each group (DESIGN.md §13). Defaults to a single bucket
    /// of [`ForwardModel::max_batch`] for backends without bucketed
    /// variants.
    fn window_buckets(&self) -> Vec<usize> {
        vec![self.max_batch().max(1)]
    }
    /// Full forward over a batch of borrowed sequences: per-position
    /// confidence + greedy candidate per row.
    fn fwd_conf(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut>;
    /// Block-boundary forward (batch 1): conf/argmax plus a refreshed dual
    /// KV cache behind an opaque handle.
    fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, CacheHandle)>;
    /// Within-block forward (batch 1) attending against `cache`.
    fn fwd_window(&self, window: &[u32], start: usize, cache: &CacheHandle)
        -> Result<ConfOut>;
    /// Batched window pass: same-shape windows from different sequences
    /// share one forward. Row `i` must equal `fwd_window(windows[i],
    /// starts[i], caches[i])` — the scheduler relies on this to keep
    /// batched results token-identical to solo decode. The default loops
    /// over [`ForwardModel::fwd_window`]; backends with a compiled batched
    /// variant override it.
    fn fwd_window_batch(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
    ) -> Result<ConfOut> {
        if windows.len() != starts.len() || windows.len() != caches.len() {
            bail!(
                "window batch arity mismatch: {} windows, {} starts, {} caches",
                windows.len(),
                starts.len(),
                caches.len()
            );
        }
        let row_len = self.config().block_len;
        let mut out = ConfOut::with_capacity(row_len, windows.len());
        for ((window, &start), cache) in windows.iter().zip(starts).zip(caches) {
            let row = self.fwd_window(window, start, cache)?;
            if row.is_empty() {
                bail!("fwd_window returned no rows");
            }
            out.append(row);
        }
        Ok(out)
    }
    /// Fused batched window pass + threshold acceptance (DESIGN.md §11):
    /// row `i` applies `rules[i]` (plus the argmax liveness fallback) to
    /// its own window's confidences and returns only compact acceptance —
    /// the scheduler's fast path for policies whose `plan()` is
    /// device-fusible. Row `i` must commit exactly the positions the
    /// policy's host-side `select_explain` would pick on the downloaded
    /// rows; backends get that for free from this default, which runs
    /// [`ForwardModel::fwd_window_batch`] and reduces it with the shared
    /// host reference rule [`accept_rows`]. The PJRT runtime overrides it
    /// with the compiled `fwd_window_accept_b{B}` executables, where the
    /// reduction happens on device and full confidence rows never cross
    /// the host boundary.
    fn fwd_window_accept(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
    ) -> Result<AcceptOut> {
        if windows.len() != rules.len() {
            bail!(
                "accept batch arity mismatch: {} windows, {} rules",
                windows.len(),
                rules.len()
            );
        }
        let out = self.fwd_window_batch(windows, starts, caches)?;
        if out.len() < windows.len() {
            bail!(
                "fwd_window_batch returned {} rows for a batch of {}",
                out.len(),
                windows.len()
            );
        }
        Ok(accept_rows(&out, windows, self.config().mask_id, rules))
    }

    /// Cumulative transfer/exec accounting, for backends that measure it
    /// (the PJRT runtime). Drivers publish deltas into serving metrics.
    fn runtime_stats(&self) -> Option<RuntimeStats> {
        None
    }
}

impl ForwardModel for crate::runtime::ModelRuntime {
    fn config(&self) -> &ModelConfig {
        self.config()
    }
    fn max_batch(&self) -> usize {
        self.max_batch()
    }
    fn window_buckets(&self) -> Vec<usize> {
        crate::runtime::ModelRuntime::window_buckets(self)
    }
    fn fwd_conf(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut> {
        crate::runtime::ModelRuntime::fwd_conf(self, batch_tokens)
    }
    fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, CacheHandle)> {
        crate::runtime::ModelRuntime::fwd_full_kv(self, tokens)
    }
    fn fwd_window(
        &self,
        window: &[u32],
        start: usize,
        cache: &CacheHandle,
    ) -> Result<ConfOut> {
        crate::runtime::ModelRuntime::fwd_window(self, window, start, cache)
    }
    fn fwd_window_batch(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
    ) -> Result<ConfOut> {
        crate::runtime::ModelRuntime::fwd_window_batch(self, windows, starts, caches)
    }
    fn fwd_window_accept(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
    ) -> Result<AcceptOut> {
        crate::runtime::ModelRuntime::fwd_window_accept(
            self, windows, starts, caches, rules,
        )
    }
    fn runtime_stats(&self) -> Option<RuntimeStats> {
        Some(self.stats())
    }
}

/// Outcome of decoding one sequence.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// Full final sequence (prompt region + committed gen region).
    pub tokens: Vec<u32>,
    /// Total denoising steps (policy decisions) across blocks.
    pub steps: usize,
    /// Forward passes, split by kind (full == fwd_conf or fwd_full_kv).
    pub full_passes: usize,
    pub window_passes: usize,
    /// Steps where the policy's raw rule selected nothing and the argmax
    /// fallback committed the single most confident position.
    pub fallback_steps: usize,
    /// Schedule steps the elision planner jumped over (DESIGN.md §14);
    /// 0 unless step elision is enabled.
    pub steps_elided: usize,
    /// Elided runs whose jumped-to step accepted nothing by rule.
    pub elision_mispredictions: usize,
    /// Blocks that completed with elided steps (retired early).
    pub blocks_retired_early: usize,
    /// Per-(block, executed-step) masked-position confidences —
    /// calibration input and Figure 1/2 raw material. Always recorded
    /// (cheap: few KB). Elided steps never appear here, so drift
    /// signatures compare executed steps only.
    pub trace: CalibrationTrace,
}

impl DecodeResult {
    /// The gen-region tokens.
    pub fn gen_tokens(&self, cfg: &ModelConfig) -> &[u32] {
        &self.tokens[cfg.gen_range()]
    }
}

/// The decode engine: one forward model + execution options. A thin facade
/// over [`StepScheduler`] for the run-to-completion cases.
pub struct Engine<'m, M: ForwardModel> {
    model: &'m M,
    /// Fast-dLLM dual KV cache behaviour.
    pub cache: crate::cache::CacheConfig,
    /// Prompt-prefix index + paged pool, when `cache.sharing_active()`.
    /// Held at engine level so every scheduler minted from this engine
    /// (including rebuilds after a step error) shares one index.
    shared: Option<crate::cache::SharedKv>,
}

impl<'m, M: ForwardModel> Engine<'m, M> {
    pub fn new(model: &'m M) -> Self {
        Engine::with_cache(model, crate::cache::CacheConfig::disabled())
    }

    pub fn with_kv_cache(model: &'m M) -> Self {
        Engine::with_cache(model, crate::cache::CacheConfig::block_boundary())
    }

    pub fn with_cache(model: &'m M, cache: crate::cache::CacheConfig) -> Self {
        let shared = cache.sharing_active().then(|| {
            let c = model.config();
            crate::cache::SharedKv::new(
                [c.n_layers, c.n_heads, c.seq_len, c.head_dim],
                c.prompt_len,
                cache.kv_page_len,
                crate::cache::DEFAULT_MAX_KV_PAGES,
            )
        });
        Engine { model, cache, shared }
    }

    pub fn model(&self) -> &M {
        self.model
    }

    /// The engine's prompt-prefix index, when prefix sharing is active.
    pub fn shared_kv(&self) -> Option<&crate::cache::SharedKv> {
        self.shared.as_ref()
    }

    /// A fresh scheduler with this engine's model and cache configuration —
    /// the entry point for drivers that admit/retire sequences themselves
    /// (the coordinator's continuous-batching worker loop).
    pub fn scheduler<P: PolicyRef>(&self, max_active: usize) -> StepScheduler<'m, M, P> {
        let mut sched = StepScheduler::new(self.model, self.cache, max_active);
        sched.set_shared_kv(self.shared.clone());
        sched
    }

    /// Decode one sequence (batch 1 — the paper's serving setup).
    pub fn decode(&self, layout: Vec<u32>, policy: &dyn Policy) -> Result<DecodeResult> {
        let mut sched = self.scheduler::<&dyn Policy>(1);
        sched.admit(0, layout, policy)?;
        let mut results = sched.drain()?;
        if results.len() != 1 {
            bail!("scheduler retired {} sequences for one admission", results.len());
        }
        Ok(results.pop().expect("checked length").1)
    }

    /// Decode many sequences through the step scheduler. Up to the model's
    /// max batch run concurrently (sharing forward passes); the rest queue
    /// FIFO and join as slots free up, so any number of sequences is
    /// accepted. Sequences finish independently; results come back in input
    /// order. Works with the KV cache on or off.
    pub fn decode_batch(
        &self,
        layouts: Vec<Vec<u32>>,
        policies: &[&dyn Policy],
    ) -> Result<Vec<DecodeResult>> {
        if layouts.len() != policies.len() {
            bail!("{} layouts vs {} policies", layouts.len(), policies.len());
        }
        let n = layouts.len();
        // ask for n slots: the scheduler clamps to the widest compiled
        // bucket, so co-execution widens past max_batch when bucketed
        // window variants exist
        let mut sched = self.scheduler::<&dyn Policy>(n.max(1));
        for (i, (layout, &policy)) in layouts.into_iter().zip(policies).enumerate() {
            sched.admit(i as u64, layout, policy)?;
        }
        let mut out: Vec<Option<DecodeResult>> = (0..n).map(|_| None).collect();
        for (id, res) in sched.drain()? {
            out[id as usize] = Some(res);
        }
        out.into_iter()
            .enumerate()
            .map(|(i, r)| r.with_context(|| format!("sequence {i} never retired")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{SequentialTopK, StaticThreshold};
    use crate::sim::SimModel;

    fn sim() -> SimModel {
        SimModel::math_like(7)
    }

    fn masked_layout(m: &SimModel) -> Vec<u32> {
        m.layout_from_seed(1)
    }

    #[test]
    fn sequential_top1_takes_gen_len_steps() {
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &SequentialTopK::new(1))
            .unwrap();
        let cfg = m.config();
        assert_eq!(res.steps, cfg.gen_len, "one token per step");
        assert_eq!(res.full_passes, cfg.gen_len);
        // nothing masked remains
        assert!(res.tokens[cfg.gen_range()]
            .iter()
            .all(|&t| t != cfg.mask_id));
    }

    #[test]
    fn static_threshold_fewer_steps_than_sequential() {
        let m = sim();
        let eng = Engine::new(&m);
        let seq = eng
            .decode(masked_layout(&m), &SequentialTopK::new(1))
            .unwrap();
        let par = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.9))
            .unwrap();
        assert!(par.steps < seq.steps, "{} !< {}", par.steps, seq.steps);
    }

    #[test]
    fn trace_covers_every_step() {
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.9))
            .unwrap();
        assert_eq!(res.trace.total_steps(), res.steps);
    }

    #[test]
    fn blocks_decode_left_to_right() {
        // after decoding, every token is set; trace must show blocks in
        // order with no interleaving (block b only starts once b-1 done)
        let m = sim();
        let eng = Engine::new(&m);
        let res = eng
            .decode(masked_layout(&m), &StaticThreshold::new(0.8))
            .unwrap();
        for b in 0..m.config().num_blocks {
            assert!(
                !res.trace.per_block[b].is_empty(),
                "block {b} has no steps"
            );
        }
    }

    #[test]
    fn batched_results_match_individual() {
        let m = sim();
        let eng = Engine::new(&m);
        let p = StaticThreshold::new(0.85);
        let l1 = m.layout_from_seed(10);
        let l2 = m.layout_from_seed(20);
        let solo1 = eng.decode(l1.clone(), &p).unwrap();
        let solo2 = eng.decode(l2.clone(), &p).unwrap();
        let both = eng
            .decode_batch(vec![l1, l2], &[&p, &p])
            .unwrap();
        assert_eq!(both[0].tokens, solo1.tokens);
        assert_eq!(both[1].tokens, solo2.tokens);
        assert_eq!(both[0].steps, solo1.steps);
        assert_eq!(both[1].steps, solo2.steps);
    }

    #[test]
    fn cached_and_uncached_agree_when_model_is_cache_exact() {
        // SimModel's window path reproduces its full path exactly, so the
        // cached decode must produce identical tokens & steps.
        let m = sim();
        let plain = Engine::new(&m);
        let cached = Engine::with_kv_cache(&m);
        let p = StaticThreshold::new(0.9);
        let a = plain.decode(masked_layout(&m), &p).unwrap();
        let b = cached.decode(masked_layout(&m), &p).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.steps, b.steps);
        // cache path must be cheaper in full passes
        assert_eq!(b.full_passes, m.config().num_blocks);
        assert_eq!(b.window_passes, b.steps - b.full_passes);
    }

    #[test]
    fn cached_batched_decode_matches_solo_cached() {
        // batching never changes per-sequence results, cache on or off
        let m = sim();
        let eng = Engine::with_kv_cache(&m);
        let p = StaticThreshold::new(0.88);
        let layouts: Vec<Vec<u32>> = (0..3).map(|i| m.layout_from_seed(30 + i)).collect();
        let solos: Vec<DecodeResult> = layouts
            .iter()
            .map(|l| eng.decode(l.clone(), &p).unwrap())
            .collect();
        let policies: Vec<&dyn Policy> = vec![&p, &p, &p];
        let batched = eng.decode_batch(layouts, &policies).unwrap();
        for (b, s) in batched.iter().zip(&solos) {
            assert_eq!(b.tokens, s.tokens);
            assert_eq!(b.steps, s.steps);
            assert_eq!(b.full_passes, s.full_passes);
            assert_eq!(b.window_passes, s.window_passes);
        }
    }

    #[test]
    fn rejects_wrong_layout_len() {
        let m = sim();
        let eng = Engine::new(&m);
        assert!(eng.decode(vec![0; 3], &SequentialTopK::new(1)).is_err());
    }

    #[test]
    fn oversized_batch_queues_and_completes() {
        // more sequences than the model's max batch: the scheduler queues
        // the overflow and every sequence still matches its solo decode
        let m = sim();
        let eng = Engine::new(&m);
        let p = StaticThreshold::new(0.85);
        let n = m.max_batch() + 3;
        let layouts: Vec<Vec<u32>> =
            (0..n).map(|i| m.layout_from_seed(i as u64)).collect();
        let solos: Vec<DecodeResult> = layouts
            .iter()
            .map(|l| eng.decode(l.clone(), &p).unwrap())
            .collect();
        let policies: Vec<&dyn Policy> =
            layouts.iter().map(|_| &p as &dyn Policy).collect();
        let batched = eng.decode_batch(layouts, &policies).unwrap();
        assert_eq!(batched.len(), n);
        for (b, s) in batched.iter().zip(&solos) {
            assert_eq!(b.tokens, s.tokens);
            assert_eq!(b.steps, s.steps);
        }
    }
}
