//! TCP JSON-line serving front-end + client library.
//!
//! Wire protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"task":"synth-math","prompt":"Q: 3+4=?","policy":"osdt:block:q1:0.75:0.2"}
//! <- {"id":1,"completion":"A: 3+4=7. #### 7","steps":9,"latency_ms":52.1,
//!     "tokens_per_sec":1843.2,"full_passes":9,"window_passes":0,
//!     "calibrated":false}
//! -> {"task":"synth-math","prompt":"Q: 3+4=?","policy":"static:0.9",
//!     "slo_ms":250}                      (optional per-request deadline)
//! <- {"id":2,...,"error":"shed: ...","retry_after_ms":83.0}   (if shed)
//! -> {"cmd":"metrics"}
//! <- {"metrics":"osdt_requests_completed_total 12\n..."}
//! -> {"cmd":"ping"}
//! <- {"pong":true}
//! ```
//!
//! The `profiles` admin command exposes the fleet-wide profile registry
//! (DESIGN.md §9):
//!
//! ```text
//! -> {"cmd":"profiles"}                                    (list)
//! <- {"profiles":[{"task":"synth-math","mode":"block","metric":"q1",
//!     "version":1,"stale":false,"observed":4,...}]}
//! -> {"cmd":"profiles","action":"inspect","task":"synth-math",
//!     "mode":"block","metric":"q1"}
//! <- {"profile":{...taus + signature + version...}}
//! -> {"cmd":"profiles","action":"invalidate","task":"synth-math",
//!     "mode":"block","metric":"q1"}
//! <- {"invalidated":true}                (next request recalibrates)
//! ```
//!
//! Built on std::net + threads (the offline registry has no tokio); one
//! thread per connection, responses written in completion order per
//! connection.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, Request, Response};
use crate::policy::{DynamicMode, Metric, ProfileKey};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Serialize a coordinator response to its wire form.
pub fn response_to_json(r: &Response) -> Json {
    let mut pairs = vec![
        ("id", Json::Num(r.id as f64)),
        ("completion", Json::Str(r.completion.clone())),
        ("steps", Json::Num(r.steps as f64)),
        ("full_passes", Json::Num(r.full_passes as f64)),
        ("window_passes", Json::Num(r.window_passes as f64)),
        ("latency_ms", Json::Num(r.latency_ms)),
        ("ttft_ms", Json::Num(r.ttft_ms)),
        ("tokens_per_sec", Json::Num(r.tokens_per_sec)),
        ("calibrated", Json::Bool(r.calibrated)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::Str(e.clone())));
    }
    if let Some(retry) = r.retry_after_ms {
        pairs.push(("retry_after_ms", Json::Num(retry)));
    }
    Json::obj(pairs)
}

/// Parse a wire response back into a [`Response`] (client side).
pub fn response_from_json(j: &Json) -> Result<Response> {
    let num = |k: &str| -> Result<f64> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_f64()
            .with_context(|| format!("{k} not a number"))
    };
    Ok(Response {
        id: num("id")? as u64,
        completion: j
            .req("completion")
            .map_err(anyhow::Error::msg)?
            .as_str()
            .context("completion not a string")?
            .to_string(),
        steps: num("steps")? as usize,
        full_passes: num("full_passes")? as usize,
        window_passes: num("window_passes")? as usize,
        latency_ms: num("latency_ms")?,
        // optional on the wire so newer clients parse older servers
        ttft_ms: j.get("ttft_ms").and_then(Json::as_f64).unwrap_or(0.0),
        tokens_per_sec: num("tokens_per_sec")?,
        calibrated: j
            .get("calibrated")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
        retry_after_ms: j.get("retry_after_ms").and_then(Json::as_f64),
    })
}

/// A running server; dropping/`stop()` halts the accept loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve requests on
    /// `coordinator` until stopped, with the default connection timeout.
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Server> {
        let default_ms = crate::config::ServerConfig::default().conn_timeout_ms;
        Self::start_with_timeout(
            addr,
            coordinator,
            Duration::from_millis(default_ms),
        )
    }

    /// [`Server::start`] with an explicit per-connection socket timeout:
    /// every accepted stream gets read/write timeouts, so a stalled or
    /// half-dead peer is disconnected (and counted in
    /// `connection_timeouts`) instead of pinning its `osdt-conn` thread
    /// forever. `Duration::ZERO` disables the timeout.
    pub fn start_with_timeout(
        addr: &str,
        coordinator: Arc<Coordinator>,
        conn_timeout: Duration,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("osdt-accept".into())
            .spawn(move || {
                log::info!("server listening on {local}");
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::debug!("connection from {peer}");
                            if !conn_timeout.is_zero() {
                                stream.set_read_timeout(Some(conn_timeout)).ok();
                                stream
                                    .set_write_timeout(Some(conn_timeout))
                                    .ok();
                            }
                            let coord = coordinator.clone();
                            let _ = std::thread::Builder::new()
                                .name("osdt-conn".into())
                                .spawn(move || {
                                    if let Err(e) = handle_conn(stream, &coord) {
                                        log::debug!("connection ended: {e:#}");
                                    }
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

/// Socket-timeout error kinds (Linux reports `WouldBlock`, other
/// platforms `TimedOut`, for a blocking socket with SO_RCVTIMEO).
fn is_timeout(kind: std::io::ErrorKind) -> bool {
    matches!(
        kind,
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            // The per-connection socket timeout fired: the peer stalled.
            // Close (don't kill the server) and count it.
            Err(e) if is_timeout(e.kind()) => {
                coord.metrics.add("connection_timeouts", 1);
                log::debug!("connection idle past timeout; closing");
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
            Ok(j) => {
                if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "ping" => Json::obj(vec![("pong", Json::Bool(true))]),
                        "metrics" => Json::obj(vec![(
                            "metrics",
                            // coordinator metrics + fleet-wide registry
                            // metrics in one exposition (names disjoint)
                            Json::Str(format!(
                                "{}{}",
                                coord.metrics.render(),
                                coord.registry.metrics().render()
                            )),
                        )]),
                        "profiles" => handle_profiles(&j, coord),
                        other => Json::obj(vec![(
                            "error",
                            Json::Str(format!("unknown cmd {other:?}")),
                        )]),
                    }
                } else {
                    match request_from_json(&j) {
                        Err(e) => {
                            Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
                        }
                        Ok(req) => {
                            let rx = coord.submit(req);
                            match rx.recv() {
                                Ok(resp) => response_to_json(&resp),
                                Err(_) => Json::obj(vec![(
                                    "error",
                                    Json::Str("coordinator shut down".into()),
                                )]),
                            }
                        }
                    }
                }
            }
        };
        if let Err(e) = writeln!(writer, "{reply}").and_then(|_| writer.flush())
        {
            if is_timeout(e.kind()) {
                coord.metrics.add("connection_timeouts", 1);
                log::debug!("write stalled past timeout; closing");
                return Ok(());
            }
            return Err(e.into());
        }
    }
    Ok(())
}

/// Parse the (task, mode, metric) key fields of a `profiles` sub-command.
fn profile_key_from_json(j: &Json) -> Result<ProfileKey> {
    fn field<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_str()
            .with_context(|| format!("{k} not a string"))
    }
    Ok(ProfileKey::new(
        field(j, "task")?,
        DynamicMode::parse(field(j, "mode")?)?,
        Metric::parse(field(j, "metric")?)?,
    ))
}

/// The `profiles` admin command: list (default), inspect, invalidate.
fn handle_profiles(j: &Json, coord: &Coordinator) -> Json {
    let err = |e: &dyn std::fmt::Display| {
        Json::obj(vec![("error", Json::Str(e.to_string()))])
    };
    match j.get("action").and_then(Json::as_str).unwrap_or("list") {
        "list" => {
            let rows = coord
                .registry
                .snapshot()
                .into_iter()
                .map(|s| {
                    Json::obj(vec![
                        ("task", Json::Str(s.key.task)),
                        ("mode", Json::Str(s.key.mode.as_str().into())),
                        ("metric", Json::Str(s.key.metric.as_str().into())),
                        ("version", Json::Num(s.version as f64)),
                        ("stale", Json::Bool(s.stale)),
                        ("calibrating", Json::Bool(s.leased)),
                        ("observed", Json::Num(s.observed as f64)),
                        ("warm_started", Json::Bool(s.warm_started)),
                        ("blocks", Json::Num(s.num_blocks as f64)),
                    ])
                })
                .collect();
            Json::obj(vec![("profiles", Json::Arr(rows))])
        }
        "inspect" => match profile_key_from_json(j) {
            Err(e) => err(&format!("{e:#}")),
            Ok(key) => match coord.registry.get(&key) {
                None => err(&format!("no profile for {key}")),
                Some(entry) => {
                    let mut doc = entry.profile.to_json();
                    if let Json::Obj(m) = &mut doc {
                        m.insert("task".into(), Json::Str(key.task.clone()));
                        m.insert("version".into(), Json::Num(entry.version as f64));
                        m.insert("stale".into(), Json::Bool(entry.stale));
                        m.insert("observed".into(), Json::Num(entry.observed as f64));
                        m.insert("signature".into(), Json::from_f64s(&entry.signature));
                    }
                    Json::obj(vec![("profile", doc)])
                }
            },
        },
        "invalidate" => match profile_key_from_json(j) {
            Err(e) => err(&format!("{e:#}")),
            Ok(key) => Json::obj(vec![(
                "invalidated",
                Json::Bool(coord.registry.invalidate(&key)),
            )]),
        },
        other => err(&format!("unknown profiles action {other:?}")),
    }
}

fn request_from_json(j: &Json) -> Result<Request> {
    let s = |k: &str| -> Result<String> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_str()
            .map(str::to_string)
            .with_context(|| format!("{k} not a string"))
    };
    Ok(Request {
        id: j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        task: s("task")?,
        prompt: s("prompt")?,
        policy: s("policy")?,
        // optional per-request deadline; absent inherits the server default
        slo_ms: j.get("slo_ms").and_then(Json::as_f64),
    })
}

/// Client-side retry policy for idempotent requests: jittered
/// exponential backoff with a bounded retry budget, honoring the
/// server's §15 `retry_after_ms` shed hint when one is present.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (total attempts = this + 1).
    pub max_retries: usize,
    /// First-retry backoff; doubles per retry up to `backoff_max`, then
    /// jittered into [d/2, d).
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// Jitter PRNG seed (deterministic for tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            seed: 1,
        }
    }
}

impl RetryPolicy {
    /// Pure backoff schedule: the sleep before retry `attempt`
    /// (0-based). A finite server `retry_after_ms` hint acts as a floor
    /// — the server knows its backlog better than our schedule does.
    pub fn backoff_for(
        &self,
        attempt: usize,
        retry_after_ms: Option<f64>,
        rng: &mut Rng,
    ) -> Duration {
        let full = self
            .backoff_base
            .saturating_mul(1u32 << attempt.min(16) as u32)
            .min(self.backoff_max);
        let jittered = full / 2
            + Duration::from_secs_f64(
                full.as_secs_f64() / 2.0 * rng.next_f64(),
            );
        match retry_after_ms {
            Some(ms) if ms.is_finite() && ms > 0.0 => {
                jittered.max(Duration::from_secs_f64(ms / 1e3))
            }
            _ => jittered,
        }
    }
}

/// Blocking line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Peer address, kept so retries can reconnect after a transport
    /// failure (None only if the OS cannot report it).
    peer: Option<SocketAddr>,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        let peer = stream.peer_addr().ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            peer,
        })
    }

    fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        writeln!(self.writer, "{msg}")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(&Json::obj(vec![("cmd", Json::Str("ping".into()))]))?;
        Ok(j.get("pong").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn metrics(&mut self) -> Result<String> {
        let j =
            self.roundtrip(&Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
        Ok(j.get("metrics")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string())
    }

    /// List registered profiles (the `profiles` admin command).
    pub fn profiles(&mut self) -> Result<Json> {
        let j =
            self.roundtrip(&Json::obj(vec![("cmd", Json::Str("profiles".into()))]))?;
        j.get("profiles")
            .cloned()
            .context("no profiles field in reply")
    }

    /// Inspect one profile (full thresholds + signature).
    pub fn inspect_profile(
        &mut self,
        task: &str,
        mode: &str,
        metric: &str,
    ) -> Result<Json> {
        let j = self.roundtrip(&Json::obj(vec![
            ("cmd", Json::Str("profiles".into())),
            ("action", Json::Str("inspect".into())),
            ("task", Json::Str(task.into())),
            ("mode", Json::Str(mode.into())),
            ("metric", Json::Str(metric.into())),
        ]))?;
        if let Some(e) = j.get("error").and_then(Json::as_str) {
            bail!("server error: {e}");
        }
        j.get("profile").cloned().context("no profile field in reply")
    }

    /// Mark a profile stale so the next request recalibrates; returns
    /// whether the profile existed.
    pub fn invalidate_profile(
        &mut self,
        task: &str,
        mode: &str,
        metric: &str,
    ) -> Result<bool> {
        let j = self.roundtrip(&Json::obj(vec![
            ("cmd", Json::Str("profiles".into())),
            ("action", Json::Str("invalidate".into())),
            ("task", Json::Str(task.into())),
            ("mode", Json::Str(mode.into())),
            ("metric", Json::Str(metric.into())),
        ]))?;
        if let Some(e) = j.get("error").and_then(Json::as_str) {
            bail!("server error: {e}");
        }
        j.get("invalidated")
            .and_then(Json::as_bool)
            .context("no invalidated field in reply")
    }

    pub fn generate(&mut self, task: &str, prompt: &str, policy: &str) -> Result<Response> {
        self.generate_with_slo(task, prompt, policy, None)
    }

    /// [`Client::generate`] with a per-request deadline budget attached. A
    /// server over its shed watermark (or unable to meet the budget)
    /// rejects with `error` + a finite `retry_after_ms` instead of queueing.
    pub fn generate_with_slo(
        &mut self,
        task: &str,
        prompt: &str,
        policy: &str,
        slo_ms: Option<f64>,
    ) -> Result<Response> {
        let mut pairs = vec![
            ("task", Json::Str(task.into())),
            ("prompt", Json::Str(prompt.into())),
            ("policy", Json::Str(policy.into())),
        ];
        if let Some(slo) = slo_ms {
            pairs.push(("slo_ms", Json::Num(slo)));
        }
        let msg = Json::obj(pairs);
        let j = self.roundtrip(&msg)?;
        if j.get("id").is_none() {
            if let Some(e) = j.get("error").and_then(Json::as_str) {
                bail!("server error: {e}");
            }
        }
        response_from_json(&j)
    }

    /// [`Client::generate`] with a bounded retry budget. Decode requests
    /// are idempotent (same prompt + policy → same tokens), so two
    /// failure classes are retried after a jittered backoff:
    ///
    /// - transport failures (connection dropped, server died) —
    ///   reconnects to the same peer before the next attempt;
    /// - §15 shed responses — sleeps at least the server's
    ///   `retry_after_ms` hint, then retries on the live connection.
    ///
    /// When the budget is exhausted the last error (or shed response) is
    /// returned as-is.
    pub fn generate_with_retry(
        &mut self,
        task: &str,
        prompt: &str,
        policy: &str,
        retry: &RetryPolicy,
    ) -> Result<Response> {
        let mut rng = Rng::new(retry.seed ^ 0x9e37_79b9);
        for attempt in 0.. {
            match self.generate(task, prompt, policy) {
                Ok(r) => {
                    let shed = r
                        .error
                        .as_deref()
                        .map(|e| e.starts_with("shed"))
                        .unwrap_or(false);
                    if !shed || attempt >= retry.max_retries {
                        return Ok(r);
                    }
                    std::thread::sleep(retry.backoff_for(
                        attempt,
                        r.retry_after_ms,
                        &mut rng,
                    ));
                }
                Err(e) => {
                    if attempt >= retry.max_retries {
                        return Err(e);
                    }
                    std::thread::sleep(retry.backoff_for(
                        attempt,
                        None,
                        &mut rng,
                    ));
                    if let Some(peer) = self.peer {
                        if let Ok(fresh) = Client::connect(peer) {
                            *self = fresh;
                        }
                    }
                }
            }
        }
        unreachable!("retry loop returns from within");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::model::fixtures::tiny_config;
    use crate::sim::SimModel;

    fn start_stack() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig::default(), tiny_config(), |_| {
                Ok(SimModel::math_like(3))
            })
            .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    #[test]
    fn ping_and_metrics() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        assert!(c.ping().unwrap());
        // counters appear once a request has flowed through
        c.generate("synth-math", "Q: 1+1=?", "static:0.9").unwrap();
        let m = c.metrics().unwrap();
        assert!(m.contains("osdt_requests_submitted_total"), "{m}");
        assert!(m.contains("osdt_requests_completed_total 1"), "{m}");
        // scheduler metrics ride the same exposition
        assert!(m.contains("osdt_queue_depth"), "{m}");
        assert!(m.contains("osdt_batch_occupancy"), "{m}");
        assert!(m.contains("osdt_admission_wait_count"), "{m}");
        assert!(m.contains("osdt_scheduler_steps_total"), "{m}");
        server.stop();
    }

    #[test]
    fn generate_over_wire() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        let r = c
            .generate("synth-math", "Q: 1+2=?", "static:0.9")
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.steps > 0);
        assert!(!r.completion.is_empty());
        server.stop();
    }

    #[test]
    fn malformed_json_gets_error_line() {
        let (server, _coord) = start_stack();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "this is not json").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        server.stop();
    }

    #[test]
    fn missing_fields_rejected() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        let j = c
            .roundtrip(&Json::obj(vec![("task", Json::Str("synth-math".into()))]))
            .unwrap();
        assert!(j.get("error").is_some());
        server.stop();
    }

    #[test]
    fn multiple_clients() {
        let (server, coord) = start_stack();
        let addr = server.addr;
        let mut handles = vec![];
        for i in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .generate("synth-math", &format!("Q: {i}+1=?"), "static:0.8")
                    .unwrap();
                assert!(r.error.is_none());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(coord.metrics.counter_value("requests_completed"), 4);
        server.stop();
    }

    #[test]
    fn profiles_admin_list_inspect_invalidate() {
        let (server, coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        // empty registry -> empty list
        assert_eq!(c.profiles().unwrap().as_arr().unwrap().len(), 0);
        // calibrate one task, then the registry surfaces it
        let r = c
            .generate("synth-math", "Q: 1+2=?", "osdt:block:q1:0.75:0.2")
            .unwrap();
        assert!(r.calibrated);
        let list = c.profiles().unwrap();
        let rows = list.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("task").and_then(Json::as_str), Some("synth-math"));
        assert_eq!(rows[0].get("stale").and_then(Json::as_bool), Some(false));
        // inspect returns the full thresholds + signature
        let prof = c.inspect_profile("synth-math", "block", "q1").unwrap();
        assert!(prof.get("taus").is_some());
        assert!(!prof
            .get("signature")
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty());
        // invalidate -> stale -> next request recalibrates
        assert!(c.invalidate_profile("synth-math", "block", "q1").unwrap());
        let r2 = c
            .generate("synth-math", "Q: 3+4=?", "osdt:block:q1:0.75:0.2")
            .unwrap();
        assert!(r2.calibrated, "invalidated profile must recalibrate");
        // unknown key: inspect errors, invalidate reports absence
        assert!(c.inspect_profile("nope", "block", "q1").is_err());
        assert!(!c.invalidate_profile("nope", "block", "q1").unwrap());
        // registry metrics ride the metrics exposition
        let m = c.metrics().unwrap();
        assert!(m.contains("osdt_calibrations_completed_total 2"), "{m}");
        assert!(m.contains("osdt_recalibrations_total 1"), "{m}");
        assert_eq!(coord.registry.metrics().counter_value("recalibrations"), 1);
        server.stop();
    }

    #[test]
    fn response_json_roundtrip() {
        let r = Response {
            id: 7,
            completion: "A: #### 5".into(),
            steps: 12,
            full_passes: 3,
            window_passes: 9,
            latency_ms: 41.5,
            tokens_per_sec: 2314.0,
            calibrated: true,
            ttft_ms: 8.25,
            error: None,
            retry_after_ms: None,
        };
        let back = response_from_json(&response_to_json(&r)).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.completion, r.completion);
        assert_eq!(back.steps, 12);
        assert!(back.calibrated);
        assert_eq!(back.ttft_ms, 8.25);
        assert!(back.error.is_none());
        assert!(back.retry_after_ms.is_none(), "absent on the wire stays None");
        // older servers omit ttft_ms: the client defaults it to 0
        let mut j = response_to_json(&r);
        if let Json::Obj(m) = &mut j {
            m.remove("ttft_ms");
        }
        assert_eq!(response_from_json(&j).unwrap().ttft_ms, 0.0);
        // a shed response carries its retry hint through the roundtrip
        let shed = Response::shed(9, 83.5, "shed: predicted backlog over watermark".into());
        let back = response_from_json(&response_to_json(&shed)).unwrap();
        assert_eq!(back.retry_after_ms, Some(83.5));
        assert!(back.error.unwrap().contains("shed"));
    }

    #[test]
    fn idle_connection_times_out_and_is_counted() {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig::default(), tiny_config(), |_| {
                Ok(SimModel::math_like(3))
            })
            .unwrap(),
        );
        let server = Server::start_with_timeout(
            "127.0.0.1:0",
            coord.clone(),
            Duration::from_millis(100),
        )
        .unwrap();
        // A request/response cycle well under the timeout is unaffected
        // (client closed cleanly afterwards: no timeout counted for it).
        {
            let mut c = Client::connect(server.addr).unwrap();
            assert!(c.ping().unwrap());
        }
        // An idle raw connection is closed once the socket timeout fires:
        // our blocking read observes EOF instead of hanging.
        let idle = TcpStream::connect(server.addr).unwrap();
        idle.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut r = BufReader::new(idle);
        let mut line = String::new();
        let n = r.read_line(&mut line).unwrap();
        assert_eq!(n, 0, "server should close the idle connection");
        assert_eq!(coord.metrics.counter_value("connection_timeouts"), 1);
        // The server keeps serving fresh connections afterwards.
        let mut c = Client::connect(server.addr).unwrap();
        assert!(c.ping().unwrap());
        server.stop();
    }

    #[test]
    fn retry_backoff_schedule_doubles_caps_and_honors_hints() {
        let rp = RetryPolicy {
            backoff_base: Duration::from_millis(10),
            backoff_max: Duration::from_millis(40),
            ..RetryPolicy::default()
        };
        let mut rng = Rng::new(11);
        for (attempt, full_ms) in
            [(0usize, 10.0f64), (1, 20.0), (2, 40.0), (7, 40.0)]
        {
            let d =
                rp.backoff_for(attempt, None, &mut rng).as_secs_f64() * 1e3;
            assert!(
                d >= full_ms / 2.0 - 1e-9 && d < full_ms + 1e-9,
                "attempt {attempt}: {d}ms outside [{}, {})",
                full_ms / 2.0,
                full_ms
            );
        }
        // A finite server hint floors the schedule...
        let d = rp.backoff_for(0, Some(500.0), &mut rng);
        assert!(d >= Duration::from_millis(500), "{d:?}");
        // ...but infinite/zero hints are ignored.
        let d = rp.backoff_for(0, Some(f64::INFINITY), &mut rng);
        assert!(d < Duration::from_millis(10), "{d:?}");
        let d = rp.backoff_for(0, Some(0.0), &mut rng);
        assert!(d < Duration::from_millis(10), "{d:?}");
    }

    #[test]
    fn retry_budget_is_bounded_and_reconnects() {
        use std::sync::atomic::AtomicUsize;
        // A server that accepts and immediately hangs up: every attempt
        // is a transport failure, so the client must reconnect per retry
        // and give up after exactly max_retries + 1 attempts.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accepted = Arc::new(AtomicUsize::new(0));
        let accepted2 = accepted.clone();
        let h = std::thread::spawn(move || {
            // First accept feeds Client::connect; the next two feed the
            // reconnects after failed attempts 0 and 1 (the final
            // attempt exhausts the budget without reconnecting).
            for _ in 0..3 {
                if let Ok((s, _)) = listener.accept() {
                    accepted2.fetch_add(1, Ordering::SeqCst);
                    drop(s);
                }
            }
        });
        let mut c = Client::connect(addr).unwrap();
        let rp = RetryPolicy {
            max_retries: 2,
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(2),
            ..RetryPolicy::default()
        };
        let err = c
            .generate_with_retry("synth-math", "Q: 1+1=?", "static:0.9", &rp)
            .unwrap_err();
        assert!(!err.to_string().is_empty());
        h.join().unwrap();
        // Exactly 1 connect + max_retries reconnects: the budget bounds
        // both the attempt count and the reconnect storm.
        assert_eq!(accepted.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn retry_returns_success_immediately() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        let r = c
            .generate_with_retry(
                "synth-math",
                "Q: 2+2=?",
                "static:0.9",
                &RetryPolicy::default(),
            )
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.completion.is_empty());
        server.stop();
    }

    #[test]
    fn slo_field_parses_over_wire() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        // a generous per-request budget flows through the optional field
        // and the request completes normally (shedding is off by default)
        let r = c
            .generate_with_slo("synth-math", "Q: 2+3=?", "static:0.9", Some(60_000.0))
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.retry_after_ms.is_none());
        assert!(r.steps > 0);
        server.stop();
    }
}
