//! TCP JSON-line serving front-end + client library.
//!
//! Wire protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"task":"synth-math","prompt":"Q: 3+4=?","policy":"osdt:block:q1:0.75:0.2"}
//! <- {"id":1,"completion":"A: 3+4=7. #### 7","steps":9,"latency_ms":52.1,
//!     "tokens_per_sec":1843.2,"full_passes":9,"window_passes":0,
//!     "calibrated":false}
//! -> {"task":"synth-math","prompt":"Q: 3+4=?","policy":"static:0.9",
//!     "slo_ms":250}                      (optional per-request deadline)
//! <- {"id":2,...,"error":"shed: ...","retry_after_ms":83.0}   (if shed)
//! -> {"cmd":"metrics"}
//! <- {"metrics":"osdt_requests_completed_total 12\n..."}
//! -> {"cmd":"ping"}
//! <- {"pong":true}
//! ```
//!
//! The `profiles` admin command exposes the fleet-wide profile registry
//! (DESIGN.md §9):
//!
//! ```text
//! -> {"cmd":"profiles"}                                    (list)
//! <- {"profiles":[{"task":"synth-math","mode":"block","metric":"q1",
//!     "version":1,"stale":false,"observed":4,...}]}
//! -> {"cmd":"profiles","action":"inspect","task":"synth-math",
//!     "mode":"block","metric":"q1"}
//! <- {"profile":{...taus + signature + version...}}
//! -> {"cmd":"profiles","action":"invalidate","task":"synth-math",
//!     "mode":"block","metric":"q1"}
//! <- {"invalidated":true}                (next request recalibrates)
//! ```
//!
//! Built on std::net + threads (the offline registry has no tokio); one
//! thread per connection, responses written in completion order per
//! connection.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, Request, Response};
use crate::policy::{DynamicMode, Metric, ProfileKey};
use crate::util::json::Json;

/// Serialize a coordinator response to its wire form.
pub fn response_to_json(r: &Response) -> Json {
    let mut pairs = vec![
        ("id", Json::Num(r.id as f64)),
        ("completion", Json::Str(r.completion.clone())),
        ("steps", Json::Num(r.steps as f64)),
        ("full_passes", Json::Num(r.full_passes as f64)),
        ("window_passes", Json::Num(r.window_passes as f64)),
        ("latency_ms", Json::Num(r.latency_ms)),
        ("ttft_ms", Json::Num(r.ttft_ms)),
        ("tokens_per_sec", Json::Num(r.tokens_per_sec)),
        ("calibrated", Json::Bool(r.calibrated)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::Str(e.clone())));
    }
    if let Some(retry) = r.retry_after_ms {
        pairs.push(("retry_after_ms", Json::Num(retry)));
    }
    Json::obj(pairs)
}

/// Parse a wire response back into a [`Response`] (client side).
pub fn response_from_json(j: &Json) -> Result<Response> {
    let num = |k: &str| -> Result<f64> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_f64()
            .with_context(|| format!("{k} not a number"))
    };
    Ok(Response {
        id: num("id")? as u64,
        completion: j
            .req("completion")
            .map_err(anyhow::Error::msg)?
            .as_str()
            .context("completion not a string")?
            .to_string(),
        steps: num("steps")? as usize,
        full_passes: num("full_passes")? as usize,
        window_passes: num("window_passes")? as usize,
        latency_ms: num("latency_ms")?,
        // optional on the wire so newer clients parse older servers
        ttft_ms: j.get("ttft_ms").and_then(Json::as_f64).unwrap_or(0.0),
        tokens_per_sec: num("tokens_per_sec")?,
        calibrated: j
            .get("calibrated")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        error: j.get("error").and_then(Json::as_str).map(str::to_string),
        retry_after_ms: j.get("retry_after_ms").and_then(Json::as_f64),
    })
}

/// A running server; dropping/`stop()` halts the accept loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve requests on
    /// `coordinator` until stopped.
    pub fn start(addr: &str, coordinator: Arc<Coordinator>) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("osdt-accept".into())
            .spawn(move || {
                log::info!("server listening on {local}");
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, peer)) => {
                            log::debug!("connection from {peer}");
                            let coord = coordinator.clone();
                            let _ = std::thread::Builder::new()
                                .name("osdt-conn".into())
                                .spawn(move || {
                                    if let Err(e) = handle_conn(stream, &coord) {
                                        log::debug!("connection ended: {e:#}");
                                    }
                                });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(e) => {
                            log::warn!("accept error: {e}");
                            break;
                        }
                    }
                }
            })?;
        Ok(Server { addr: local, stop, accept_handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: &Coordinator) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))]),
            Ok(j) => {
                if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
                    match cmd {
                        "ping" => Json::obj(vec![("pong", Json::Bool(true))]),
                        "metrics" => Json::obj(vec![(
                            "metrics",
                            // coordinator metrics + fleet-wide registry
                            // metrics in one exposition (names disjoint)
                            Json::Str(format!(
                                "{}{}",
                                coord.metrics.render(),
                                coord.registry.metrics().render()
                            )),
                        )]),
                        "profiles" => handle_profiles(&j, coord),
                        other => Json::obj(vec![(
                            "error",
                            Json::Str(format!("unknown cmd {other:?}")),
                        )]),
                    }
                } else {
                    match request_from_json(&j) {
                        Err(e) => {
                            Json::obj(vec![("error", Json::Str(format!("{e:#}")))])
                        }
                        Ok(req) => {
                            let rx = coord.submit(req);
                            match rx.recv() {
                                Ok(resp) => response_to_json(&resp),
                                Err(_) => Json::obj(vec![(
                                    "error",
                                    Json::Str("coordinator shut down".into()),
                                )]),
                            }
                        }
                    }
                }
            }
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
    }
    Ok(())
}

/// Parse the (task, mode, metric) key fields of a `profiles` sub-command.
fn profile_key_from_json(j: &Json) -> Result<ProfileKey> {
    fn field<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_str()
            .with_context(|| format!("{k} not a string"))
    }
    Ok(ProfileKey::new(
        field(j, "task")?,
        DynamicMode::parse(field(j, "mode")?)?,
        Metric::parse(field(j, "metric")?)?,
    ))
}

/// The `profiles` admin command: list (default), inspect, invalidate.
fn handle_profiles(j: &Json, coord: &Coordinator) -> Json {
    let err = |e: &dyn std::fmt::Display| {
        Json::obj(vec![("error", Json::Str(e.to_string()))])
    };
    match j.get("action").and_then(Json::as_str).unwrap_or("list") {
        "list" => {
            let rows = coord
                .registry
                .snapshot()
                .into_iter()
                .map(|s| {
                    Json::obj(vec![
                        ("task", Json::Str(s.key.task)),
                        ("mode", Json::Str(s.key.mode.as_str().into())),
                        ("metric", Json::Str(s.key.metric.as_str().into())),
                        ("version", Json::Num(s.version as f64)),
                        ("stale", Json::Bool(s.stale)),
                        ("calibrating", Json::Bool(s.leased)),
                        ("observed", Json::Num(s.observed as f64)),
                        ("warm_started", Json::Bool(s.warm_started)),
                        ("blocks", Json::Num(s.num_blocks as f64)),
                    ])
                })
                .collect();
            Json::obj(vec![("profiles", Json::Arr(rows))])
        }
        "inspect" => match profile_key_from_json(j) {
            Err(e) => err(&format!("{e:#}")),
            Ok(key) => match coord.registry.get(&key) {
                None => err(&format!("no profile for {key}")),
                Some(entry) => {
                    let mut doc = entry.profile.to_json();
                    if let Json::Obj(m) = &mut doc {
                        m.insert("task".into(), Json::Str(key.task.clone()));
                        m.insert("version".into(), Json::Num(entry.version as f64));
                        m.insert("stale".into(), Json::Bool(entry.stale));
                        m.insert("observed".into(), Json::Num(entry.observed as f64));
                        m.insert("signature".into(), Json::from_f64s(&entry.signature));
                    }
                    Json::obj(vec![("profile", doc)])
                }
            },
        },
        "invalidate" => match profile_key_from_json(j) {
            Err(e) => err(&format!("{e:#}")),
            Ok(key) => Json::obj(vec![(
                "invalidated",
                Json::Bool(coord.registry.invalidate(&key)),
            )]),
        },
        other => err(&format!("unknown profiles action {other:?}")),
    }
}

fn request_from_json(j: &Json) -> Result<Request> {
    let s = |k: &str| -> Result<String> {
        j.req(k)
            .map_err(anyhow::Error::msg)?
            .as_str()
            .map(str::to_string)
            .with_context(|| format!("{k} not a string"))
    };
    Ok(Request {
        id: j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        task: s("task")?,
        prompt: s("prompt")?,
        policy: s("policy")?,
        // optional per-request deadline; absent inherits the server default
        slo_ms: j.get("slo_ms").and_then(Json::as_f64),
    })
}

/// Blocking line-protocol client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl std::net::ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn roundtrip(&mut self, msg: &Json) -> Result<Json> {
        writeln!(self.writer, "{msg}")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        Ok(Json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let j = self.roundtrip(&Json::obj(vec![("cmd", Json::Str("ping".into()))]))?;
        Ok(j.get("pong").and_then(Json::as_bool).unwrap_or(false))
    }

    pub fn metrics(&mut self) -> Result<String> {
        let j =
            self.roundtrip(&Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
        Ok(j.get("metrics")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string())
    }

    /// List registered profiles (the `profiles` admin command).
    pub fn profiles(&mut self) -> Result<Json> {
        let j =
            self.roundtrip(&Json::obj(vec![("cmd", Json::Str("profiles".into()))]))?;
        j.get("profiles")
            .cloned()
            .context("no profiles field in reply")
    }

    /// Inspect one profile (full thresholds + signature).
    pub fn inspect_profile(
        &mut self,
        task: &str,
        mode: &str,
        metric: &str,
    ) -> Result<Json> {
        let j = self.roundtrip(&Json::obj(vec![
            ("cmd", Json::Str("profiles".into())),
            ("action", Json::Str("inspect".into())),
            ("task", Json::Str(task.into())),
            ("mode", Json::Str(mode.into())),
            ("metric", Json::Str(metric.into())),
        ]))?;
        if let Some(e) = j.get("error").and_then(Json::as_str) {
            bail!("server error: {e}");
        }
        j.get("profile").cloned().context("no profile field in reply")
    }

    /// Mark a profile stale so the next request recalibrates; returns
    /// whether the profile existed.
    pub fn invalidate_profile(
        &mut self,
        task: &str,
        mode: &str,
        metric: &str,
    ) -> Result<bool> {
        let j = self.roundtrip(&Json::obj(vec![
            ("cmd", Json::Str("profiles".into())),
            ("action", Json::Str("invalidate".into())),
            ("task", Json::Str(task.into())),
            ("mode", Json::Str(mode.into())),
            ("metric", Json::Str(metric.into())),
        ]))?;
        if let Some(e) = j.get("error").and_then(Json::as_str) {
            bail!("server error: {e}");
        }
        j.get("invalidated")
            .and_then(Json::as_bool)
            .context("no invalidated field in reply")
    }

    pub fn generate(&mut self, task: &str, prompt: &str, policy: &str) -> Result<Response> {
        self.generate_with_slo(task, prompt, policy, None)
    }

    /// [`Client::generate`] with a per-request deadline budget attached. A
    /// server over its shed watermark (or unable to meet the budget)
    /// rejects with `error` + a finite `retry_after_ms` instead of queueing.
    pub fn generate_with_slo(
        &mut self,
        task: &str,
        prompt: &str,
        policy: &str,
        slo_ms: Option<f64>,
    ) -> Result<Response> {
        let mut pairs = vec![
            ("task", Json::Str(task.into())),
            ("prompt", Json::Str(prompt.into())),
            ("policy", Json::Str(policy.into())),
        ];
        if let Some(slo) = slo_ms {
            pairs.push(("slo_ms", Json::Num(slo)));
        }
        let msg = Json::obj(pairs);
        let j = self.roundtrip(&msg)?;
        if j.get("id").is_none() {
            if let Some(e) = j.get("error").and_then(Json::as_str) {
                bail!("server error: {e}");
            }
        }
        response_from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::model::fixtures::tiny_config;
    use crate::sim::SimModel;

    fn start_stack() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig::default(), tiny_config(), |_| {
                Ok(SimModel::math_like(3))
            })
            .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
        (server, coord)
    }

    #[test]
    fn ping_and_metrics() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        assert!(c.ping().unwrap());
        // counters appear once a request has flowed through
        c.generate("synth-math", "Q: 1+1=?", "static:0.9").unwrap();
        let m = c.metrics().unwrap();
        assert!(m.contains("osdt_requests_submitted_total"), "{m}");
        assert!(m.contains("osdt_requests_completed_total 1"), "{m}");
        // scheduler metrics ride the same exposition
        assert!(m.contains("osdt_queue_depth"), "{m}");
        assert!(m.contains("osdt_batch_occupancy"), "{m}");
        assert!(m.contains("osdt_admission_wait_count"), "{m}");
        assert!(m.contains("osdt_scheduler_steps_total"), "{m}");
        server.stop();
    }

    #[test]
    fn generate_over_wire() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        let r = c
            .generate("synth-math", "Q: 1+2=?", "static:0.9")
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.steps > 0);
        assert!(!r.completion.is_empty());
        server.stop();
    }

    #[test]
    fn malformed_json_gets_error_line() {
        let (server, _coord) = start_stack();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "this is not json").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
        server.stop();
    }

    #[test]
    fn missing_fields_rejected() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        let j = c
            .roundtrip(&Json::obj(vec![("task", Json::Str("synth-math".into()))]))
            .unwrap();
        assert!(j.get("error").is_some());
        server.stop();
    }

    #[test]
    fn multiple_clients() {
        let (server, coord) = start_stack();
        let addr = server.addr;
        let mut handles = vec![];
        for i in 0..4 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let r = c
                    .generate("synth-math", &format!("Q: {i}+1=?"), "static:0.8")
                    .unwrap();
                assert!(r.error.is_none());
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(coord.metrics.counter_value("requests_completed"), 4);
        server.stop();
    }

    #[test]
    fn profiles_admin_list_inspect_invalidate() {
        let (server, coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        // empty registry -> empty list
        assert_eq!(c.profiles().unwrap().as_arr().unwrap().len(), 0);
        // calibrate one task, then the registry surfaces it
        let r = c
            .generate("synth-math", "Q: 1+2=?", "osdt:block:q1:0.75:0.2")
            .unwrap();
        assert!(r.calibrated);
        let list = c.profiles().unwrap();
        let rows = list.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("task").and_then(Json::as_str), Some("synth-math"));
        assert_eq!(rows[0].get("stale").and_then(Json::as_bool), Some(false));
        // inspect returns the full thresholds + signature
        let prof = c.inspect_profile("synth-math", "block", "q1").unwrap();
        assert!(prof.get("taus").is_some());
        assert!(!prof
            .get("signature")
            .and_then(Json::as_arr)
            .unwrap()
            .is_empty());
        // invalidate -> stale -> next request recalibrates
        assert!(c.invalidate_profile("synth-math", "block", "q1").unwrap());
        let r2 = c
            .generate("synth-math", "Q: 3+4=?", "osdt:block:q1:0.75:0.2")
            .unwrap();
        assert!(r2.calibrated, "invalidated profile must recalibrate");
        // unknown key: inspect errors, invalidate reports absence
        assert!(c.inspect_profile("nope", "block", "q1").is_err());
        assert!(!c.invalidate_profile("nope", "block", "q1").unwrap());
        // registry metrics ride the metrics exposition
        let m = c.metrics().unwrap();
        assert!(m.contains("osdt_calibrations_completed_total 2"), "{m}");
        assert!(m.contains("osdt_recalibrations_total 1"), "{m}");
        assert_eq!(coord.registry.metrics().counter_value("recalibrations"), 1);
        server.stop();
    }

    #[test]
    fn response_json_roundtrip() {
        let r = Response {
            id: 7,
            completion: "A: #### 5".into(),
            steps: 12,
            full_passes: 3,
            window_passes: 9,
            latency_ms: 41.5,
            tokens_per_sec: 2314.0,
            calibrated: true,
            ttft_ms: 8.25,
            error: None,
            retry_after_ms: None,
        };
        let back = response_from_json(&response_to_json(&r)).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.completion, r.completion);
        assert_eq!(back.steps, 12);
        assert!(back.calibrated);
        assert_eq!(back.ttft_ms, 8.25);
        assert!(back.error.is_none());
        assert!(back.retry_after_ms.is_none(), "absent on the wire stays None");
        // older servers omit ttft_ms: the client defaults it to 0
        let mut j = response_to_json(&r);
        if let Json::Obj(m) = &mut j {
            m.remove("ttft_ms");
        }
        assert_eq!(response_from_json(&j).unwrap().ttft_ms, 0.0);
        // a shed response carries its retry hint through the roundtrip
        let shed = Response::shed(9, 83.5, "shed: predicted backlog over watermark".into());
        let back = response_from_json(&response_to_json(&shed)).unwrap();
        assert_eq!(back.retry_after_ms, Some(83.5));
        assert!(back.error.unwrap().contains("shed"));
    }

    #[test]
    fn slo_field_parses_over_wire() {
        let (server, _coord) = start_stack();
        let mut c = Client::connect(server.addr).unwrap();
        // a generous per-request budget flows through the optional field
        // and the request completes normally (shedding is off by default)
        let r = c
            .generate_with_slo("synth-math", "Q: 2+3=?", "static:0.9", Some(60_000.0))
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.retry_after_ms.is_none());
        assert!(r.steps > 0);
        server.stop();
    }
}
