//! Configuration substrate: typed configs for the engine / policies /
//! server plus a small CLI argument parser (the registry has no clap).
//!
//! Policies are configured by a compact spec string used uniformly across
//! the CLI, the benches and the wire protocol:
//!
//!   sequential[:k]              LLaDA fixed-quota baseline (default k=1)
//!   static[:tau]                Fast-dLLM global threshold (default 0.9)
//!   factor[:f]                  Fast-dLLM factor schedule (default 0.95)
//!   osdt:MODE:METRIC:KAPPA:EPS  e.g. osdt:block:q1:0.75:0.2
//!                                    osdt:step-block:q2:0.75:0.2

use std::collections::VecDeque;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::policy::{DynamicMode, Metric, PolicySpec};

/// Engine-level configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Directory holding model_config.json / weights.bin / *.hlo.txt.
    pub artifact_dir: PathBuf,
    /// Use the Fast-dLLM dual KV cache (fwd_full_kv + fwd_window) instead
    /// of full recomputation every step.
    pub kv_cache: bool,
    /// Greedy-confidence decode temperature is fixed at 1.0 (paper setting);
    /// kept here to document the choice.
    pub temperature: f32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifact_dir: PathBuf::from("artifacts"),
            kv_cache: false,
            temperature: 1.0,
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub addr: String,
    /// Engine worker threads (each owns a PJRT executable set).
    pub workers: usize,
    /// Dynamic batcher window: max requests per batch.
    pub max_batch: usize,
    /// Dynamic batcher window: max wait before dispatching a partial batch.
    pub batch_wait_ms: u64,
    /// Profile registry persistence directory (None = in-memory only; set
    /// to warm-start calibrations across restarts). CLI: `--profile-dir`.
    pub profile_dir: Option<PathBuf>,
    /// Signature-drift cosine floor (profiles below it are marked stale
    /// and recalibrated). CLI: `--drift-floor`.
    pub drift_floor: f64,
    /// Registry-level EMA refinement rate (0 = pure one-shot, the paper's
    /// setting). CLI: `--ema-alpha`.
    pub ema_alpha: f64,
    /// Prometheus exposition address (None = endpoint disabled).
    /// CLI: `--metrics-addr`.
    pub metrics_addr: Option<String>,
    /// Profile-guided step elision: skip window passes the calibrated
    /// acceptance trajectory predicts are empty (DESIGN.md §14). Off by
    /// default — elision trades exactness of the step schedule for fewer
    /// passes and is opt-in. CLI: `--step-elision on|off`.
    pub step_elision: bool,
    /// Predicted per-step acceptance count below which a step is treated
    /// as empty by the elision planner. CLI: `--elide-floor`.
    pub elide_floor: f64,
    /// Admission order (DESIGN.md §15): predicted-cost priority (aged
    /// shortest-predicted-job-first) when true, plain FIFO when false.
    /// CLI: `--admission predictive|fifo`.
    pub predictive: bool,
    /// Alignment band for forecast-aware slot promotion, in predicted
    /// window passes (0 = FIFO promotion). CLI: `--align-band`.
    pub align_band: usize,
    /// Predicted-backlog shed watermark, in forward passes (0 = never
    /// shed). CLI: `--shed-watermark`.
    pub shed_watermark: usize,
    /// Default per-request deadline budget, ms (0 = none). CLI: `--slo-ms`.
    pub slo_ms: f64,
    /// Per-connection socket read/write timeout, ms (0 = no timeout): a
    /// stalled or half-dead peer cannot pin an `osdt-conn` thread
    /// forever. CLI: `--conn-timeout-ms`.
    pub conn_timeout_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        let registry = crate::policy::RegistryConfig::default();
        ServerConfig {
            addr: "127.0.0.1:7474".into(),
            workers: 1,
            max_batch: 4,
            batch_wait_ms: 5,
            profile_dir: None,
            drift_floor: registry.drift_floor,
            ema_alpha: registry.ema_alpha,
            metrics_addr: None,
            step_elision: false,
            elide_floor: crate::policy::DEFAULT_ELIDE_FLOOR,
            predictive: true,
            align_band: 0,
            shed_watermark: 0,
            slo_ms: 0.0,
            conn_timeout_ms: 30_000,
        }
    }
}

/// Parse a policy spec string (see module docs).
pub fn parse_policy_spec(s: &str) -> Result<PolicySpec> {
    let parts: Vec<&str> = s.split(':').collect();
    let fl = |x: &str, what: &str| -> Result<f64> {
        x.parse::<f64>().with_context(|| format!("bad {what}: {x:?}"))
    };
    match parts[0] {
        "sequential" => {
            let k = if parts.len() > 1 {
                parts[1].parse::<usize>().context("bad k")?
            } else {
                1
            };
            if k == 0 {
                bail!("sequential k must be >= 1");
            }
            Ok(PolicySpec::Sequential { k })
        }
        "static" => {
            let tau = if parts.len() > 1 { fl(parts[1], "tau")? } else { 0.9 };
            if !(0.0..=1.0).contains(&tau) {
                bail!("tau must be in [0,1]");
            }
            Ok(PolicySpec::Static { tau })
        }
        "factor" => {
            let f = if parts.len() > 1 { fl(parts[1], "factor")? } else { 0.95 };
            if !(0.0..=1.0).contains(&f) {
                bail!("factor must be in [0,1]");
            }
            Ok(PolicySpec::Factor { factor: f })
        }
        "osdt" => {
            if parts.len() != 5 {
                bail!("osdt spec is osdt:MODE:METRIC:KAPPA:EPS, got {s:?}");
            }
            let mode = DynamicMode::parse(parts[1])
                .map_err(|_| anyhow::anyhow!("unknown osdt mode {:?}", parts[1]))?;
            let metric = Metric::parse(parts[2])?;
            let kappa = fl(parts[3], "kappa")?;
            let epsilon = fl(parts[4], "epsilon")?;
            if !(0.0..=1.0).contains(&kappa) || !(0.0..1.0).contains(&epsilon) {
                bail!("kappa in [0,1], epsilon in [0,1) required");
            }
            Ok(PolicySpec::Osdt {
                mode,
                metric,
                kappa,
                epsilon,
            })
        }
        other => bail!(
            "unknown policy {other:?} (expected sequential|static|factor|osdt)"
        ),
    }
}

// ---------------------------------------------------------------------------
// CLI argument parser
// ---------------------------------------------------------------------------

/// Simple `--flag value` / `--flag` / positional parser with typed getters.
/// No short flags, no combined `--k=v` — kept intentionally small.
#[derive(Debug)]
pub struct Args {
    pub positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    /// Parse from raw args (without argv[0]). `value_flags` lists flags
    /// that consume a following value; all other `--x` are boolean.
    pub fn parse(raw: impl IntoIterator<Item = String>, value_flags: &[&str]) -> Result<Args> {
        let mut q: VecDeque<String> = raw.into_iter().collect();
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        while let Some(a) = q.pop_front() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), Some(v.to_string())));
                } else if value_flags.contains(&name) {
                    let v = q
                        .pop_front()
                        .with_context(|| format!("--{name} needs a value"))?;
                    flags.push((name.to_string(), Some(v)));
                } else {
                    flags.push((name.to_string(), None));
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args { positional, flags })
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Every occurrence of a repeatable value flag, in order (e.g.
    /// `serve-fleet --replica=A --replica=B`).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == name)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_policy_specs() {
        assert!(matches!(
            parse_policy_spec("sequential").unwrap(),
            PolicySpec::Sequential { k: 1 }
        ));
        assert!(matches!(
            parse_policy_spec("sequential:3").unwrap(),
            PolicySpec::Sequential { k: 3 }
        ));
        match parse_policy_spec("static:0.85").unwrap() {
            PolicySpec::Static { tau } => assert!((tau - 0.85).abs() < 1e-12),
            _ => panic!(),
        }
        match parse_policy_spec("osdt:block:q1:0.75:0.2").unwrap() {
            PolicySpec::Osdt { mode, metric, kappa, epsilon } => {
                assert_eq!(mode, DynamicMode::Block);
                assert_eq!(metric, Metric::Q1);
                assert!((kappa - 0.75).abs() < 1e-12);
                assert!((epsilon - 0.2).abs() < 1e-12);
            }
            _ => panic!(),
        }
        match parse_policy_spec("osdt:step-block:mean:0.9:0.05").unwrap() {
            PolicySpec::Osdt { mode, metric, .. } => {
                assert_eq!(mode, DynamicMode::StepBlock);
                assert_eq!(metric, Metric::Mean);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "unknown",
            "static:2.0",
            "sequential:0",
            "osdt:block:q1:0.75",          // missing eps
            "osdt:spiral:q1:0.75:0.2",     // bad mode
            "osdt:block:q9:0.75:0.2",      // bad metric
            "osdt:block:q1:0.75:1.0",      // eps out of range
        ] {
            assert!(parse_policy_spec(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn args_basic() {
        let a = Args::parse(
            sv(&["serve", "--addr", "0.0.0.0:1", "--verbose", "x"]),
            &["addr"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["serve", "x"]);
        assert_eq!(a.get("addr"), Some("0.0.0.0:1"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn args_equals_form_and_typed() {
        let a = Args::parse(sv(&["--n=42", "--rate=1.5"]), &[]).unwrap();
        assert_eq!(a.get_parse::<usize>("n", 0).unwrap(), 42);
        assert!((a.get_parse::<f64>("rate", 0.0).unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(a.get_parse::<usize>("missing", 7).unwrap(), 7);
        let b = Args::parse(sv(&["--n=x"]), &[]).unwrap();
        assert!(b.get_parse::<usize>("n", 0).is_err());
    }

    #[test]
    fn args_missing_value_errors() {
        assert!(Args::parse(sv(&["--addr"]), &["addr"]).is_err());
    }

    #[test]
    fn args_get_all_keeps_order_and_get_takes_last() {
        let a = Args::parse(
            sv(&["--replica=127.0.0.1:1", "--replica=127.0.0.1:2"]),
            &[],
        )
        .unwrap();
        assert_eq!(a.get_all("replica"), vec!["127.0.0.1:1", "127.0.0.1:2"]);
        assert_eq!(a.get("replica"), Some("127.0.0.1:2"));
        assert!(a.get_all("missing").is_empty());
    }
}
