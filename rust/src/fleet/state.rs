//! Durable fleet state: `state.json` is the supervisor's pidfile,
//! lockfile, and replica table in one document.
//!
//! The file is written atomically (temp + rename in the same directory)
//! on every supervisor tick, so readers never observe a torn document.
//! On startup the supervisor loads any existing file and classifies it
//! ([`FleetState::staleness`]): a live supervisor PID means a second
//! supervisor must refuse to start; a dead PID means the previous
//! supervisor crashed and the file is *stale* — its replica entries are
//! probed individually and either adopted (still alive) or respawned.

use std::net::TcpListener;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::procfs::pid_alive;

/// `state.json` schema version; bumped on incompatible layout changes.
pub const FLEET_STATE_SCHEMA: u32 = 1;

/// One replica row in the supervisor's table.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaState {
    /// Stable replica index; ports are allocated once per id, so the
    /// router's replica table never changes across respawns.
    pub id: usize,
    pub pid: u32,
    /// Serving address (`host:port`) the replica listens on.
    pub addr: String,
    /// Times this slot has been respawned since the supervisor started.
    pub respawns: u64,
}

/// The whole durable fleet document.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetState {
    pub schema: u32,
    pub supervisor_pid: u32,
    /// Supervisor control socket (`fleet status` / `rolling-restart` /
    /// `stop` speak JSON lines here).
    pub control_addr: String,
    pub router_pid: u32,
    pub router_addr: String,
    /// Mirror of the shared ProfileStore generation counter — bumped
    /// exactly once per fleet-wide (re)calibration, so operators can
    /// read invalidation progress without touching the store.
    pub profile_generation: u64,
    pub replicas: Vec<ReplicaState>,
}

/// Startup classification of an existing `state.json`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StaleState {
    /// No state file: a fresh start.
    Absent,
    /// The recorded supervisor PID is alive — a second supervisor must
    /// not start against the same directory.
    Live,
    /// The recorded supervisor PID is dead: the previous supervisor
    /// crashed (or was SIGKILLed) and left the file behind. Replicas
    /// listed in it may still be running and should be adopted.
    Stale,
}

impl FleetState {
    pub fn new(control_addr: String) -> FleetState {
        FleetState {
            schema: FLEET_STATE_SCHEMA,
            supervisor_pid: std::process::id(),
            control_addr,
            router_pid: 0,
            router_addr: String::new(),
            profile_generation: 0,
            replicas: Vec::new(),
        }
    }

    /// Path of `state.json` under a fleet directory.
    pub fn path_in(dir: &Path) -> PathBuf {
        dir.join("state.json")
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Num(self.schema as f64)),
            ("supervisor_pid", Json::Num(self.supervisor_pid as f64)),
            ("control_addr", Json::Str(self.control_addr.clone())),
            (
                "router",
                Json::obj(vec![
                    ("pid", Json::Num(self.router_pid as f64)),
                    ("addr", Json::Str(self.router_addr.clone())),
                ]),
            ),
            (
                "profile_generation",
                Json::Num(self.profile_generation as f64),
            ),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Num(r.id as f64)),
                                ("pid", Json::Num(r.pid as f64)),
                                ("addr", Json::Str(r.addr.clone())),
                                ("respawns", Json::Num(r.respawns as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<FleetState> {
        fn num(j: &Json, k: &str) -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("missing/bad field {k:?}"))
        }
        fn text(j: &Json, k: &str) -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("missing/bad field {k:?}"))
        }
        let schema = num(j, "schema")? as u32;
        if schema != FLEET_STATE_SCHEMA {
            bail!("state.json schema {schema} != {FLEET_STATE_SCHEMA}");
        }
        let router = j.get("router").context("missing field \"router\"")?;
        let rows = j
            .get("replicas")
            .and_then(Json::as_arr)
            .context("missing/bad field \"replicas\"")?;
        let mut replicas = Vec::new();
        for r in rows {
            replicas.push(ReplicaState {
                id: num(r, "id")?,
                pid: num(r, "pid")? as u32,
                addr: text(r, "addr")?,
                respawns: num(r, "respawns")? as u64,
            });
        }
        Ok(FleetState {
            schema,
            supervisor_pid: num(j, "supervisor_pid")? as u32,
            control_addr: text(j, "control_addr")?,
            router_pid: num(router, "pid")? as u32,
            router_addr: text(router, "addr")?,
            profile_generation: num(j, "profile_generation")? as u64,
            replicas,
        })
    }

    /// Atomically persist to `state.json` under `dir` (temp + rename in
    /// the same directory, so a crash never leaves a torn file).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating fleet dir {}", dir.display()))?;
        let tmp = dir.join(format!(".state.tmp.{}", std::process::id()));
        let path = Self::path_in(dir);
        std::fs::write(&tmp, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path).with_context(|| {
            let _ = std::fs::remove_file(&tmp);
            format!("renaming into {}", path.display())
        })
    }

    /// Load `state.json` from `dir`; Ok(None) if absent.
    pub fn load(dir: &Path) -> Result<Option<FleetState>> {
        let path = Self::path_in(dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading {}", path.display()))
            }
        };
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        Ok(Some(Self::from_json(&j)?))
    }

    /// Classify an existing state file for startup stale-detection.
    pub fn staleness(dir: &Path) -> Result<StaleState> {
        match Self::load(dir)? {
            None => Ok(StaleState::Absent),
            Some(st) if pid_alive(st.supervisor_pid) => Ok(StaleState::Live),
            Some(_) => Ok(StaleState::Stale),
        }
    }

    /// Remove `state.json` (supervisor clean shutdown).
    pub fn remove(dir: &Path) -> Result<()> {
        let path = Self::path_in(dir);
        match std::fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => {
                Err(e).with_context(|| format!("removing {}", path.display()))
            }
        }
    }
}

/// Allocate a free loopback port: bind :0, read the assignment, drop
/// the listener. The tiny race window (another process grabbing the
/// port before our child binds it) is acceptable for the supervisor —
/// a replica that loses the race fails its first heartbeat and is
/// respawned on the same port once it frees up.
pub fn free_port() -> Result<u16> {
    let l = TcpListener::bind("127.0.0.1:0").context("binding :0")?;
    Ok(l.local_addr().context("reading bound addr")?.port())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "osdt-fleet-state-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> FleetState {
        let mut st = FleetState::new("127.0.0.1:9100".into());
        st.router_pid = 41;
        st.router_addr = "127.0.0.1:9101".into();
        st.profile_generation = 3;
        st.replicas = vec![
            ReplicaState {
                id: 0,
                pid: 42,
                addr: "127.0.0.1:9102".into(),
                respawns: 0,
            },
            ReplicaState {
                id: 1,
                pid: 43,
                addr: "127.0.0.1:9103".into(),
                respawns: 2,
            },
        ];
        st
    }

    #[test]
    fn roundtrips_through_json() {
        let st = sample();
        let parsed =
            FleetState::from_json(&Json::parse(&st.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(parsed, st);
    }

    #[test]
    fn save_load_remove() {
        let dir = tmpdir("slr");
        let st = sample();
        st.save(&dir).unwrap();
        assert_eq!(FleetState::load(&dir).unwrap(), Some(st));
        FleetState::remove(&dir).unwrap();
        assert_eq!(FleetState::load(&dir).unwrap(), None);
        // Idempotent removal.
        FleetState::remove(&dir).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staleness_classification() {
        let dir = tmpdir("stale");
        assert_eq!(FleetState::staleness(&dir).unwrap(), StaleState::Absent);
        // A state file naming our own (live) PID reads as Live.
        let mut st = sample();
        st.supervisor_pid = std::process::id();
        st.save(&dir).unwrap();
        assert_eq!(FleetState::staleness(&dir).unwrap(), StaleState::Live);
        // A dead supervisor PID reads as Stale.
        st.supervisor_pid = u32::MAX;
        st.save(&dir).unwrap();
        assert_eq!(FleetState::staleness(&dir).unwrap(), StaleState::Stale);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_unknown_schema() {
        let mut j = sample().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Num(99.0));
        }
        assert!(FleetState::from_json(&j).is_err());
    }

    #[test]
    fn free_port_is_bindable() {
        let p = free_port().unwrap();
        assert!(p > 0);
        // Immediately rebindable by us (SO_REUSEADDR not even needed on
        // a cleanly dropped listener).
        TcpListener::bind(("127.0.0.1", p)).unwrap();
    }
}
