//! Fleet tier: a standalone router process in front of N supervised
//! replica processes (DESIGN.md §16).
//!
//! The single-process server (`server::Server`) remains the unit of
//! execution; this module adds the process topology around it:
//!
//! - [`state`] — the supervisor's durable `state.json` (pidfile +
//!   lockfile + replica table + profile generation mirror) with atomic
//!   persistence and stale-state detection.
//! - [`router`] — `serve-fleet`: a thread-per-connection TCP daemon
//!   speaking the existing JSON line protocol, forwarding each request
//!   to the least-loaded healthy replica, retrying idempotent requests
//!   on survivors with jittered exponential backoff, and degrading to
//!   §15 shedding (finite `retry_after_ms`) when capacity is gone.
//! - [`supervisor`] — `fleet run`: spawns the router and replicas as
//!   detached process-group leaders (they survive a supervisor crash),
//!   heartbeats them, respawns the dead with exponential backoff on
//!   their original ports, serializes rolling restarts behind router
//!   drains, and mirrors the ProfileStore generation counter into
//!   `state.json` so operators can watch cross-process invalidation.
//!
//! Profiles stay exactly-once fleet-wide through the cross-process
//! calibration lease in `policy::registry` (`RegistryConfig::cross_process`)
//! — replicas share one `--profile-dir` and coordinate through
//! version-stamped ProfileStore files plus a generation counter, never
//! through the supervisor (which only observes).

pub mod router;
pub mod state;
pub mod supervisor;

pub use router::{
    probe_ping, roundtrip_line, FleetRouter, ReplicaSpec, RouterConfig,
};
pub use state::{FleetState, ReplicaState, StaleState};
pub use supervisor::{FleetConfig, Supervisor};
