//! The fleet router: a standalone front-end daemon that speaks the
//! existing TCP JSON line protocol on both sides.
//!
//! Requests are forwarded to the least-loaded healthy replica over a
//! fresh per-attempt connection. Decode requests are idempotent (a
//! retried decode re-runs the same deterministic policy over the same
//! prompt), so transport failures — a replica dying mid-decode, a
//! connect refusal, a read timeout — are retried on surviving replicas
//! with jittered exponential backoff. When no healthy replica remains
//! (or the retry budget is spent) the router degrades to §15 shedding:
//! the client receives `error` plus a finite `retry_after_ms` rather
//! than an indefinite hang, exactly as a single overloaded server
//! would shed at admission.
//!
//! A background health thread pings every replica each
//! `health_interval`, so a SIGKILLed replica stops receiving new
//! requests within one heartbeat even before a forward attempt fails.
//! Replicas can also be administratively *drained* (`{"cmd":"drain",
//! "replica":N}`) — they keep serving in-flight work but receive no new
//! requests — which is the primitive the supervisor's rolling restart
//! is built from.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Response;
use crate::metrics;
use crate::server::response_to_json;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One replica endpoint in the router's (static) table. Ports are
/// allocated once by the supervisor, so the table survives respawns.
#[derive(Clone, Debug)]
pub struct ReplicaSpec {
    pub id: usize,
    pub addr: String,
}

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Listen address (port 0 for ephemeral).
    pub addr: String,
    pub replicas: Vec<ReplicaSpec>,
    /// Health-probe period; a dead replica is off rotation within one.
    pub health_interval: Duration,
    /// Per-attempt connect/read/write timeout on forwarded requests.
    pub request_timeout: Duration,
    /// Retries after the first attempt before degrading to shedding.
    pub max_retries: usize,
    /// First-retry backoff; doubles per attempt up to `backoff_max`,
    /// then jittered into [d/2, d).
    pub backoff_base: Duration,
    pub backoff_max: Duration,
    /// Total in-flight forwards above which new requests are shed
    /// outright — the fleet-capacity analogue of `--shed-watermark`
    /// (0 = unlimited).
    pub shed_outstanding: usize,
    /// `retry_after_ms` hint attached to shed responses.
    pub shed_retry_after_ms: f64,
    /// Backoff-jitter PRNG seed (deterministic for tests).
    pub seed: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:0".into(),
            replicas: Vec::new(),
            health_interval: Duration::from_millis(500),
            request_timeout: Duration::from_secs(30),
            max_retries: 3,
            backoff_base: Duration::from_millis(25),
            backoff_max: Duration::from_millis(400),
            shed_outstanding: 0,
            shed_retry_after_ms: 100.0,
            seed: 1,
        }
    }
}

struct Slot {
    spec: ReplicaSpec,
    healthy: AtomicBool,
    draining: AtomicBool,
    outstanding: AtomicUsize,
}

struct RouterState {
    cfg: RouterConfig,
    slots: Vec<Slot>,
    metrics: Arc<metrics::Registry>,
    rng: Mutex<Rng>,
    stop: AtomicBool,
    requests_seen: AtomicU64,
}

impl RouterState {
    /// Pick the healthy, non-draining replica with the fewest in-flight
    /// forwards (ties to the lowest id).
    fn pick(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| {
                s.healthy.load(Ordering::Relaxed)
                    && !s.draining.load(Ordering::Relaxed)
            })
            .min_by_key(|(i, s)| (s.outstanding.load(Ordering::Relaxed), *i))
            .map(|(i, _)| i)
    }

    fn mark_health(&self, idx: usize, healthy: bool) {
        let was = self.slots[idx].healthy.swap(healthy, Ordering::Relaxed);
        if was && !healthy {
            self.metrics.add("fleet_replica_failures", 1);
            log::warn!(
                "replica {} ({}) marked unhealthy",
                self.slots[idx].spec.id,
                self.slots[idx].spec.addr
            );
        } else if !was && healthy {
            log::info!(
                "replica {} ({}) healthy",
                self.slots[idx].spec.id,
                self.slots[idx].spec.addr
            );
        }
        self.update_gauges();
    }

    fn update_gauges(&self) {
        let healthy = self
            .slots
            .iter()
            .filter(|s| s.healthy.load(Ordering::Relaxed))
            .count();
        let draining = self
            .slots
            .iter()
            .filter(|s| s.draining.load(Ordering::Relaxed))
            .count();
        self.metrics.set_gauge("fleet_replicas_healthy", healthy as i64);
        self.metrics.set_gauge("fleet_replicas_draining", draining as i64);
    }

    fn total_outstanding(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.outstanding.load(Ordering::Relaxed))
            .sum()
    }

    fn backoff(&self, attempt: usize) -> Duration {
        let d = self
            .cfg
            .backoff_base
            .saturating_mul(1u32 << attempt.min(16) as u32)
            .min(self.cfg.backoff_max);
        let jitter = self.rng.lock().unwrap().next_f64(); // [0,1)
        d / 2 + Duration::from_secs_f64(d.as_secs_f64() / 2.0 * jitter)
    }
}

/// Resolve `host:port` to a socket address (first match).
fn resolve(addr: &str) -> Option<SocketAddr> {
    addr.to_socket_addrs().ok()?.next()
}

/// One JSON-line ping over a fresh connection; true iff a `pong` came
/// back within `timeout`. Shared with the supervisor's heartbeat and
/// the `fleet` CLI.
pub fn probe_ping(addr: &str, timeout: Duration) -> bool {
    roundtrip_line(
        addr,
        &Json::obj(vec![("cmd", Json::Str("ping".into()))]).to_string(),
        timeout,
    )
    .map(|j| j.get("pong").and_then(Json::as_bool).unwrap_or(false))
    .unwrap_or(false)
}

/// Forward one raw protocol line over a fresh connection and read one
/// reply line, all under `timeout`. Public: the `fleet` CLI drives the
/// router's and supervisor's control commands through it.
pub fn roundtrip_line(
    addr: &str,
    line: &str,
    timeout: Duration,
) -> Result<Json> {
    let sa = resolve(addr).with_context(|| format!("resolving {addr}"))?;
    let stream = TcpStream::connect_timeout(&sa, timeout)
        .with_context(|| format!("connecting {addr}"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    stream.set_write_timeout(Some(timeout)).ok();
    stream.set_nodelay(true).ok();
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{line}")?;
    writer.flush()?;
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        anyhow::bail!("replica {addr} closed the connection");
    }
    Ok(Json::parse(&reply)?)
}

/// A running fleet router; dropping/`stop()` halts it.
pub struct FleetRouter {
    pub addr: SocketAddr,
    state: Arc<RouterState>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl FleetRouter {
    pub fn start(cfg: RouterConfig) -> Result<FleetRouter> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let slots = cfg
            .replicas
            .iter()
            .map(|spec| Slot {
                spec: spec.clone(),
                // Optimistic until the first probe: a replica that is
                // actually down fails its first forward and is marked
                // unhealthy immediately.
                healthy: AtomicBool::new(true),
                draining: AtomicBool::new(false),
                outstanding: AtomicUsize::new(0),
            })
            .collect();
        let state = Arc::new(RouterState {
            rng: Mutex::new(Rng::new(cfg.seed ^ 0x0f1e_e7f1)),
            cfg,
            slots,
            metrics: Arc::new(metrics::Registry::new()),
            stop: AtomicBool::new(false),
            requests_seen: AtomicU64::new(0),
        });
        state.update_gauges();

        let mut handles = Vec::new();
        // Health thread: probe every replica each interval.
        {
            let st = state.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("osdt-fleet-health".into())
                    .spawn(move || {
                        let probe_to = st
                            .cfg
                            .health_interval
                            .min(Duration::from_millis(250));
                        while !st.stop.load(Ordering::Relaxed) {
                            for i in 0..st.slots.len() {
                                let ok = probe_ping(
                                    &st.slots[i].spec.addr,
                                    probe_to,
                                );
                                st.mark_health(i, ok);
                            }
                            std::thread::sleep(st.cfg.health_interval);
                        }
                    })?,
            );
        }
        // Accept loop, same shape as the single-process server.
        {
            let st = state.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("osdt-fleet-accept".into())
                    .spawn(move || {
                        log::info!("fleet router listening on {local}");
                        while !st.stop.load(Ordering::Relaxed) {
                            match listener.accept() {
                                Ok((stream, _peer)) => {
                                    let st2 = st.clone();
                                    let _ = std::thread::Builder::new()
                                        .name("osdt-fleet-conn".into())
                                        .spawn(move || {
                                            let _ = handle_conn(stream, &st2);
                                        });
                                }
                                Err(e)
                                    if e.kind()
                                        == std::io::ErrorKind::WouldBlock =>
                                {
                                    std::thread::sleep(Duration::from_millis(
                                        5,
                                    ));
                                }
                                Err(e) => {
                                    log::warn!("fleet accept error: {e}");
                                    break;
                                }
                            }
                        }
                    })?,
            );
        }
        Ok(FleetRouter { addr: local, state, handles })
    }

    /// The router's own metric registry (fleet_* families).
    pub fn metrics(&self) -> Arc<metrics::Registry> {
        self.state.metrics.clone()
    }

    /// Administratively drain / undrain a replica (used by tests; the
    /// wire `drain` command drives the same bit).
    pub fn set_draining(&self, replica: usize, draining: bool) -> bool {
        match self.state.slots.iter().find(|s| s.spec.id == replica) {
            Some(s) => {
                s.draining.store(draining, Ordering::Relaxed);
                self.state.update_gauges();
                true
            }
            None => false,
        }
    }

    pub fn stop(mut self) {
        self.state.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for FleetRouter {
    fn drop(&mut self) {
        self.state.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, st: &Arc<RouterState>) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => {
                Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))])
            }
            Ok(j) => match j.get("cmd").and_then(Json::as_str) {
                Some("ping") => Json::obj(vec![("pong", Json::Bool(true))]),
                Some("metrics") => Json::obj(vec![(
                    "metrics",
                    Json::Str(st.metrics.render()),
                )]),
                Some("fleet-status") => status_doc(st),
                Some("drain") => drain_cmd(st, &j, true),
                Some("undrain") => drain_cmd(st, &j, false),
                Some(other) => Json::obj(vec![(
                    "error",
                    Json::Str(format!("unknown cmd {other:?}")),
                )]),
                // Anything without `cmd` is a decode request: forward.
                None => route(st, &line, &j),
            },
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
    }
    Ok(())
}

fn status_doc(st: &RouterState) -> Json {
    let rows = st
        .slots
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("id", Json::Num(s.spec.id as f64)),
                ("addr", Json::Str(s.spec.addr.clone())),
                ("healthy", Json::Bool(s.healthy.load(Ordering::Relaxed))),
                ("draining", Json::Bool(s.draining.load(Ordering::Relaxed))),
                (
                    "outstanding",
                    Json::Num(s.outstanding.load(Ordering::Relaxed) as f64),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("replicas", Json::Arr(rows)),
        (
            "requests",
            Json::Num(st.requests_seen.load(Ordering::Relaxed) as f64),
        ),
    ])
}

fn drain_cmd(st: &RouterState, j: &Json, draining: bool) -> Json {
    let id = match j.get("replica").and_then(Json::as_f64) {
        Some(n) => n as usize,
        None => {
            return Json::obj(vec![(
                "error",
                Json::Str("drain needs a replica id".into()),
            )])
        }
    };
    match st.slots.iter().find(|s| s.spec.id == id) {
        None => Json::obj(vec![(
            "error",
            Json::Str(format!("no replica {id}")),
        )]),
        Some(s) => {
            s.draining.store(draining, Ordering::Relaxed);
            st.update_gauges();
            Json::obj(vec![
                ("replica", Json::Num(id as f64)),
                ("draining", Json::Bool(draining)),
                (
                    "outstanding",
                    Json::Num(s.outstanding.load(Ordering::Relaxed) as f64),
                ),
            ])
        }
    }
}

/// Forward one request line, retrying transport failures on surviving
/// replicas; degrade to a §15 shed response when capacity is gone.
fn route(st: &Arc<RouterState>, line: &str, j: &Json) -> Json {
    st.requests_seen.fetch_add(1, Ordering::Relaxed);
    let id = j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let shed = |st: &RouterState, reason: &str| {
        st.metrics.add("fleet_requests_shed", 1);
        response_to_json(&Response::shed(
            id,
            st.cfg.shed_retry_after_ms,
            format!("shed: {reason}"),
        ))
    };
    if st.cfg.shed_outstanding > 0
        && st.total_outstanding() >= st.cfg.shed_outstanding
    {
        return shed(st, "fleet backlog over watermark");
    }
    let attempts = st.cfg.max_retries + 1;
    for attempt in 0..attempts {
        let idx = match st.pick() {
            Some(i) => i,
            // Every replica unhealthy or draining: capacity is below
            // any backlog — shed rather than hang.
            None => return shed(st, "no healthy replica"),
        };
        let slot = &st.slots[idx];
        slot.outstanding.fetch_add(1, Ordering::Relaxed);
        let res = roundtrip_line(&slot.spec.addr, line, st.cfg.request_timeout);
        slot.outstanding.fetch_sub(1, Ordering::Relaxed);
        match res {
            Ok(reply) => {
                st.metrics.add("fleet_requests_routed", 1);
                return reply;
            }
            Err(e) => {
                log::warn!(
                    "forward to replica {} failed: {e:#}",
                    slot.spec.id
                );
                st.mark_health(idx, false);
                if attempt + 1 < attempts {
                    st.metrics.add("fleet_request_retries", 1);
                    std::thread::sleep(st.backoff(attempt));
                }
            }
        }
    }
    shed(st, "retry budget exhausted")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, CoordinatorConfig};
    use crate::model::fixtures::tiny_config;
    use crate::server::{Client, Server};
    use crate::sim::SimModel;

    /// Two single-process replicas on the same sim seed (so completions
    /// are token-identical) behind one router.
    fn start_fleet(
        max_retries: usize,
    ) -> (FleetRouter, Vec<(Server, Arc<Coordinator>)>) {
        let mut replicas = Vec::new();
        let mut specs = Vec::new();
        for id in 0..2 {
            let coord = Arc::new(
                Coordinator::start(
                    CoordinatorConfig::default(),
                    tiny_config(),
                    |_| Ok(SimModel::math_like(5)),
                )
                .unwrap(),
            );
            let server = Server::start("127.0.0.1:0", coord.clone()).unwrap();
            specs.push(ReplicaSpec { id, addr: server.addr.to_string() });
            replicas.push((server, coord));
        }
        let router = FleetRouter::start(RouterConfig {
            replicas: specs,
            health_interval: Duration::from_millis(50),
            request_timeout: Duration::from_secs(10),
            max_retries,
            backoff_base: Duration::from_millis(5),
            backoff_max: Duration::from_millis(20),
            ..RouterConfig::default()
        })
        .unwrap();
        (router, replicas)
    }

    #[test]
    fn routes_and_reports_status() {
        let (router, replicas) = start_fleet(2);
        let mut c = Client::connect(router.addr).unwrap();
        assert!(c.ping().unwrap());
        let r = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(!r.completion.is_empty());
        assert_eq!(
            router.metrics().counter_value("fleet_requests_routed"),
            1
        );
        let status = roundtrip_line(
            &router.addr.to_string(),
            r#"{"cmd":"fleet-status"}"#,
            Duration::from_secs(2),
        )
        .unwrap();
        assert_eq!(status.req("replicas").unwrap().as_arr().unwrap().len(), 2);
        drop(replicas);
        router.stop();
    }

    #[test]
    fn failover_retries_on_survivor_with_identical_tokens() {
        let (router, mut replicas) = start_fleet(3);
        let mut c = Client::connect(router.addr).unwrap();
        let baseline =
            c.generate("synth-math", "Q: 2+3=?", "static:0.9").unwrap();
        assert!(baseline.error.is_none());
        // Kill replica 0 (stop its server + coordinator): the next
        // forward that lands there fails at transport level and is
        // retried on the survivor.
        let (server0, coord0) = replicas.remove(0);
        server0.stop();
        // the server held the only other Arc: dropping ours joins the
        // coordinator's workers via Drop
        drop(coord0);
        let mut saw_retry = false;
        for _ in 0..6 {
            let r =
                c.generate("synth-math", "Q: 2+3=?", "static:0.9").unwrap();
            assert!(r.error.is_none(), "{:?}", r.error);
            // Same seed + same prompt: failover must not corrupt tokens.
            assert_eq!(r.completion, baseline.completion);
            saw_retry = router
                .metrics()
                .counter_value("fleet_request_retries")
                > 0;
        }
        let m = router.metrics();
        assert!(
            saw_retry || m.counter_value("fleet_replica_failures") > 0,
            "dead replica never noticed"
        );
        drop(replicas);
        router.stop();
    }

    #[test]
    fn drained_replica_gets_no_new_requests() {
        let (router, replicas) = start_fleet(1);
        let reply = roundtrip_line(
            &router.addr.to_string(),
            r#"{"cmd":"drain","replica":0}"#,
            Duration::from_secs(2),
        )
        .unwrap();
        assert_eq!(reply.get("draining").and_then(Json::as_bool), Some(true));
        let mut c = Client::connect(router.addr).unwrap();
        for _ in 0..3 {
            let r =
                c.generate("synth-math", "Q: 4+4=?", "static:0.9").unwrap();
            assert!(r.error.is_none());
        }
        // All traffic went to replica 1.
        assert_eq!(
            replicas[0].1.metrics.counter_value("requests_completed"),
            0
        );
        assert_eq!(
            replicas[1].1.metrics.counter_value("requests_completed"),
            3
        );
        // Unknown replica id errors.
        let bad = roundtrip_line(
            &router.addr.to_string(),
            r#"{"cmd":"drain","replica":9}"#,
            Duration::from_secs(2),
        )
        .unwrap();
        assert!(bad.get("error").is_some());
        drop(replicas);
        router.stop();
    }

    #[test]
    fn sheds_with_finite_retry_after_when_capacity_gone() {
        let (router, replicas) = start_fleet(1);
        // Drain everything: no routable replica -> immediate shed.
        assert!(router.set_draining(0, true));
        assert!(router.set_draining(1, true));
        let mut c = Client::connect(router.addr).unwrap();
        let r = c.generate("synth-math", "Q: 5+5=?", "static:0.9").unwrap();
        assert!(
            r.error.as_deref().unwrap_or("").contains("shed"),
            "{:?}",
            r.error
        );
        assert!(r.retry_after_ms.unwrap().is_finite());
        assert_eq!(router.metrics().counter_value("fleet_requests_shed"), 1);
        // The raw wire response carries a finite retry_after_ms.
        let j = roundtrip_line(
            &router.addr.to_string(),
            r#"{"id":7,"task":"synth-math","prompt":"Q: 1+1=?","policy":"static:0.9"}"#,
            Duration::from_secs(2),
        )
        .unwrap();
        let retry = j.get("retry_after_ms").and_then(Json::as_f64).unwrap();
        assert!(retry.is_finite() && retry > 0.0, "{retry}");
        assert_eq!(j.get("id").and_then(Json::as_f64), Some(7.0));
        // Undrain restores service on the same connection.
        assert!(router.set_draining(1, false));
        let r = c.generate("synth-math", "Q: 5+5=?", "static:0.9").unwrap();
        assert!(r.error.is_none());
        drop(replicas);
        router.stop();
    }

    #[test]
    fn backoff_schedule_doubles_and_caps() {
        let st = RouterState {
            cfg: RouterConfig {
                backoff_base: Duration::from_millis(10),
                backoff_max: Duration::from_millis(40),
                ..RouterConfig::default()
            },
            slots: Vec::new(),
            metrics: Arc::new(metrics::Registry::new()),
            rng: Mutex::new(Rng::new(7)),
            stop: AtomicBool::new(false),
            requests_seen: AtomicU64::new(0),
        };
        for (attempt, full_ms) in [(0usize, 10.0f64), (1, 20.0), (2, 40.0), (5, 40.0)] {
            let d = st.backoff(attempt).as_secs_f64() * 1e3;
            assert!(
                d >= full_ms / 2.0 - 1e-9 && d < full_ms + 1e-9,
                "attempt {attempt}: {d}ms outside [{}, {})",
                full_ms / 2.0,
                full_ms
            );
        }
    }
}
