//! The fleet supervisor: spawns and babysits one router process plus N
//! replica processes, all detached into their own process groups so
//! they survive a supervisor crash (`fleet start` / `fleet run`).
//!
//! Robustness mechanics, in the order they matter during an incident:
//!
//! - **Durable state** — `state.json` ([`FleetState`]) is persisted
//!   atomically every heartbeat. On startup an existing file is
//!   classified: a *live* supervisor PID refuses the second start, a
//!   *stale* one (previous supervisor crashed) has its replica rows
//!   probed individually — still-serving replicas are **adopted** on
//!   their recorded ports, dead ones respawned. Nothing is restarted
//!   that didn't need to be.
//! - **Heartbeat** — each tick reaps exited children, probes every
//!   process (`/proc` liveness + a protocol ping), and respawns the
//!   dead with jittered exponential backoff per slot, so a crash-looping
//!   replica cannot hot-spin the supervisor. Ports are allocated once
//!   per slot; respawns reuse them, so the router's table never changes.
//! - **Rolling restart** — one replica at a time: drain at the router,
//!   wait for in-flight work to finish, SIGKILL, respawn on the same
//!   port, wait healthy, undrain. The heartbeat skips only the slot
//!   under restart, so an *unrelated* replica dying mid-rolling-restart
//!   is still auto-respawned.
//!
//! The supervisor never touches profiles: replicas share one
//! `--profile-dir` with `--fleet-locks=on` and coordinate recalibration
//! among themselves (DESIGN.md §16); the supervisor only mirrors the
//! store's generation counter into `state.json` for operators.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::process::CommandExt;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::fleet::router::{probe_ping, roundtrip_line};
use crate::fleet::state::{free_port, FleetState, ReplicaState, StaleState};
use crate::metrics;
use crate::policy::ProfileStore;
use crate::util::json::Json;
use crate::util::procfs::{pid_alive, send_signal};
use crate::util::rng::Rng;

/// Slot id used for the router (replica ids are dense from 0).
const ROUTER_SLOT: usize = usize::MAX;
/// Sentinel for "no replica is under rolling restart".
const NO_RESTART: usize = usize::MAX - 1;

/// Supervisor configuration (`fleet run` flags map 1:1 onto this).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Fleet home: `state.json`, the shared `profiles/` store, and
    /// per-process log files all live here.
    pub dir: PathBuf,
    /// Binary to spawn for router and replicas (defaults to the
    /// supervisor's own executable).
    pub binary: PathBuf,
    pub replicas: usize,
    /// Replica model backend (`sim` needs no artifacts; anything else
    /// must be routable by `serve` via `replica_args`).
    pub backend: String,
    /// Shared sim seed — every replica decodes token-identically, which
    /// is what makes failover transparent in the smoke/chaos tests.
    pub sim_seed: u64,
    /// Router listen address (port 0 = allocate once at startup).
    pub router_addr: String,
    /// Supervisor control socket (port 0 = ephemeral; recorded in
    /// `state.json` for `fleet status|stop|rolling-restart`).
    pub control_addr: String,
    /// Heartbeat period: dead processes are detected within one.
    pub heartbeat: Duration,
    /// First respawn backoff; doubles per consecutive failure up to
    /// `respawn_max`, jittered into [d/2, d).
    pub respawn_base: Duration,
    pub respawn_max: Duration,
    /// Router per-request retry budget (forwarded to `serve-fleet`).
    pub max_retries: usize,
    /// Router per-attempt timeout (forwarded to `serve-fleet`).
    pub request_timeout: Duration,
    /// Extra flags appended to every replica's `serve` command line
    /// (e.g. `--artifacts=...` for a real-model fleet).
    pub replica_args: Vec<String>,
    /// Start even if `state.json` names a live supervisor (last resort;
    /// normally refused).
    pub force: bool,
    /// Jitter PRNG seed (deterministic for tests).
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            dir: PathBuf::from("fleet-state"),
            binary: std::env::current_exe()
                .unwrap_or_else(|_| PathBuf::from("osdt")),
            replicas: 2,
            backend: "sim".into(),
            sim_seed: 5,
            router_addr: "127.0.0.1:0".into(),
            control_addr: "127.0.0.1:0".into(),
            heartbeat: Duration::from_millis(500),
            respawn_base: Duration::from_millis(200),
            respawn_max: Duration::from_secs(5),
            max_retries: 3,
            request_timeout: Duration::from_secs(30),
            replica_args: Vec::new(),
            force: false,
            seed: 1,
        }
    }
}

/// Command line for one replica process: the ordinary single-process
/// `serve`, pointed at the shared profile store with cross-process
/// calibration leases on.
fn replica_cmdline(cfg: &FleetConfig, addr: &str) -> Vec<String> {
    let mut args = vec![
        "serve".to_string(),
        format!("--addr={addr}"),
        format!("--backend={}", cfg.backend),
        format!("--sim-seed={}", cfg.sim_seed),
        format!("--profile-dir={}", cfg.dir.join("profiles").display()),
        "--fleet-locks=on".to_string(),
    ];
    args.extend(cfg.replica_args.iter().cloned());
    args
}

/// Command line for the router process (`serve-fleet`).
fn router_cmdline(
    cfg: &FleetConfig,
    router_addr: &str,
    replica_addrs: &[String],
) -> Vec<String> {
    let mut args =
        vec!["serve-fleet".to_string(), format!("--addr={router_addr}")];
    for addr in replica_addrs {
        args.push(format!("--replica={addr}"));
    }
    args.push(format!("--health-interval-ms={}", cfg.heartbeat.as_millis()));
    args.push(format!(
        "--request-timeout-ms={}",
        cfg.request_timeout.as_millis()
    ));
    args.push(format!("--max-retries={}", cfg.max_retries));
    args
}

/// Jittered exponential respawn backoff for the `exp`-th consecutive
/// failure of one slot.
fn respawn_backoff(cfg: &FleetConfig, exp: u32, rng: &mut Rng) -> Duration {
    let full = cfg
        .respawn_base
        .saturating_mul(1u32 << exp.min(16))
        .min(cfg.respawn_max);
    full / 2
        + Duration::from_secs_f64(full.as_secs_f64() / 2.0 * rng.next_f64())
}

/// One supervised process slot (replica or the router).
struct Slot {
    /// Replica id, or [`ROUTER_SLOT`] for the router.
    id: usize,
    addr: String,
    pid: u32,
    /// Present when this supervisor spawned the process; adopted
    /// processes (stale-state recovery) have no child handle and are
    /// managed purely by PID.
    child: Option<Child>,
    respawns: u64,
    fail_streak: u32,
    backoff_exp: u32,
    next_respawn_at: Instant,
}

impl Slot {
    fn adopted(id: usize, addr: String, pid: u32, respawns: u64) -> Slot {
        Slot {
            id,
            addr,
            pid,
            child: None,
            respawns,
            fail_streak: 0,
            backoff_exp: 0,
            next_respawn_at: Instant::now(),
        }
    }

    fn label(&self) -> String {
        if self.id == ROUTER_SLOT {
            "router".to_string()
        } else {
            format!("replica {}", self.id)
        }
    }
}

struct Inner {
    cfg: FleetConfig,
    metrics: Arc<metrics::Registry>,
    store: ProfileStore,
    control_addr: String,
    router_addr: String,
    /// Replica addresses in id order — fixed at startup, reused across
    /// respawns, fed to every router spawn.
    replica_addrs: Vec<String>,
    /// Replica slots in id order, router slot last.
    slots: Mutex<Vec<Slot>>,
    rng: Mutex<Rng>,
    restarting: AtomicUsize,
    stop: AtomicBool,
}

impl Inner {
    /// Spawn one detached worker process appending to `<dir>/<tag>.log`.
    fn spawn_process(&self, tag: &str, args: &[String]) -> Result<Child> {
        let log_path = self.cfg.dir.join(format!("{tag}.log"));
        let log = File::options()
            .create(true)
            .append(true)
            .open(&log_path)
            .with_context(|| format!("opening {}", log_path.display()))?;
        let err = log.try_clone().context("cloning log handle")?;
        let mut cmd = Command::new(&self.cfg.binary);
        cmd.args(args)
            .stdin(Stdio::null())
            .stdout(Stdio::from(log))
            .stderr(Stdio::from(err))
            // New process group: a supervisor crash (or its controlling
            // terminal going away) must not take the workers down.
            .process_group(0);
        cmd.spawn().with_context(|| {
            format!("spawning {} {}", self.cfg.binary.display(), args.join(" "))
        })
    }

    fn spawn_slot_process(&self, id: usize, addr: &str) -> Result<Child> {
        if id == ROUTER_SLOT {
            self.spawn_process(
                "router",
                &router_cmdline(&self.cfg, addr, &self.replica_addrs),
            )
        } else {
            self.spawn_process(
                &format!("replica-{id}"),
                &replica_cmdline(&self.cfg, addr),
            )
        }
    }

    /// Kill a slot's process (if any) and reap the child handle.
    fn kill_slot(&self, slot: &mut Slot) {
        if slot.pid != 0 && pid_alive(slot.pid) {
            send_signal(slot.pid, "KILL");
        }
        if let Some(mut child) = slot.child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Respawn a slot on its original address, with backoff bookkeeping.
    fn respawn_slot(&self, slot: &mut Slot, now: Instant) {
        self.kill_slot(slot);
        match self.spawn_slot_process(slot.id, &slot.addr.clone()) {
            Ok(child) => {
                slot.pid = child.id();
                slot.child = Some(child);
                slot.respawns += 1;
                self.metrics.add("fleet_respawns", 1);
                log::warn!(
                    "{} respawned on {} (pid {}, respawn #{})",
                    slot.label(),
                    slot.addr,
                    slot.pid,
                    slot.respawns
                );
            }
            Err(e) => {
                slot.pid = 0;
                log::error!("{} respawn failed: {e:#}", slot.label());
            }
        }
        let backoff = respawn_backoff(
            &self.cfg,
            slot.backoff_exp,
            &mut self.rng.lock().unwrap(),
        );
        slot.next_respawn_at = now + backoff;
        slot.backoff_exp = slot.backoff_exp.saturating_add(1);
    }

    /// One heartbeat: reap, probe, respawn, persist.
    fn tick(&self) {
        let probe_to = self.cfg.heartbeat.min(Duration::from_millis(250));
        let now = Instant::now();
        let restarting = self.restarting.load(Ordering::Relaxed);
        {
            let mut slots = self.slots.lock().unwrap();
            for slot in slots.iter_mut() {
                if slot.id == restarting {
                    continue; // rolling restart owns this slot right now
                }
                if let Some(child) = slot.child.as_mut() {
                    if let Ok(Some(status)) = child.try_wait() {
                        log::warn!("{} exited: {status}", slot.label());
                        slot.child = None;
                    }
                }
                let alive = slot.pid != 0 && pid_alive(slot.pid);
                if alive && probe_ping(&slot.addr, probe_to) {
                    slot.fail_streak = 0;
                    slot.backoff_exp = 0;
                    continue;
                }
                slot.fail_streak = slot.fail_streak.saturating_add(1);
                // A dead PID respawns immediately (subject to backoff);
                // a live-but-unresponsive one gets a grace heartbeat
                // before being killed and respawned.
                if (!alive || slot.fail_streak >= 2)
                    && now >= slot.next_respawn_at
                {
                    self.respawn_slot(slot, now);
                }
            }
        }
        if let Err(e) = self.persist() {
            log::warn!("persisting state.json failed: {e:#}");
        }
    }

    /// Write the current fleet document to `state.json`.
    fn persist(&self) -> Result<()> {
        let mut st = FleetState::new(self.control_addr.clone());
        st.router_addr = self.router_addr.clone();
        st.profile_generation = self.store.generation();
        {
            let slots = self.slots.lock().unwrap();
            for s in slots.iter() {
                if s.id == ROUTER_SLOT {
                    st.router_pid = s.pid;
                } else {
                    st.replicas.push(ReplicaState {
                        id: s.id,
                        pid: s.pid,
                        addr: s.addr.clone(),
                        respawns: s.respawns,
                    });
                }
            }
        }
        st.save(&self.cfg.dir)
    }

    /// Status document for the control socket (and `fleet status`).
    fn status_doc(&self) -> Json {
        let slots = self.slots.lock().unwrap();
        let mut rows = Vec::new();
        let mut router = Json::Null;
        for s in slots.iter() {
            let doc = Json::obj(vec![
                ("id", Json::Num(s.id as f64)),
                ("addr", Json::Str(s.addr.clone())),
                ("pid", Json::Num(s.pid as f64)),
                ("alive", Json::Bool(s.pid != 0 && pid_alive(s.pid))),
                ("respawns", Json::Num(s.respawns as f64)),
            ]);
            if s.id == ROUTER_SLOT {
                router = Json::obj(vec![
                    ("addr", Json::Str(s.addr.clone())),
                    ("pid", Json::Num(s.pid as f64)),
                    ("alive", Json::Bool(s.pid != 0 && pid_alive(s.pid))),
                ]);
            } else {
                rows.push(doc);
            }
        }
        drop(slots);
        Json::obj(vec![
            ("supervisor_pid", Json::Num(std::process::id() as f64)),
            ("router", router),
            ("replicas", Json::Arr(rows)),
            (
                "profile_generation",
                Json::Num(self.store.generation() as f64),
            ),
            (
                "stale_states_recovered",
                Json::Num(
                    self.metrics.counter_value("fleet_stale_states_recovered")
                        as f64,
                ),
            ),
        ])
    }

    /// Drain → wait idle → kill → respawn → wait healthy → undrain, for
    /// one replica. The heartbeat skips exactly this slot meanwhile.
    fn restart_one(&self, id: usize) -> Result<()> {
        let router = self.router_addr.clone();
        let to = Duration::from_secs(2);
        self.restarting.store(id, Ordering::SeqCst);
        let done = (|| -> Result<()> {
            roundtrip_line(
                &router,
                &format!(r#"{{"cmd":"drain","replica":{id}}}"#),
                to,
            )
            .context("draining at router")?;
            // Wait for in-flight work on the drained replica to finish.
            let deadline = Instant::now() + self.cfg.request_timeout;
            loop {
                let status = roundtrip_line(
                    &router,
                    r#"{"cmd":"fleet-status"}"#,
                    to,
                )?;
                let outstanding = status
                    .get("replicas")
                    .and_then(Json::as_arr)
                    .context("no replicas in router status")?
                    .iter()
                    .find(|r| {
                        r.get("id").and_then(Json::as_f64)
                            == Some(id as f64)
                    })
                    .and_then(|r| r.get("outstanding"))
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0);
                if outstanding == 0.0 {
                    break;
                }
                if Instant::now() > deadline {
                    bail!("replica {id} never went idle under drain");
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            // Kill and respawn on the same port.
            {
                let mut slots = self.slots.lock().unwrap();
                let slot = slots
                    .iter_mut()
                    .find(|s| s.id == id)
                    .with_context(|| format!("no replica {id}"))?;
                slot.fail_streak = 0;
                slot.backoff_exp = 0;
                slot.next_respawn_at = Instant::now();
                self.respawn_slot(slot, Instant::now());
            }
            // Wait for the replacement to serve pings.
            let addr = {
                let slots = self.slots.lock().unwrap();
                slots.iter().find(|s| s.id == id).unwrap().addr.clone()
            };
            let deadline = Instant::now() + self.cfg.request_timeout;
            while !probe_ping(&addr, to) {
                if Instant::now() > deadline {
                    bail!("replica {id} not healthy after restart");
                }
                std::thread::sleep(Duration::from_millis(20));
            }
            Ok(())
        })();
        // Always undrain and release the slot, even on failure.
        let _ = roundtrip_line(
            &router,
            &format!(r#"{{"cmd":"undrain","replica":{id}}}"#),
            to,
        );
        self.restarting.store(NO_RESTART, Ordering::SeqCst);
        done
    }

    /// Orchestrated rolling restart: every replica, one at a time.
    fn rolling_restart(&self) -> Result<usize> {
        self.metrics.add("fleet_rolling_restarts", 1);
        let ids: Vec<usize> = {
            let slots = self.slots.lock().unwrap();
            slots
                .iter()
                .filter(|s| s.id != ROUTER_SLOT)
                .map(|s| s.id)
                .collect()
        };
        for id in &ids {
            self.restart_one(*id)
                .with_context(|| format!("rolling restart of replica {id}"))?;
        }
        let _ = self.persist();
        Ok(ids.len())
    }
}

/// A running fleet supervisor. [`Supervisor::start`] spawns (or adopts)
/// the router and replicas, then heartbeats them until `shutdown`.
pub struct Supervisor {
    inner: Arc<Inner>,
    pub control_addr: String,
    pub router_addr: String,
    handles: Vec<std::thread::JoinHandle<()>>,
    shut_down: bool,
}

impl Supervisor {
    pub fn start(mut cfg: FleetConfig) -> Result<Supervisor> {
        std::fs::create_dir_all(&cfg.dir).with_context(|| {
            format!("creating fleet dir {}", cfg.dir.display())
        })?;
        // Startup stale-state detection (DESIGN.md §16).
        let metrics = Arc::new(metrics::Registry::new());
        let prior = match FleetState::staleness(&cfg.dir)? {
            StaleState::Live if !cfg.force => {
                let st = FleetState::load(&cfg.dir)?.unwrap();
                bail!(
                    "a supervisor (pid {}) is already running for {} — \
                     stop it first or pass --force",
                    st.supervisor_pid,
                    cfg.dir.display()
                );
            }
            StaleState::Live => FleetState::load(&cfg.dir)?,
            StaleState::Stale => {
                metrics.add("fleet_stale_states_recovered", 1);
                let st = FleetState::load(&cfg.dir)?;
                log::warn!(
                    "stale state.json (dead supervisor {}): probing {} \
                     recorded replicas for adoption",
                    st.as_ref().map(|s| s.supervisor_pid).unwrap_or(0),
                    st.as_ref().map(|s| s.replicas.len()).unwrap_or(0)
                );
                st
            }
            StaleState::Absent => None,
        };

        let store = ProfileStore::new(cfg.dir.join("profiles"))?;

        // Excess recorded replicas (a prior, larger fleet) are killed
        // rather than silently leaked.
        if let Some(st) = prior.as_ref() {
            for r in st.replicas.iter().filter(|r| r.id >= cfg.replicas) {
                if pid_alive(r.pid) {
                    log::warn!(
                        "killing surplus recorded replica {} (pid {})",
                        r.id,
                        r.pid
                    );
                    send_signal(r.pid, "KILL");
                }
            }
            // Reuse the recorded router address so a surviving router
            // can be adopted instead of orphaned on its old port.
            if !st.router_addr.is_empty() {
                cfg.router_addr = st.router_addr.clone();
            }
        }

        // Concretize port-0 addresses once; slots keep them forever.
        if cfg.router_addr.ends_with(":0") {
            cfg.router_addr = format!("127.0.0.1:{}", free_port()?);
        }
        let mut replica_addrs = Vec::with_capacity(cfg.replicas);
        for id in 0..cfg.replicas {
            let from_prior = prior
                .as_ref()
                .and_then(|st| st.replicas.iter().find(|r| r.id == id))
                .map(|r| r.addr.clone());
            match from_prior {
                Some(addr) => replica_addrs.push(addr),
                None => {
                    replica_addrs.push(format!("127.0.0.1:{}", free_port()?))
                }
            }
        }

        // Control socket binds first so `fleet start` can wait on it.
        let control = TcpListener::bind(&cfg.control_addr)
            .with_context(|| format!("binding {}", cfg.control_addr))?;
        let control_addr = control.local_addr()?.to_string();
        control.set_nonblocking(true)?;

        let inner = Arc::new(Inner {
            metrics,
            store,
            control_addr: control_addr.clone(),
            router_addr: cfg.router_addr.clone(),
            replica_addrs: replica_addrs.clone(),
            slots: Mutex::new(Vec::new()),
            rng: Mutex::new(Rng::new(cfg.seed ^ 0x5afe_f1ee)),
            restarting: AtomicUsize::new(NO_RESTART),
            stop: AtomicBool::new(false),
            cfg,
        });

        // Build slots: adopt live recorded processes, spawn the rest.
        {
            let probe_to = Duration::from_millis(250);
            let mut slots = Vec::new();
            for (id, addr) in replica_addrs.iter().enumerate() {
                let recorded = prior
                    .as_ref()
                    .and_then(|st| st.replicas.iter().find(|r| r.id == id));
                let adoptable = recorded
                    .map(|r| pid_alive(r.pid) && probe_ping(&r.addr, probe_to))
                    .unwrap_or(false);
                let mut slot = match (adoptable, recorded) {
                    (true, Some(r)) => {
                        log::info!(
                            "adopting live replica {id} (pid {}) on {}",
                            r.pid,
                            r.addr
                        );
                        Slot::adopted(id, r.addr.clone(), r.pid, r.respawns)
                    }
                    _ => Slot::adopted(id, addr.clone(), 0, 0),
                };
                if slot.pid == 0 {
                    let child = inner.spawn_slot_process(id, addr)?;
                    slot.pid = child.id();
                    slot.child = Some(child);
                }
                slots.push(slot);
            }
            // Router slot last; adopt it too when it survived.
            let router_adoptable = prior
                .as_ref()
                .map(|st| {
                    st.router_addr == inner.router_addr
                        && pid_alive(st.router_pid)
                        && probe_ping(&st.router_addr, probe_to)
                })
                .unwrap_or(false);
            let mut router_slot = if router_adoptable {
                let st = prior.as_ref().unwrap();
                log::info!(
                    "adopting live router (pid {}) on {}",
                    st.router_pid,
                    st.router_addr
                );
                Slot::adopted(
                    ROUTER_SLOT,
                    st.router_addr.clone(),
                    st.router_pid,
                    0,
                )
            } else {
                Slot::adopted(ROUTER_SLOT, inner.router_addr.clone(), 0, 0)
            };
            if router_slot.pid == 0 {
                let child = inner
                    .spawn_slot_process(ROUTER_SLOT, &inner.router_addr)?;
                router_slot.pid = child.id();
                router_slot.child = Some(child);
            }
            slots.push(router_slot);
            *inner.slots.lock().unwrap() = slots;
        }
        inner.persist()?;

        let mut handles = Vec::new();
        // Heartbeat thread.
        {
            let inn = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("osdt-fleet-heartbeat".into())
                    .spawn(move || {
                        while !inn.stop.load(Ordering::Relaxed) {
                            std::thread::sleep(inn.cfg.heartbeat);
                            if inn.stop.load(Ordering::Relaxed) {
                                break;
                            }
                            inn.tick();
                        }
                    })?,
            );
        }
        // Control socket thread.
        {
            let inn = inner.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("osdt-fleet-control".into())
                    .spawn(move || {
                        while !inn.stop.load(Ordering::Relaxed) {
                            match control.accept() {
                                Ok((stream, _)) => {
                                    let inn2 = inn.clone();
                                    let _ = std::thread::Builder::new()
                                        .name("osdt-fleet-ctl-conn".into())
                                        .spawn(move || {
                                            let _ =
                                                control_conn(stream, &inn2);
                                        });
                                }
                                Err(e)
                                    if e.kind()
                                        == std::io::ErrorKind::WouldBlock =>
                                {
                                    std::thread::sleep(Duration::from_millis(
                                        10,
                                    ));
                                }
                                Err(e) => {
                                    log::warn!("control accept error: {e}");
                                    break;
                                }
                            }
                        }
                    })?,
            );
        }

        Ok(Supervisor {
            control_addr,
            router_addr: inner.router_addr.clone(),
            inner,
            handles,
            shut_down: false,
        })
    }

    /// The supervisor's own metric registry (`fleet_respawns`,
    /// `fleet_stale_states_recovered`, `fleet_rolling_restarts`).
    pub fn metrics(&self) -> Arc<metrics::Registry> {
        self.inner.metrics.clone()
    }

    /// Block until every replica and the router answer pings, or the
    /// timeout elapses. Returns whether the fleet came up.
    pub fn wait_all_healthy(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let addrs: Vec<String> = {
            let slots = self.inner.slots.lock().unwrap();
            slots.iter().map(|s| s.addr.clone()).collect()
        };
        loop {
            let ok = addrs
                .iter()
                .all(|a| probe_ping(a, Duration::from_millis(250)));
            if ok {
                return true;
            }
            if Instant::now() > deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Drain/kill/respawn every replica, one at a time.
    pub fn rolling_restart(&self) -> Result<usize> {
        self.inner.rolling_restart()
    }

    /// True once `stop` was requested (control socket or [`Supervisor::stop`]).
    pub fn stopped(&self) -> bool {
        self.inner.stop.load(Ordering::Relaxed)
    }

    /// Request shutdown without tearing down (the run loop calls
    /// [`Supervisor::shutdown`] after this).
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
    }

    /// Stop supervision, kill every worker, reap, and remove
    /// `state.json` (clean shutdown — the next start is `Absent`).
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        if self.shut_down {
            return;
        }
        self.shut_down = true;
        self.inner.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut slots = self.inner.slots.lock().unwrap();
        for slot in slots.iter_mut() {
            self.inner.kill_slot(slot);
            slot.pid = 0;
        }
        drop(slots);
        let _ = FleetState::remove(&self.inner.cfg.dir);
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Panicking tests must not leak worker processes.
        self.teardown();
    }
}

/// Control-socket connection: JSON lines, one command per line.
fn control_conn(stream: TcpStream, inn: &Arc<Inner>) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match Json::parse(&line) {
            Err(e) => {
                Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))])
            }
            Ok(j) => match j.get("cmd").and_then(Json::as_str) {
                Some("ping") => Json::obj(vec![("pong", Json::Bool(true))]),
                Some("metrics") => Json::obj(vec![(
                    "metrics",
                    Json::Str(inn.metrics.render()),
                )]),
                Some("fleet-status") => inn.status_doc(),
                Some("rolling-restart") => match inn.rolling_restart() {
                    Ok(n) => {
                        Json::obj(vec![("restarted", Json::Num(n as f64))])
                    }
                    Err(e) => Json::obj(vec![(
                        "error",
                        Json::Str(format!("{e:#}")),
                    )]),
                },
                Some("stop") => {
                    inn.stop.store(true, Ordering::Relaxed);
                    Json::obj(vec![("stopping", Json::Bool(true))])
                }
                Some(other) => Json::obj(vec![(
                    "error",
                    Json::Str(format!("unknown cmd {other:?}")),
                )]),
                None => Json::obj(vec![(
                    "error",
                    Json::Str("control socket takes cmd objects".into()),
                )]),
            },
        };
        writeln!(writer, "{reply}")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_cmdline_shares_profile_store_and_enables_fleet_locks() {
        let cfg = FleetConfig {
            dir: PathBuf::from("/tmp/fleet-x"),
            sim_seed: 9,
            replica_args: vec!["--workers=2".into()],
            ..FleetConfig::default()
        };
        let args = replica_cmdline(&cfg, "127.0.0.1:7001");
        assert_eq!(args[0], "serve");
        assert!(args.contains(&"--addr=127.0.0.1:7001".to_string()));
        assert!(args.contains(&"--backend=sim".to_string()));
        assert!(args.contains(&"--sim-seed=9".to_string()));
        assert!(args
            .contains(&"--profile-dir=/tmp/fleet-x/profiles".to_string()));
        assert!(args.contains(&"--fleet-locks=on".to_string()));
        // Extra args ride along at the end.
        assert_eq!(args.last().unwrap(), "--workers=2");
    }

    #[test]
    fn router_cmdline_lists_every_replica_in_order() {
        let cfg = FleetConfig {
            max_retries: 5,
            heartbeat: Duration::from_millis(100),
            ..FleetConfig::default()
        };
        let args = router_cmdline(
            &cfg,
            "127.0.0.1:7000",
            &["127.0.0.1:7001".into(), "127.0.0.1:7002".into()],
        );
        assert_eq!(args[0], "serve-fleet");
        assert_eq!(args[1], "--addr=127.0.0.1:7000");
        assert_eq!(args[2], "--replica=127.0.0.1:7001");
        assert_eq!(args[3], "--replica=127.0.0.1:7002");
        assert!(args.contains(&"--health-interval-ms=100".to_string()));
        assert!(args.contains(&"--max-retries=5".to_string()));
    }

    #[test]
    fn respawn_backoff_doubles_and_caps_with_jitter() {
        let cfg = FleetConfig {
            respawn_base: Duration::from_millis(100),
            respawn_max: Duration::from_millis(400),
            ..FleetConfig::default()
        };
        let mut rng = Rng::new(3);
        for (exp, full_ms) in
            [(0u32, 100.0f64), (1, 200.0), (2, 400.0), (9, 400.0)]
        {
            let d = respawn_backoff(&cfg, exp, &mut rng).as_secs_f64() * 1e3;
            assert!(
                d >= full_ms / 2.0 - 1e-9 && d < full_ms + 1e-9,
                "exp {exp}: {d}ms outside [{}, {})",
                full_ms / 2.0,
                full_ms
            );
        }
    }

    #[test]
    fn second_supervisor_refuses_a_live_state_file() {
        let dir = std::env::temp_dir().join(format!(
            "osdt-sup-live-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // A state file naming our own (live) PID must refuse startup
        // before any process is spawned.
        let st = FleetState::new("127.0.0.1:1".into());
        st.save(&dir).unwrap();
        let err = Supervisor::start(FleetConfig {
            dir: dir.clone(),
            binary: PathBuf::from("/nonexistent-osdt"),
            ..FleetConfig::default()
        })
        .unwrap_err();
        assert!(err.to_string().contains("already running"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
