//! Model metadata: the Rust-side mirror of `artifacts/model_config.json`,
//! the single source of truth emitted by the python build (geometry, vocab,
//! special tokens, parameter order, HLO variant table).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One AOT-lowered HLO variant (e.g. `fwd_conf_b1`).
#[derive(Clone, Debug)]
pub struct VariantInfo {
    pub name: String,
    pub file: String,
    pub batch: usize,
}

/// Parsed model configuration.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub block_len: usize,
    pub num_blocks: usize,
    pub pad_id: u32,
    pub mask_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    /// id -> surface form (specials keep their bracket names)
    pub vocab: Vec<String>,
    /// frozen flattening order of weight tensors
    pub param_order: Vec<String>,
    pub variants: BTreeMap<String, VariantInfo>,
    pub weights_file: String,
    /// directory the config was loaded from (artifact root)
    pub artifact_dir: PathBuf,
}

impl ModelConfig {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref();
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing model_config.json")?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let us = |k: &str| -> Result<usize> {
            j.req(k)
                .and_then(|v| v.as_usize().ok_or_else(|| format!("{k} not usize")))
                .map_err(anyhow::Error::msg)
        };
        let u32f = |k: &str| -> Result<u32> {
            j.req(k)
                .and_then(|v| v.as_u32().ok_or_else(|| format!("{k} not u32")))
                .map_err(anyhow::Error::msg)
        };
        let strs = |k: &str| -> Result<Vec<String>> {
            j.req(k)
                .map_err(anyhow::Error::msg)?
                .as_arr()
                .context(format!("{k} not array"))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .context(format!("{k} element not string"))
                })
                .collect()
        };

        let mut variants = BTreeMap::new();
        let vobj = j
            .req("variants")
            .map_err(anyhow::Error::msg)?
            .as_obj()
            .context("variants not object")?;
        for (name, v) in vobj {
            variants.insert(
                name.clone(),
                VariantInfo {
                    name: name.clone(),
                    file: v
                        .req("file")
                        .map_err(anyhow::Error::msg)?
                        .as_str()
                        .context("variant file not string")?
                        .to_string(),
                    batch: v
                        .req("batch")
                        .map_err(anyhow::Error::msg)?
                        .as_usize()
                        .context("variant batch not usize")?,
                },
            );
        }

        let cfg = ModelConfig {
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            head_dim: us("head_dim")?,
            d_ff: us("d_ff")?,
            vocab_size: us("vocab_size")?,
            seq_len: us("seq_len")?,
            prompt_len: us("prompt_len")?,
            gen_len: us("gen_len")?,
            block_len: us("block_len")?,
            num_blocks: us("num_blocks")?,
            pad_id: u32f("pad_id")?,
            mask_id: u32f("mask_id")?,
            bos_id: u32f("bos_id")?,
            eos_id: u32f("eos_id")?,
            vocab: strs("vocab")?,
            param_order: strs("param_order")?,
            variants,
            weights_file: j
                .req("weights_file")
                .map_err(anyhow::Error::msg)?
                .as_str()
                .context("weights_file not string")?
                .to_string(),
            artifact_dir: dir.to_path_buf(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.vocab.len() != self.vocab_size {
            bail!(
                "vocab table len {} != vocab_size {}",
                self.vocab.len(),
                self.vocab_size
            );
        }
        if self.prompt_len + self.gen_len != self.seq_len {
            bail!("prompt_len + gen_len != seq_len");
        }
        if self.block_len * self.num_blocks != self.gen_len {
            bail!("block_len * num_blocks != gen_len");
        }
        if self.d_model != self.n_heads * self.head_dim {
            bail!("d_model != n_heads * head_dim");
        }
        for id in [self.pad_id, self.mask_id, self.bos_id, self.eos_id] {
            if id as usize >= self.vocab_size {
                bail!("special id {id} out of vocab");
            }
        }
        Ok(())
    }

    /// Gen-region index range [prompt_len, seq_len).
    pub fn gen_range(&self) -> std::ops::Range<usize> {
        self.prompt_len..self.seq_len
    }

    /// Absolute index range of gen block `b`.
    pub fn block_range(&self, b: usize) -> std::ops::Range<usize> {
        assert!(b < self.num_blocks, "block {b} out of range");
        let start = self.prompt_len + b * self.block_len;
        start..start + self.block_len
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .get(name)
            .with_context(|| format!("variant '{name}' not in model_config.json"))
    }

    pub fn hlo_path(&self, v: &VariantInfo) -> PathBuf {
        self.artifact_dir.join(&v.file)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.artifact_dir.join(&self.weights_file)
    }
}

pub mod fixtures {
    use super::*;

    /// In-memory config mirroring the python geometry — used by unit tests
    /// and by the analytic simulator (`sim::SimModel`), neither of which
    /// needs built artifacts.
    pub fn tiny_config() -> ModelConfig {
        let mut vocab: Vec<String> = ["[PAD]", "[MASK]", "[BOS]", "[EOS]"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let chars = "abcdefghijklmnopqrstuvwxyz\
                     ABCDEFGHIJKLMNOPQRSTUVWXYZ\
                     0123456789 .,:;?!#+-*/=()<>'\"_|";
        vocab.extend(chars.chars().map(|c| c.to_string()));
        ModelConfig {
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            head_dim: 16,
            d_ff: 256,
            vocab_size: vocab.len(),
            seq_len: 160,
            prompt_len: 64,
            gen_len: 96,
            block_len: 32,
            num_blocks: 3,
            pad_id: 0,
            mask_id: 1,
            bos_id: 2,
            eos_id: 3,
            vocab,
            param_order: vec![],
            variants: BTreeMap::new(),
            weights_file: "weights.bin".into(),
            artifact_dir: PathBuf::from("artifacts"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::fixtures::tiny_config;
    use super::*;

    #[test]
    fn tiny_config_valid() {
        tiny_config().validate().unwrap();
    }

    #[test]
    fn block_ranges_tile_gen_region() {
        let cfg = tiny_config();
        let mut covered = vec![];
        for b in 0..cfg.num_blocks {
            covered.extend(cfg.block_range(b));
        }
        assert_eq!(covered, cfg.gen_range().collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn block_out_of_range_panics() {
        tiny_config().block_range(3);
    }

    #[test]
    fn from_json_roundtrip() {
        let text = r#"{
            "d_model": 8, "n_layers": 1, "n_heads": 2, "head_dim": 4,
            "d_ff": 16, "vocab_size": 5, "seq_len": 12, "prompt_len": 4,
            "gen_len": 8, "block_len": 4, "num_blocks": 2,
            "pad_id": 0, "mask_id": 1, "bos_id": 2, "eos_id": 3,
            "vocab": ["[PAD]","[MASK]","[BOS]","[EOS]","a"],
            "param_order": ["w"],
            "variants": {"fwd_conf_b1": {"file": "f.hlo.txt", "batch": 1}},
            "weights_file": "weights.bin"
        }"#;
        let j = Json::parse(text).unwrap();
        let cfg = ModelConfig::from_json(&j, Path::new("/tmp/x")).unwrap();
        assert_eq!(cfg.variant("fwd_conf_b1").unwrap().batch, 1);
        assert!(cfg.variant("nope").is_err());
        assert_eq!(cfg.hlo_path(cfg.variant("fwd_conf_b1").unwrap()),
                   PathBuf::from("/tmp/x/f.hlo.txt"));
    }

    #[test]
    fn invalid_config_rejected() {
        let mut cfg = tiny_config();
        cfg.gen_len = 95; // breaks both sums
        assert!(cfg.validate().is_err());
    }
}
