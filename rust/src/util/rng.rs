//! Deterministic PRNG substrate.
//!
//! The offline registry has no `rand` crate, so we implement the generators
//! we need: SplitMix64 for seeding and xoshiro256** for the main stream.
//! Every stochastic component in the coordinator (workload generation, the
//! confidence simulator, property tests) takes an explicit `Rng` so runs are
//! reproducible from a single seed.

/// SplitMix64 — used to expand a single u64 seed into xoshiro state.
/// Reference: Steele, Lea, Flood (2014); the standard public-domain stepper.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (guarantees a non-zero state for any seed).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-request / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller (no caching of the second value —
    /// simplicity over speed; not on any hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with given rate (inter-arrival times for open-loop load).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        // chi-square-lite: each bucket of below(10) within 20% of expected
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - n as f64 / 10.0).abs() < n as f64 / 10.0 * 0.2);
        }
    }

    #[test]
    fn below_covers_bounds() {
        let mut r = Rng::new(9);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn range_inclusive_hits_both_ends() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match r.range_inclusive(-3, 3) {
                -3 => lo_seen = true,
                3 => hi_seen = true,
                x => assert!((-3..=3).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(100);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
