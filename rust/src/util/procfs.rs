//! Minimal `/proc`-based process introspection plus a signal helper.
//!
//! The vendored registry has no `libc`/`nix`, so liveness checks read
//! `/proc/<pid>/stat` directly and signals go through the external
//! `kill(1)` binary — both are fine for the supervisor's control plane,
//! which operates on human-scale timescales (heartbeats, restarts).

use std::process::Command;

/// True iff `pid` names a live, non-zombie process.
///
/// Parses the state character from `/proc/<pid>/stat`. The comm field is
/// parenthesised and may itself contain spaces or parentheses, so the
/// state char is located after the *last* `)` in the line. A zombie
/// (`Z`) has exited and only awaits reaping — for supervision purposes
/// it is dead.
pub fn pid_alive(pid: u32) -> bool {
    if pid == 0 {
        return false;
    }
    let stat = match std::fs::read_to_string(format!("/proc/{pid}/stat")) {
        Ok(s) => s,
        Err(_) => return false,
    };
    match stat.rsplit(')').next().and_then(|rest| {
        rest.split_whitespace().next().and_then(|s| s.chars().next())
    }) {
        Some('Z') => false,
        Some(_) => true,
        None => false,
    }
}

/// Send `signal` (a `kill(1)` name or number, e.g. "TERM", "KILL", "9")
/// to `pid`. Returns true if the signal was delivered (the process
/// existed and we had permission).
pub fn send_signal(pid: u32, signal: &str) -> bool {
    Command::new("kill")
        .arg(format!("-{signal}"))
        .arg(pid.to_string())
        .status()
        .map(|s| s.success())
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn own_pid_is_alive() {
        assert!(pid_alive(std::process::id()));
    }

    #[test]
    fn pid_zero_and_absurd_pid_are_dead() {
        assert!(!pid_alive(0));
        // PIDs are bounded by /proc/sys/kernel/pid_max (<= 2^22 by
        // default); u32::MAX cannot name a live process.
        assert!(!pid_alive(u32::MAX));
    }

    #[test]
    fn signal_zero_probes_liveness() {
        assert!(send_signal(std::process::id(), "0"));
        assert!(!send_signal(u32::MAX, "0"));
    }

    #[test]
    fn dead_child_is_not_alive_after_reap() {
        let mut child = Command::new("true").spawn().unwrap();
        let pid = child.id();
        child.wait().unwrap();
        assert!(!pid_alive(pid));
    }
}
