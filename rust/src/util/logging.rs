//! Tiny `log` facade backend writing to stderr with wall-clock offsets.
//! (The registry has no env_logger; this is the whole backend.)

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:9.3}s {:5} {}] {}",
                START.elapsed().as_secs_f64(),
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops. Level comes from
/// `OSDT_LOG` (error|warn|info|debug|trace), default info.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    Lazy::force(&START);
    let level = match std::env::var("OSDT_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { level }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
