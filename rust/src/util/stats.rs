//! Statistics substrate: descriptive stats, quantiles (the paper's threshold
//! metrics μ), cosine similarity (Figure 2), and streaming histograms for
//! latency/throughput metrics.

/// Descriptive statistics over a slice. Quantiles use the nearest-rank
/// linear-interpolation convention (numpy default), which is what the
/// paper's box-plot metrics (Q1/median/Q3/min-whisker) are defined against.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Option<Summary> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        q1: quantile_sorted(&sorted, 0.25),
        median: quantile_sorted(&sorted, 0.5),
        q3: quantile_sorted(&sorted, 0.75),
        max: sorted[n - 1],
    })
}

impl Summary {
    /// Tukey lower whisker: smallest observation >= Q1 - 1.5*IQR.
    /// This is the paper's "min-whisker" threshold metric.
    pub fn min_whisker(&self, sorted: &[f64]) -> f64 {
        let fence = self.q1 - 1.5 * (self.q3 - self.q1);
        sorted
            .iter()
            .copied()
            .find(|&x| x >= fence)
            .unwrap_or(self.min)
    }
}

/// Linear-interpolation quantile over an ascending-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let h = q * (sorted.len() - 1) as f64;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (h - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Cosine similarity between two equal-length vectors; None if either has
/// zero norm or lengths differ.
pub fn cosine(a: &[f64], b: &[f64]) -> Option<f64> {
    if a.len() != b.len() || a.is_empty() {
        return None;
    }
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return None;
    }
    Some(dot / (na * nb))
}

/// Fixed-bound log-bucketed histogram for latencies (microseconds).
/// Lock-free-enough for our use: owned per-thread or behind a Mutex.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i covers [lo * g^i, lo * g^(i+1))
    counts: Vec<u64>,
    lo: f64,
    growth: f64,
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Histogram {
    /// Covers [lo_us, hi_us] with ~`buckets` log-spaced buckets.
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && buckets >= 2);
        let growth = (hi / lo).powf(1.0 / buckets as f64);
        Histogram {
            counts: vec![0; buckets + 2], // +underflow +overflow
            lo,
            growth,
            n: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            min: f64::INFINITY,
        }
    }

    /// Default latency histogram: 1us .. 100s, ~1.5% resolution.
    pub fn latency() -> Self {
        Histogram::new(1.0, 1e8, 1200)
    }

    pub fn record(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.max = self.max.max(x);
        self.min = self.min.min(x);
        let idx = if x < self.lo {
            0
        } else {
            let i = ((x / self.lo).ln() / self.growth.ln()).floor() as usize + 1;
            i.min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    /// Approximate quantile from bucket midpoints (exact at bucket
    /// resolution; clamped by observed min/max).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        let target = (q * self.n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                let est = if i == 0 {
                    self.lo
                } else {
                    self.lo * self.growth.powf(i as f64 - 0.5)
                };
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Cumulative counts at fixed ascending upper bounds — the raw
    /// material of a Prometheus `_bucket{le=...}` series. Each internal
    /// log bucket is attributed to the first bound at or above its upper
    /// edge; observations above the last bound land only in the implicit
    /// `+Inf` bucket (which is `self.n`, rendered by the caller). The
    /// result is monotone non-decreasing by construction.
    pub fn cumulative_le(&self, bounds: &[f64]) -> Vec<u64> {
        let mut out = vec![0u64; bounds.len()];
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            // bucket 0 is the underflow [0, lo); bucket i >= 1 covers
            // [lo·g^(i-1), lo·g^i); the last bucket is the overflow
            let upper = if i + 1 == self.counts.len() {
                f64::INFINITY
            } else {
                self.lo * self.growth.powi(i as i32)
            };
            for (j, &b) in bounds.iter().enumerate() {
                if upper <= b * (1.0 + 1e-9) {
                    out[j] += c;
                    break;
                }
            }
        }
        for j in 1..out.len() {
            out[j] += out[j - 1];
        }
        out
    }

    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert_close(s.mean, 3.0, 1e-12);
        assert_close(s.median, 3.0, 1e-12);
        assert_close(s.q1, 2.0, 1e-12);
        assert_close(s.q3, 4.0, 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty() {
        assert!(summarize(&[]).is_none());
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert_close(quantile_sorted(&xs, 0.5), 5.0, 1e-12);
        assert_close(quantile_sorted(&xs, 0.25), 2.5, 1e-12);
        assert_eq!(quantile_sorted(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn min_whisker_excludes_outliers() {
        // bulk at 0.8..1.0 with one extreme outlier at 0.01
        let mut xs: Vec<f64> = (0..20).map(|i| 0.8 + 0.01 * i as f64).collect();
        xs.push(0.01);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let s = summarize(&xs).unwrap();
        let w = s.min_whisker(&xs);
        assert!(w >= 0.8, "whisker {w} should skip the outlier");
        assert!(w <= s.q1);
    }

    #[test]
    fn min_whisker_equals_min_when_no_outliers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let s = summarize(&xs).unwrap();
        assert_eq!(s.min_whisker(&xs), 1.0);
    }

    #[test]
    fn cosine_cases() {
        assert_close(cosine(&[1.0, 0.0], &[1.0, 0.0]).unwrap(), 1.0, 1e-12);
        assert_close(cosine(&[1.0, 0.0], &[0.0, 1.0]).unwrap(), 0.0, 1e-12);
        assert_close(cosine(&[1.0, 2.0], &[-1.0, -2.0]).unwrap(), -1.0, 1e-12);
        assert!(cosine(&[0.0], &[1.0]).is_none());
        assert!(cosine(&[1.0], &[1.0, 2.0]).is_none());
        assert!(cosine(&[], &[]).is_none());
    }

    #[test]
    fn histogram_quantiles_are_sane() {
        let mut h = Histogram::latency();
        for i in 1..=10_000u64 {
            h.record(i as f64); // 1..10000 us uniform
        }
        assert_eq!(h.n, 10_000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 / 5000.0 - 1.0).abs() < 0.1, "p50 {p50}");
        assert!((p99 / 9900.0 - 1.0).abs() < 0.1, "p99 {p99}");
        assert_close(h.mean(), 5000.5, 1.0);
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(10.0, 100.0, 4);
        h.record(1.0); // underflow
        h.record(1e9); // overflow
        assert_eq!(h.n, 2);
        assert!(h.quantile(0.01) >= 1.0);
        assert!(h.quantile(0.99) <= 1e9);
    }

    #[test]
    fn cumulative_le_buckets() {
        // growth 10: buckets [0,1), [1,10), [10,100), [100,1000), overflow
        let mut h = Histogram::new(1.0, 1000.0, 3);
        h.record(5.0); // [1,10), upper edge 10
        h.record(0.5); // underflow, upper edge 1
        h.record(5e6); // overflow, upper edge +inf
        let cum = h.cumulative_le(&[10.0, 1000.0]);
        assert_eq!(cum, vec![2, 2], "overflow only reaches +Inf");
        assert_eq!(h.n, 3);
        // monotone even with interleaved empty bounds
        let cum = h.cumulative_le(&[0.1, 1.0, 10.0, 1e9]);
        assert_eq!(cum, vec![0, 1, 2, 2]);
        // no bounds -> empty
        assert!(h.cumulative_le(&[]).is_empty());
    }

    #[test]
    fn cumulative_le_is_monotone_under_load() {
        let mut h = Histogram::latency();
        for i in 1..=5000u64 {
            h.record(i as f64 * 37.0);
        }
        let bounds = [100.0, 1000.0, 10_000.0, 100_000.0, 1e6];
        let cum = h.cumulative_le(&bounds);
        for w in cum.windows(2) {
            assert!(w[1] >= w[0], "{cum:?}");
        }
        assert!(*cum.last().unwrap() <= h.n);
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        for i in 0..100 {
            a.record(100.0 + i as f64);
            b.record(10_000.0 + i as f64);
        }
        a.merge(&b);
        assert_eq!(a.n, 200);
        assert!(a.quantile(0.25) < 1000.0);
        assert!(a.quantile(0.75) > 5000.0);
    }
}
