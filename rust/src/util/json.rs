//! Minimal JSON substrate (the offline registry has no serde).
//!
//! Covers everything this repo needs: parsing `model_config.json`, the
//! eval JSONL datasets emitted by the python build, OSDT calibration
//! profiles, and the TCP wire protocol. Full RFC 8259 value model with
//! `\uXXXX` escapes (incl. surrogate pairs); numbers are f64 (all our
//! payloads fit comfortably).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (stable golden tests, reproducible profile files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---- accessors (ergonomic, fallible) ----------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_usize().and_then(|x| u32::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no spaces) — the wire/file format.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(true) => f.write_str("true"),
            Json::Bool(false) => f.write_str("false"),
            Json::Num(x) => write_num(f, *x),
            Json::Str(s) => write_str(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_str(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_num(f: &mut fmt::Formatter<'_>, x: f64) -> fmt::Result {
    if !x.is_finite() {
        // JSON has no Inf/NaN; emit null (we never serialize these on
        // purpose, but a metric can legitimately be NaN before warmup).
        return f.write_str("null");
    }
    if x.fract() == 0.0 && x.abs() < 9.007_199_254_740_992e15 {
        write!(f, "{}", x as i64)
    } else {
        // 17 significant digits round-trips every f64
        let s = format!("{x:e}");
        if s.contains('e') && !s.contains("e-") && !s.contains("e+") {
            // rust's {:e} gives e.g. 1.5e3; JSON accepts it, keep simple:
            write!(f, "{x}")
        } else {
            write!(f, "{x}")
        }
    }
}

fn write_str(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.eat(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("bad low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-assemble UTF-8 multibyte sequences byte-wise
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

/// Parse one JSONL file into values (skipping blank lines).
pub fn parse_jsonl(text: &str) -> Result<Vec<Json>, JsonError> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(Json::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A 😀 é");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":1,"b":[true,null,"x"],"c":{"d":-2.5}}"#,
            r#"[1,2,3]"#,
            r#""quote \" backslash \\""#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "roundtrip {c}");
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(-0.125).to_string(), "-0.125");
    }

    #[test]
    fn f64_roundtrip_precision() {
        for x in [0.1, 1.0 / 3.0, 1e-17, 123456.789012345, f64::MAX] {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "{x} -> {s}");
        }
    }

    #[test]
    fn object_order_is_deterministic() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn jsonl() {
        let v = parse_jsonl("{\"a\":1}\n\n{\"a\":2}\n").unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v[1].get("a").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n":7,"s":"x","b":true,"a":[]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.req("n").unwrap().as_u32().unwrap(), 7);
        assert!(v.req("missing").is_err());
        assert!(v.get("s").unwrap().as_f64().is_none());
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
