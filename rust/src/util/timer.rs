//! Micro-timing helpers for the perf pass and the bench harness.

use std::time::{Duration, Instant};

/// Measure a closure, returning (result, elapsed).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// A stopwatch accumulating named segments — used to attribute step time
/// between forward-pass execution and coordinator overhead in §Perf.
#[derive(Debug, Default)]
pub struct SegmentTimer {
    segments: Vec<(String, Duration)>,
}

impl SegmentTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    pub fn add(&mut self, name: &str, d: Duration) {
        if let Some((_, acc)) = self.segments.iter_mut().find(|(n, _)| n == name) {
            *acc += d;
        } else {
            self.segments.push((name.to_string(), d));
        }
    }

    pub fn total(&self) -> Duration {
        self.segments.iter().map(|(_, d)| *d).sum()
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        self.segments
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| *d)
    }

    /// "name: 12.3ms (45.6%)" lines, largest first.
    pub fn report(&self) -> String {
        let total = self.total().as_secs_f64().max(1e-12);
        let mut rows: Vec<_> = self.segments.iter().collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1));
        rows.iter()
            .map(|(n, d)| {
                format!(
                    "{n}: {:.3}ms ({:.1}%)",
                    d.as_secs_f64() * 1e3,
                    d.as_secs_f64() / total * 100.0
                )
            })
            .collect::<Vec<_>>()
            .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_segments() {
        let mut t = SegmentTimer::new();
        t.add("a", Duration::from_millis(10));
        t.add("a", Duration::from_millis(5));
        t.add("b", Duration::from_millis(1));
        assert_eq!(t.get("a").unwrap(), Duration::from_millis(15));
        assert_eq!(t.total(), Duration::from_millis(16));
        let rep = t.report();
        assert!(rep.starts_with("a:"), "{rep}");
    }

    #[test]
    fn time_it_returns_value() {
        let (v, d) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }
}
