//! Foundation substrates built from scratch for the offline environment
//! (no serde / rand / proptest / criterion in the vendored registry):
//! PRNG, JSON, statistics, property testing, logging, timing.

pub mod json;
pub mod logging;
pub mod procfs;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
