//! Property-testing substrate (the offline registry has no proptest).
//!
//! Deterministic: each case derives from a fixed master seed, and failures
//! report the per-case seed so a counterexample reproduces exactly with
//! `forall_seeded`. Includes a simple greedy shrinker for cases generated
//! through `Shrinkable` generators.

use super::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the case seed
/// and Debug form of the failing input.
pub fn forall<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    forall_seeded(name, 0xD1F5_u64, cases, gen, prop)
}

/// As `forall` with an explicit master seed (use the seed printed by a
/// failure to replay it).
pub fn forall_seeded<T, G, P>(name: &str, master: u64, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = master
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Run a property over inputs with greedy shrinking: on failure, repeatedly
/// try the candidates from `shrink` until none fails, then report the local
/// minimum.
pub fn forall_shrink<T, G, S, P>(name: &str, cases: usize, gen: G, shrink: S, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Fn(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xBEEF_u64
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // greedy descent
            let mut cur = input;
            let mut msg = first_msg;
            'outer: loop {
                for cand in shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x})\n  \
                 shrunk input: {cur:?}\n  error: {msg}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators
// ---------------------------------------------------------------------------

/// Vec of f64 in [lo, hi) with length in [min_len, max_len].
pub fn gen_f64_vec(
    rng: &mut Rng,
    min_len: usize,
    max_len: usize,
    lo: f64,
    hi: f64,
) -> Vec<f64> {
    let n = rng.range_inclusive(min_len as i64, max_len as i64) as usize;
    (0..n).map(|_| lo + rng.next_f64() * (hi - lo)).collect()
}

/// Shrink a vec by halving length and zeroing elements.
pub fn shrink_vec<T: Clone + Default>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if !v.is_empty() {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[1..].to_vec());
        let mut z = v.to_vec();
        z[0] = T::default();
        if v.len() > 1 {
            out.push(z);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("sum-commutes", 64, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        forall("always-fails", 8, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk input: []")]
    fn shrinker_reaches_minimal_case() {
        // fails for every vec (incl. empty) -> shrinker must reach []
        forall_shrink(
            "shrinks-to-empty",
            4,
            |r| gen_f64_vec(r, 3, 10, 0.0, 1.0),
            |v| shrink_vec(v),
            |_| Err("always".into()),
        );
    }

    #[test]
    fn generators_respect_bounds() {
        forall("gen-bounds", 64, |r| gen_f64_vec(r, 2, 5, -1.0, 1.0), |v| {
            if v.len() < 2 || v.len() > 5 {
                return Err(format!("len {}", v.len()));
            }
            if v.iter().any(|x| !(-1.0..1.0).contains(x)) {
                return Err("out of range".into());
            }
            Ok(())
        });
    }
}
