//! Evaluation substrate: task-specific answer extraction + scoring, and the
//! string-transform interpreter that makes `synth-code` a *functional*
//! benchmark (HumanEval's pass@1 contract: the generated output is judged
//! by execution semantics, not string match against a reference).

use anyhow::{bail, Result};

use crate::workload::Example;

/// The reference interpreter for synth-code programs — semantics identical
/// to `python/compile/data.py::run_code_op` (cross-checked by tests against
/// shared fixtures).
pub fn run_code_op(op: &str, s: &str) -> Result<String> {
    Ok(match op {
        "rev" => s.chars().rev().collect(),
        "dup" => s.chars().flat_map(|c| [c, c]).collect(),
        "rot1" => s
            .chars()
            .map(|c| {
                if c.is_ascii_lowercase() {
                    (((c as u8 - b'a' + 1) % 26) + b'a') as char
                } else {
                    c
                }
            })
            .collect(),
        "swap" => {
            let mut v: Vec<char> = s.chars().collect();
            let mut i = 0;
            while i + 1 < v.len() {
                v.swap(i, i + 1);
                i += 2;
            }
            v.into_iter().collect()
        }
        "drop2" => s.chars().step_by(2).collect(),
        _ => bail!("unknown op {op:?}"),
    })
}

/// Extract the final answer from a generated completion, per task:
/// - synth-qa / synth-math: the token after the last `####` marker;
/// - synth-code: the text after `out:` (trimmed at whitespace-end).
pub fn extract_answer(task: &str, completion: &str) -> Option<String> {
    match task {
        "synth-qa" | "synth-math" => {
            let idx = completion.rfind("####")?;
            let tail = completion[idx + 4..].trim();
            let ans: String = tail
                .chars()
                .take_while(|c| !c.is_whitespace())
                .collect();
            (!ans.is_empty()).then_some(ans)
        }
        "synth-code" => {
            let idx = completion.rfind("out:")?;
            let tail = completion[idx + 4..].trim();
            let ans: String = tail
                .chars()
                .take_while(|c| c.is_ascii_lowercase())
                .collect();
            (!ans.is_empty()).then_some(ans)
        }
        _ => None,
    }
}

/// Score one generated completion against its example.
/// synth-code is judged *functionally*: the extracted output must equal the
/// interpreter's result on the prompt's (op, input).
pub fn is_correct(ex: &Example, completion: &str) -> bool {
    let Some(got) = extract_answer(&ex.task, completion) else {
        return false;
    };
    match &ex.code_op {
        Some((op, input)) => match run_code_op(op, input) {
            Ok(expected) => got == expected,
            Err(_) => false,
        },
        None => got == ex.answer,
    }
}

/// Accuracy aggregation over a run.
#[derive(Clone, Debug, Default)]
pub struct EvalStats {
    pub total: usize,
    pub correct: usize,
    /// completions with no extractable answer (format failure)
    pub malformed: usize,
}

impl EvalStats {
    pub fn record(&mut self, ex: &Example, completion: &str) {
        self.total += 1;
        if extract_answer(&ex.task, completion).is_none() {
            self.malformed += 1;
        }
        if is_correct(ex, completion) {
            self.correct += 1;
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(task: &str, answer: &str, code: Option<(&str, &str)>) -> Example {
        Example {
            task: task.into(),
            prompt: String::new(),
            answer: answer.into(),
            code_op: code.map(|(a, b)| (a.into(), b.into())),
        }
    }

    #[test]
    fn code_ops_match_python_semantics() {
        // fixtures generated from python data.run_code_op
        let cases = [
            ("rev", "abcdef", "fedcba"),
            ("dup", "abc", "aabbcc"),
            ("rot1", "azb", "bac"),
            ("swap", "abcde", "badce"),
            ("drop2", "abcdef", "ace"),
            ("rev", "a", "a"),
            ("swap", "ab", "ba"),
            ("drop2", "a", "a"),
            ("rot1", "zzz", "aaa"),
        ];
        for (op, inp, want) in cases {
            assert_eq!(run_code_op(op, inp).unwrap(), want, "{op}({inp})");
        }
        assert!(run_code_op("nope", "x").is_err());
    }

    #[test]
    fn extract_math_and_qa() {
        assert_eq!(
            extract_answer("synth-math", "A: 3+4=7. #### 7").as_deref(),
            Some("7")
        );
        assert_eq!(
            extract_answer("synth-qa", "A: (C) dax #### C").as_deref(),
            Some("C")
        );
        // last marker wins
        assert_eq!(
            extract_answer("synth-math", "#### 3 junk #### 12").as_deref(),
            Some("12")
        );
        assert_eq!(extract_answer("synth-math", "no marker"), None);
        assert_eq!(extract_answer("synth-math", "#### "), None);
    }

    #[test]
    fn extract_code() {
        assert_eq!(
            extract_answer("synth-code", "out: fedcba").as_deref(),
            Some("fedcba")
        );
        assert_eq!(
            extract_answer("synth-code", "out: abc  extra").as_deref(),
            Some("abc")
        );
        assert_eq!(extract_answer("synth-code", "nothing"), None);
    }

    #[test]
    fn code_judged_functionally_not_textually() {
        // even if the dataset's recorded answer were wrong, execution wins
        let mut e = ex("synth-code", "WRONG", Some(("rev", "ab")));
        assert!(is_correct(&e, "out: ba"));
        assert!(!is_correct(&e, "out: ab"));
        e.code_op = Some(("dup".into(), "xy".into()));
        assert!(is_correct(&e, "out: xxyy"));
    }

    #[test]
    fn qa_math_exact_match() {
        let m = ex("synth-math", "56", None);
        assert!(is_correct(&m, "A: steps. #### 56"));
        assert!(!is_correct(&m, "A: steps. #### 57"));
        let q = ex("synth-qa", "B", None);
        assert!(is_correct(&q, "A: (B) rok #### B"));
        assert!(!is_correct(&q, "A: (B) rok #### D"));
    }

    #[test]
    fn stats_aggregate() {
        let mut st = EvalStats::default();
        let m = ex("synth-math", "5", None);
        st.record(&m, "#### 5");
        st.record(&m, "#### 6");
        st.record(&m, "garbage");
        assert_eq!(st.total, 3);
        assert_eq!(st.correct, 1);
        assert_eq!(st.malformed, 1);
        assert!((st.accuracy() - 1.0 / 3.0).abs() < 1e-12);
    }
}
