//! # OSDT — One-Shot Dynamic Thresholding for Diffusion Language Models
//!
//! A serving stack for masked diffusion language models (MDLM) reproducing
//! *"Beyond Static Cutoffs: One-Shot Dynamic Thresholding for Diffusion
//! Language Models"* (Shen & Ro, 2025).
//!
//! Three-layer architecture (see DESIGN.md):
//! - **L3 (this crate)**: the coordinator — decode engine, threshold
//!   policies (OSDT + Fast-dLLM baselines), dual KV-cache manager,
//!   continuous batcher, TCP server, workload generation, evaluation,
//!   metrics.
//! - **L2/L1 (python/, build-time only)**: the JAX mask predictor with
//!   Pallas kernels, AOT-lowered to HLO text artifacts loaded here via
//!   PJRT. Python never runs on the request path.
//!
//! Quick start (after `make artifacts`):
//! ```no_run
//! use osdt::model::ModelConfig;
//! use osdt::runtime::ModelRuntime;
//! use osdt::decode::Engine;
//! use osdt::policy::StaticThreshold;
//! use osdt::tokenizer::Tokenizer;
//!
//! let cfg = ModelConfig::load("artifacts").unwrap();
//! let rt = ModelRuntime::load(&cfg).unwrap();
//! let tok = Tokenizer::from_config(&cfg).unwrap();
//! let engine = Engine::new(&rt);
//! let layout = tok.layout_prompt(&cfg, "Q: 3+4=?").unwrap();
//! let out = engine.decode(layout, &StaticThreshold::new(0.9)).unwrap();
//! println!("{}", tok.decode_until_eos(out.gen_tokens(&cfg)));
//! ```

pub mod bench;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod decode;
pub mod eval;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tokenizer;
pub mod util;
pub mod workload;
