//! PJRT runtime: loads the AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client, keeps the model weights resident as device buffers, and
//! exposes typed forward-pass entry points to the decode engine.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> `HloModuleProto`
//! -> `XlaComputation` -> `client.compile`. All execution goes through
//! `execute_b` (device buffers) so weights are uploaded exactly once.
//!
//! One `ModelRuntime` is *not* Sync; each engine worker thread owns its own
//! (the PJRT CPU client is cheap and executables compile in milliseconds).

pub mod weights;

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::model::ModelConfig;
use weights::Tensor;

/// Forward-pass result for a batch: per-sequence confidence and candidate
/// token arrays over the full sequence (or window).
#[derive(Clone, Debug)]
pub struct ConfOut {
    pub conf: Vec<Vec<f32>>,
    pub argmax: Vec<Vec<u32>>,
}

/// Host-side copy of the dual KV cache (layers, heads, seq, head_dim) —
/// opaque to callers; produced by `fwd_full_kv`, consumed by `fwd_window`.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub dims: [usize; 4],
}

/// Counters the perf pass and benches read.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub fwd_calls: u64,
    pub fwd_full_kv_calls: u64,
    pub fwd_window_calls: u64,
    pub exec_micros: u64,
    pub transfer_micros: u64,
}

/// Reusable host-side staging buffers for the batched window pass. The
/// stacked k/v uploads are the large ones (B × layers × heads × seq ×
/// head_dim floats); reallocating them per call was the dominant transient
/// allocation of the cached serving path, so they live with the runtime
/// and are cleared + refilled each call. `ModelRuntime` is not `Sync`
/// (each worker owns one), so a `RefCell` suffices.
#[derive(Default)]
struct WindowScratch {
    tok: Vec<i32>,
    start: Vec<i32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct ModelRuntime {
    client: xla::PjRtClient,
    cfg: ModelConfig,
    /// weight tensors resident on device, in frozen param order
    weight_bufs: Vec<xla::PjRtBuffer>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// batch sizes with a compiled fwd_conf variant, ascending
    conf_batches: Vec<usize>,
    /// batch sizes with a compiled fwd_window variant, ascending
    window_batches: Vec<usize>,
    stats: std::cell::Cell<RuntimeStats>,
    scratch: std::cell::RefCell<WindowScratch>,
}

impl ModelRuntime {
    /// Load weights + compile every variant listed in model_config.json.
    pub fn load(cfg: &ModelConfig) -> Result<Self> {
        let t0 = Instant::now();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;

        let tensors = weights::load_weights(cfg.weights_path())?;
        let by_name: BTreeMap<&str, &Tensor> =
            tensors.iter().map(|t| (t.name.as_str(), t)).collect();
        let mut weight_bufs = Vec::with_capacity(cfg.param_order.len());
        for name in &cfg.param_order {
            let t = by_name
                .get(name.as_str())
                .with_context(|| format!("weights.bin missing tensor {name}"))?;
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .with_context(|| format!("uploading {name}"))?;
            weight_bufs.push(buf);
        }

        let mut executables = BTreeMap::new();
        let mut conf_batches = Vec::new();
        let mut window_batches = Vec::new();
        for (name, v) in &cfg.variants {
            let path = cfg.hlo_path(v);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling variant {name}"))?;
            executables.insert(name.clone(), exe);
            if let Some(b) = name.strip_prefix("fwd_conf_b") {
                conf_batches.push(b.parse::<usize>().context("variant batch suffix")?);
            }
            if let Some(b) = name.strip_prefix("fwd_window_b") {
                window_batches
                    .push(b.parse::<usize>().context("variant batch suffix")?);
            }
        }
        conf_batches.sort_unstable();
        window_batches.sort_unstable();
        if conf_batches.is_empty() {
            bail!("no fwd_conf_b* variants in model_config.json");
        }
        log::info!(
            "runtime ready: {} weights, {} variants, {:.2}s",
            weight_bufs.len(),
            executables.len(),
            t0.elapsed().as_secs_f64()
        );
        Ok(ModelRuntime {
            client,
            cfg: cfg.clone(),
            weight_bufs,
            executables,
            conf_batches,
            window_batches,
            stats: std::cell::Cell::new(RuntimeStats::default()),
            scratch: std::cell::RefCell::new(WindowScratch::default()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.get()
    }

    /// Largest compiled fwd_conf batch size.
    pub fn max_batch(&self) -> usize {
        *self.conf_batches.last().unwrap()
    }

    /// Smallest compiled batch size that fits `n` sequences.
    pub fn pick_batch(&self, n: usize) -> usize {
        self.conf_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch())
    }

    fn bump(&self, f: impl FnOnce(&mut RuntimeStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .with_context(|| format!("variant {name} not loaded"))
    }

    fn tokens_buffer(&self, flat: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(flat, dims, None)
            .context("uploading tokens")
    }

    /// Run one executable over weights ++ extra args; returns the
    /// decomposed output tuple as host literals.
    fn run(&self, name: &str, extra: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.extend(extra.iter());
        let t0 = Instant::now();
        let result = exe
            .execute_b(&args)
            .with_context(|| format!("executing {name}"))?;
        let exec_us = t0.elapsed().as_micros() as u64;
        let t1 = Instant::now();
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching output tuple")?;
        let parts = lit.to_tuple().context("decomposing output tuple")?;
        let transfer_us = t1.elapsed().as_micros() as u64;
        self.bump(|s| {
            s.exec_micros += exec_us;
            s.transfer_micros += transfer_us;
        });
        Ok(parts)
    }

    /// Full forward over a batch of borrowed token sequences (each of len
    /// seq_len): per-position confidence + greedy candidate. `batch` may be
    /// any size up to `max_batch`; sequences are padded to the compiled
    /// batch shape and the padding rows are dropped from the output.
    pub fn fwd_conf(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut> {
        let n = batch_tokens.len();
        if n == 0 {
            return Ok(ConfOut { conf: vec![], argmax: vec![] });
        }
        let s = self.cfg.seq_len;
        let b = self.pick_batch(n);
        if n > b {
            bail!("batch {n} exceeds max compiled batch {b}");
        }
        let mut flat = Vec::with_capacity(b * s);
        for seq in batch_tokens {
            if seq.len() != s {
                bail!("sequence length {} != {s}", seq.len());
            }
            flat.extend(seq.iter().map(|&t| t as i32));
        }
        flat.resize(b * s, self.cfg.pad_id as i32); // padding rows
        let tok_buf = self.tokens_buffer(&flat, &[b, s])?;
        let parts = self.run(&format!("fwd_conf_b{b}"), &[tok_buf])?;
        self.bump(|st| st.fwd_calls += 1);
        let (conf, argmax) = unpack_conf(&parts, n, s)?;
        Ok(ConfOut { conf, argmax })
    }

    /// Block-boundary forward (batch 1): conf/argmax plus refreshed dual
    /// KV cache.
    pub fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, KvCache)> {
        let s = self.cfg.seq_len;
        if tokens.len() != s {
            bail!("sequence length {} != {s}", tokens.len());
        }
        let flat: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.tokens_buffer(&flat, &[1, s])?;
        let parts = self.run("fwd_full_kv_b1", &[tok_buf])?;
        if parts.len() != 4 {
            bail!("fwd_full_kv output arity {} != 4", parts.len());
        }
        self.bump(|st| st.fwd_full_kv_calls += 1);
        let (conf, argmax) = unpack_conf(&parts[..2], 1, s)?;
        let dims = [
            self.cfg.n_layers,
            self.cfg.n_heads,
            s,
            self.cfg.head_dim,
        ];
        let kv = KvCache {
            k: parts[2].to_vec::<f32>().context("k_cache")?,
            v: parts[3].to_vec::<f32>().context("v_cache")?,
            dims,
        };
        let want: usize = dims.iter().product();
        if kv.k.len() != want || kv.v.len() != want {
            bail!("kv cache size {} != {want}", kv.k.len());
        }
        Ok((ConfOut { conf, argmax }, kv))
    }

    /// Within-block forward (batch 1): recompute only the `block_len`
    /// window at absolute position `start`, attending against the cache.
    pub fn fwd_window(
        &self,
        window_tokens: &[u32],
        start: usize,
        cache: &KvCache,
    ) -> Result<ConfOut> {
        let w = self.cfg.block_len;
        if window_tokens.len() != w {
            bail!("window length {} != {w}", window_tokens.len());
        }
        let flat: Vec<i32> = window_tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.tokens_buffer(&flat, &[1, w])?;
        let start_buf = self
            .client
            .buffer_from_host_buffer::<i32>(&[start as i32], &[], None)
            .context("uploading start scalar")?;
        let k_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&cache.k, &cache.dims, None)
            .context("uploading k cache")?;
        let v_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&cache.v, &cache.dims, None)
            .context("uploading v cache")?;
        let parts = self.run("fwd_window_b1", &[tok_buf, start_buf, k_buf, v_buf])?;
        self.bump(|st| st.fwd_window_calls += 1);
        let (conf, argmax) = unpack_conf(&parts, 1, w)?;
        Ok(ConfOut { conf, argmax })
    }

    /// Batched within-block forward: `n` same-shape windows from different
    /// sequences share one pass. Uses a compiled `fwd_window_b{B}` variant
    /// when the artifact set has one that fits (windows stacked to [B, w],
    /// caches to [B, layers, heads, seq, head_dim], padding rows zeroed);
    /// otherwise falls back to sequential batch-1 window passes, which is
    /// result-identical.
    pub fn fwd_window_batch(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&KvCache],
    ) -> Result<ConfOut> {
        let n = windows.len();
        if n != starts.len() || n != caches.len() {
            bail!(
                "window batch arity mismatch: {} windows, {} starts, {} caches",
                n,
                starts.len(),
                caches.len()
            );
        }
        if n == 0 {
            return Ok(ConfOut { conf: vec![], argmax: vec![] });
        }
        let bmax = self.window_batches.last().copied().unwrap_or(1);
        if n == 1 || bmax <= 1 {
            // no compiled batched variant — run the exact batch-1 path
            let mut conf = Vec::with_capacity(n);
            let mut argmax = Vec::with_capacity(n);
            for ((window, &start), cache) in windows.iter().zip(starts).zip(caches) {
                let mut out = self.fwd_window(window, start, cache)?;
                conf.push(std::mem::take(&mut out.conf[0]));
                argmax.push(std::mem::take(&mut out.argmax[0]));
            }
            return Ok(ConfOut { conf, argmax });
        }
        // chunk by the largest compiled variant (mirrors fwd_conf's
        // pick_batch) so n beyond it still uses stacked passes
        if n > bmax {
            let mut conf = Vec::with_capacity(n);
            let mut argmax = Vec::with_capacity(n);
            let mut at = 0;
            while at < n {
                let end = (at + bmax).min(n);
                let mut out = self.fwd_window_stacked(
                    &windows[at..end],
                    &starts[at..end],
                    &caches[at..end],
                )?;
                conf.append(&mut out.conf);
                argmax.append(&mut out.argmax);
                at = end;
            }
            return Ok(ConfOut { conf, argmax });
        }
        self.fwd_window_stacked(windows, starts, caches)
    }

    /// One stacked window pass (n <= the largest compiled batch). Staging
    /// goes through the runtime's reusable [`WindowScratch`] — no per-call
    /// reallocation of the flat token/start/k/v buffers.
    fn fwd_window_stacked(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&KvCache],
    ) -> Result<ConfOut> {
        let n = windows.len();
        let b = self
            .window_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.window_batches.last().copied().unwrap_or(1));
        let w = self.cfg.block_len;
        let cache_dims = [
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.seq_len,
            self.cfg.head_dim,
        ];
        let cache_len: usize = cache_dims.iter().product();
        let mut scratch = self.scratch.borrow_mut();
        let WindowScratch {
            tok: flat_tok,
            start: flat_start,
            k: flat_k,
            v: flat_v,
        } = &mut *scratch;
        flat_tok.clear();
        flat_start.clear();
        flat_k.clear();
        flat_v.clear();
        flat_tok.reserve(b * w);
        flat_k.reserve(b * cache_len);
        flat_v.reserve(b * cache_len);
        for ((window, &start), cache) in windows.iter().zip(starts).zip(caches) {
            if window.len() != w {
                bail!("window length {} != {w}", window.len());
            }
            if cache.dims != cache_dims {
                bail!("cache dims {:?} != {:?}", cache.dims, cache_dims);
            }
            flat_tok.extend(window.iter().map(|&t| t as i32));
            flat_start.push(start as i32);
            flat_k.extend_from_slice(&cache.k);
            flat_v.extend_from_slice(&cache.v);
        }
        // padding rows: pad tokens, start 0, zero caches
        flat_tok.resize(b * w, self.cfg.pad_id as i32);
        flat_start.resize(b, 0);
        flat_k.resize(b * cache_len, 0.0);
        flat_v.resize(b * cache_len, 0.0);
        let tok_buf = self.tokens_buffer(flat_tok, &[b, w])?;
        let start_buf = self
            .client
            .buffer_from_host_buffer::<i32>(flat_start, &[b], None)
            .context("uploading start vector")?;
        let stacked = [
            b,
            cache_dims[0],
            cache_dims[1],
            cache_dims[2],
            cache_dims[3],
        ];
        let k_buf = self
            .client
            .buffer_from_host_buffer::<f32>(flat_k, &stacked, None)
            .context("uploading stacked k cache")?;
        let v_buf = self
            .client
            .buffer_from_host_buffer::<f32>(flat_v, &stacked, None)
            .context("uploading stacked v cache")?;
        let parts =
            self.run(&format!("fwd_window_b{b}"), &[tok_buf, start_buf, k_buf, v_buf])?;
        self.bump(|st| st.fwd_window_calls += n as u64);
        let (conf, argmax) = unpack_conf(&parts, n, w)?;
        Ok(ConfOut { conf, argmax })
    }

    /// Debug entry: full logits for one sequence, row-major (seq, vocab).
    pub fn logits(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let s = self.cfg.seq_len;
        if tokens.len() != s {
            bail!("sequence length {} != {s}", tokens.len());
        }
        let flat: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.tokens_buffer(&flat, &[1, s])?;
        let parts = self.run("logits_b1", &[tok_buf])?;
        parts[0].to_vec::<f32>().context("logits payload")
    }
}

/// Split (conf f32[B,S], argmax i32[B,S]) literals into per-sequence rows,
/// keeping only the first `n` rows (the rest is batch padding).
fn unpack_conf(
    parts: &[xla::Literal],
    n: usize,
    s: usize,
) -> Result<(Vec<Vec<f32>>, Vec<Vec<u32>>)> {
    if parts.len() < 2 {
        bail!("expected (conf, argmax) outputs, got {}", parts.len());
    }
    let conf_flat = parts[0].to_vec::<f32>().context("conf payload")?;
    let arg_flat = parts[1].to_vec::<i32>().context("argmax payload")?;
    if conf_flat.len() < n * s || arg_flat.len() < n * s {
        bail!(
            "conf/argmax payload too small: {} / {} < {}",
            conf_flat.len(),
            arg_flat.len(),
            n * s
        );
    }
    let conf = (0..n)
        .map(|i| conf_flat[i * s..(i + 1) * s].to_vec())
        .collect();
    let argmax = (0..n)
        .map(|i| {
            arg_flat[i * s..(i + 1) * s]
                .iter()
                .map(|&x| x as u32)
                .collect()
        })
        .collect();
    Ok((conf, argmax))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpack_conf_splits_rows() {
        let conf = xla::Literal::vec1(&[0.1f32, 0.2, 0.3, 0.4]);
        let arg = xla::Literal::vec1(&[1i32, 2, 3, 4]);
        let (c, a) = unpack_conf(&[conf, arg], 2, 2).unwrap();
        assert_eq!(c, vec![vec![0.1, 0.2], vec![0.3, 0.4]]);
        assert_eq!(a, vec![vec![1, 2], vec![3, 4]]);
    }

    #[test]
    fn unpack_conf_drops_padding_rows() {
        let conf = xla::Literal::vec1(&[0.1f32, 0.2, 0.3, 0.4]);
        let arg = xla::Literal::vec1(&[1i32, 2, 3, 4]);
        let (c, _) = unpack_conf(&[conf, arg], 1, 2).unwrap();
        assert_eq!(c, vec![vec![0.1, 0.2]]);
    }

    #[test]
    fn unpack_conf_rejects_short_payload() {
        let conf = xla::Literal::vec1(&[0.1f32]);
        let arg = xla::Literal::vec1(&[1i32]);
        assert!(unpack_conf(&[conf, arg], 1, 2).is_err());
    }
}
