//! PJRT runtime: loads the AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client, keeps the model weights resident as device buffers, and
//! exposes typed forward-pass entry points to the decode engine.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO **text** -> `HloModuleProto`
//! -> `XlaComputation` -> `client.compile`. All execution goes through
//! `execute_b_parts` (device buffers in, per-element device buffers out) so
//! weights are uploaded exactly once and — on the default **device**
//! residency (DESIGN.md §10) — the dual KV cache never crosses the
//! host↔device boundary between block refreshes: `fwd_full_kv` retains its
//! k/v outputs as buffers inside an opaque [`CacheHandle`], and the window
//! passes take those buffers as arguments directly. The legacy **host**
//! residency (download-then-reupload every step) stays selectable for A/B
//! via [`ModelRuntime::set_residency`].
//!
//! The threshold *decision* is device-resident too (DESIGN.md §11): the
//! `fwd_window_accept_b{B}` variants run the per-row acceptance rule and
//! argmax fallback inside the executable, so steady-state window steps
//! download compact [`AcceptOut`] payloads — O(accepted tokens) — instead
//! of full confidence/argmax rows.
//!
//! One `ModelRuntime` is *not* Sync; each engine worker thread owns its own
//! (the PJRT CPU client is cheap and executables compile in milliseconds).

pub mod weights;

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::cache::{CacheHandle, CachePool, DeviceKv, Residency};
use crate::model::ModelConfig;
use weights::Tensor;

pub use crate::cache::handle::KvCache;

/// Forward-pass result for a batch: per-sequence confidence and candidate
/// token rows over the full sequence (or window), stored **flat** — one
/// allocation per side per pass instead of a `Vec` per row (the per-step
/// transient the old `Vec<Vec<_>>` shape forced on the scheduler).
#[derive(Clone, Debug, Default)]
pub struct ConfOut {
    rows: usize,
    row_len: usize,
    conf: Vec<f32>,
    argmax: Vec<u32>,
}

impl ConfOut {
    /// An empty result whose rows will be `row_len` wide.
    pub fn new(row_len: usize) -> ConfOut {
        ConfOut { rows: 0, row_len, conf: Vec::new(), argmax: Vec::new() }
    }

    pub fn with_capacity(row_len: usize, rows: usize) -> ConfOut {
        ConfOut {
            rows: 0,
            row_len,
            conf: Vec::with_capacity(rows * row_len),
            argmax: Vec::with_capacity(rows * row_len),
        }
    }

    /// Build from flat payloads holding exactly `rows × row_len` entries.
    pub fn from_flat(
        conf: Vec<f32>,
        argmax: Vec<u32>,
        rows: usize,
        row_len: usize,
    ) -> Result<ConfOut> {
        if conf.len() != rows * row_len || argmax.len() != rows * row_len {
            bail!(
                "flat conf/argmax payload {} / {} != {rows} x {row_len}",
                conf.len(),
                argmax.len()
            );
        }
        Ok(ConfOut { rows, row_len, conf, argmax })
    }

    /// Number of sequence rows.
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Confidence row `i` as a borrowed slice (no per-row allocation).
    pub fn conf_row(&self, i: usize) -> &[f32] {
        assert!(i < self.rows, "conf row {i} out of {}", self.rows);
        &self.conf[i * self.row_len..(i + 1) * self.row_len]
    }

    /// Candidate-token row `i` as a borrowed slice.
    pub fn argmax_row(&self, i: usize) -> &[u32] {
        assert!(i < self.rows, "argmax row {i} out of {}", self.rows);
        &self.argmax[i * self.row_len..(i + 1) * self.row_len]
    }

    /// Append one row (sim / sequential-fallback builders).
    pub fn push_row(&mut self, conf: &[f32], argmax: &[u32]) {
        assert_eq!(conf.len(), self.row_len, "conf row width");
        assert_eq!(argmax.len(), self.row_len, "argmax row width");
        self.conf.extend_from_slice(conf);
        self.argmax.extend_from_slice(argmax);
        self.rows += 1;
    }

    /// Append all rows of `other` (chunked passes).
    pub fn append(&mut self, other: ConfOut) {
        assert_eq!(other.row_len, self.row_len, "row width mismatch");
        self.conf.extend_from_slice(&other.conf);
        self.argmax.extend_from_slice(&other.argmax);
        self.rows += other.rows;
    }
}

/// Per-row device acceptance rule for [`ModelRuntime::fwd_window_accept`]
/// — the runtime mirror of a policy's `StepPlan` (DESIGN.md §11). A row's
/// raw acceptance is
///
/// ```text
/// masked[i] && (conf[i] > tau  ||  conf[i] >= factor · cmax)
/// ```
///
/// in f32, where `cmax` is the row's max masked confidence. A disabled
/// disjunct is `+inf`, which can never accept (`x > ∞` is false; `∞·cmax`
/// is `+inf` or NaN for any real confidence, so `x >= ∞·cmax` is false).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AcceptRule {
    pub tau: f32,
    pub factor: f32,
}

impl AcceptRule {
    /// Fixed-cutoff rule: accept `conf > tau` (f32 strict compare).
    pub fn threshold(tau: f32) -> AcceptRule {
        AcceptRule { tau, factor: f32::INFINITY }
    }

    /// Relative rule: accept `conf >= factor · cmax` (f32 math).
    pub fn factor_max(factor: f32) -> AcceptRule {
        AcceptRule { tau: f32::INFINITY, factor }
    }
}

/// Compact result of a fused window-acceptance pass: per row, only the
/// accepted (window-local position, token) pairs plus the two scalars the
/// decode layer needs — the masked-mean confidence (drift signatures) and
/// the argmax-fallback flag. Stored flat (offsets, not per-row `Vec`s).
#[derive(Clone, Debug, Default)]
pub struct AcceptOut {
    /// Accepted (window-local position, committed token) pairs, rows
    /// concatenated in ascending-position order within each row.
    pairs: Vec<(u32, u32)>,
    /// Per-row end offset into `pairs`.
    ends: Vec<usize>,
    /// Per-row masked-mean confidence of the step.
    means: Vec<f32>,
    /// Per-row: did the argmax liveness fallback fire?
    fell_back: Vec<bool>,
}

impl AcceptOut {
    pub fn with_capacity(rows: usize) -> AcceptOut {
        AcceptOut {
            pairs: Vec::with_capacity(2 * rows),
            ends: Vec::with_capacity(rows),
            means: Vec::with_capacity(rows),
            fell_back: Vec::with_capacity(rows),
        }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    /// Accepted (local position, token) pairs of row `i`.
    pub fn row(&self, i: usize) -> &[(u32, u32)] {
        assert!(i < self.ends.len(), "accept row {i} out of {}", self.ends.len());
        let start = if i == 0 { 0 } else { self.ends[i - 1] };
        &self.pairs[start..self.ends[i]]
    }

    /// Masked-mean confidence of row `i` (the drift-signature scalar).
    pub fn step_mean(&self, i: usize) -> f32 {
        self.means[i]
    }

    /// Whether row `i` committed via the argmax liveness fallback.
    pub fn fell_back(&self, i: usize) -> bool {
        self.fell_back[i]
    }

    pub fn push_row(&mut self, pairs: &[(u32, u32)], mean: f32, fell_back: bool) {
        self.pairs.extend_from_slice(pairs);
        self.ends.push(self.pairs.len());
        self.means.push(mean);
        self.fell_back.push(fell_back);
    }

    /// Append all rows of `other` (chunked passes).
    pub fn append(&mut self, other: AcceptOut) {
        let base = self.pairs.len();
        self.pairs.extend_from_slice(&other.pairs);
        self.ends.extend(other.ends.iter().map(|e| e + base));
        self.means.extend_from_slice(&other.means);
        self.fell_back.extend_from_slice(&other.fell_back);
    }
}

/// Host-side reference of the fused acceptance rule — the *exact* f32
/// semantics the compiled `fwd_window_accept_b{B}` kernels implement on
/// device (python `model.accept_from_conf`). Backends without compiled
/// accept variants (`SimModel`, artifact sets predating the fused kernels)
/// route through this over a full [`ConfOut`]; tests use it to pin device
/// and host to one rule. The masked set is derived from the window tokens
/// (`== mask_id`), identical to `DecodeTask::masked`.
pub fn accept_rows(
    out: &ConfOut,
    windows: &[&[u32]],
    mask_id: u32,
    rules: &[AcceptRule],
) -> AcceptOut {
    assert_eq!(windows.len(), rules.len(), "windows vs rules arity");
    assert!(out.len() >= windows.len(), "conf rows vs windows arity");
    let mut res = AcceptOut::with_capacity(windows.len());
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    for (r, (window, rule)) in windows.iter().zip(rules).enumerate() {
        let conf = out.conf_row(r);
        let arg = out.argmax_row(r);
        pairs.clear();
        // one pass over the masked set: max (ties -> lowest index via
        // strict >, matching `policy::argmax`), sum, count
        let mut cmax = f32::NEG_INFINITY;
        let mut best = None;
        let mut sum = 0.0f64;
        let mut cnt = 0usize;
        for (i, &t) in window.iter().enumerate() {
            if t != mask_id {
                continue;
            }
            sum += f64::from(conf[i]);
            cnt += 1;
            if conf[i] > cmax {
                cmax = conf[i];
                best = Some(i);
            }
        }
        if cnt == 0 {
            res.push_row(&[], 0.0, false);
            continue;
        }
        let cut = rule.factor * cmax;
        for (i, &t) in window.iter().enumerate() {
            if t == mask_id && (conf[i] > rule.tau || conf[i] >= cut) {
                pairs.push((i as u32, arg[i]));
            }
        }
        let mut fell_back = false;
        if pairs.is_empty() {
            let b = best.expect("non-empty masked set has a max");
            pairs.push((b as u32, arg[b]));
            fell_back = true;
        }
        res.push_row(&pairs, (sum / cnt as f64) as f32, fell_back);
    }
    res
}

/// Transfer/execution accounting for one runtime entry point.
#[derive(Clone, Copy, Debug, Default)]
pub struct EntryStats {
    /// Executable invocations.
    pub calls: u64,
    pub exec_micros: u64,
    pub upload_micros: u64,
    pub upload_bytes: u64,
    pub download_micros: u64,
    pub download_bytes: u64,
}

impl EntryStats {
    fn add(&mut self, o: &EntryStats) {
        self.calls += o.calls;
        self.exec_micros += o.exec_micros;
        self.upload_micros += o.upload_micros;
        self.upload_bytes += o.upload_bytes;
        self.download_micros += o.download_micros;
        self.download_bytes += o.download_bytes;
    }
}

/// Counters the perf pass, benches, and the residency acceptance tests
/// read — split per entry point so the device-residency win is visible as
/// numbers, not vibes.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub conf: EntryStats,
    pub full_kv: EntryStats,
    pub window: EntryStats,
    /// The `kv_gather_b{B}` on-device stacking pass (device residency only).
    pub gather: EntryStats,
    /// The fused `fwd_window_accept_b{B}` pass: threshold compare + argmax
    /// fallback on device, compact acceptance downloaded. On the fused
    /// steady state this replaces `window` entirely — the acceptance test
    /// pins `window.download_bytes` flat while `accept.calls` grows, and
    /// `accept.download_bytes` stays O(accepted tokens) per step.
    pub accept: EntryStats,
    /// Host→device bytes spent uploading K/V payloads as forward-pass
    /// arguments. **Zero on the device-residency path** — the acceptance
    /// counter for "no per-step host k/v round trip".
    pub cache_upload_bytes: u64,
    /// Device→host bytes spent downloading refreshed K/V out of
    /// `fwd_full_kv`. Zero on the device-residency path.
    pub cache_download_bytes: u64,
}

impl RuntimeStats {
    /// Aggregate over all entry points.
    pub fn total(&self) -> EntryStats {
        let mut t = EntryStats::default();
        for e in [
            &self.conf,
            &self.full_kv,
            &self.window,
            &self.gather,
            &self.accept,
        ] {
            t.add(e);
        }
        t
    }

    pub fn upload_bytes(&self) -> u64 {
        self.total().upload_bytes
    }

    pub fn download_bytes(&self) -> u64 {
        self.total().download_bytes
    }

    pub fn transfer_bytes(&self) -> u64 {
        let t = self.total();
        t.upload_bytes + t.download_bytes
    }

    pub fn exec_micros(&self) -> u64 {
        self.total().exec_micros
    }

    pub fn transfer_micros(&self) -> u64 {
        let t = self.total();
        t.upload_micros + t.download_micros
    }
}

/// Which entry point an upload/exec/download belongs to.
#[derive(Clone, Copy, Debug)]
enum Entry {
    Conf,
    FullKv,
    Window,
    Gather,
    Accept,
}

/// Reusable host-side staging buffers for batched passes. On the host
/// residency path the stacked k/v uploads are the large ones (B × layers ×
/// heads × seq × head_dim floats); on the device path only the token/start
/// staging remains and `flat_k`/`flat_v` stay empty. `ModelRuntime` is not
/// `Sync` (each worker owns one), so a `RefCell` suffices.
#[derive(Default)]
struct WindowScratch {
    tok: Vec<i32>,
    start: Vec<i32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

pub struct ModelRuntime {
    client: xla::PjRtClient,
    cfg: ModelConfig,
    /// weight tensors resident on device, in frozen param order
    weight_bufs: Vec<xla::PjRtBuffer>,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// batch sizes with a compiled fwd_conf variant, ascending
    conf_batches: Vec<usize>,
    /// batch sizes with a compiled fwd_window variant, ascending
    window_batches: Vec<usize>,
    /// batch sizes with BOTH fwd_window_b{B} and kv_gather_b{B} compiled —
    /// the stacked device-residency path, ascending
    gather_batches: Vec<usize>,
    /// batch sizes with a compiled fwd_window_accept variant, ascending
    accept_batches: Vec<usize>,
    /// batch sizes with BOTH fwd_window_accept_b{B} and kv_gather_b{B}
    /// compiled — the fused device-residency path, ascending
    accept_gather_batches: Vec<usize>,
    residency: std::cell::Cell<Residency>,
    pool: CachePool,
    stats: std::cell::Cell<RuntimeStats>,
    scratch: std::cell::RefCell<WindowScratch>,
}

impl ModelRuntime {
    /// Load weights + compile every variant listed in model_config.json.
    pub fn load(cfg: &ModelConfig) -> Result<Self> {
        let t0 = Instant::now();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;

        let tensors = weights::load_weights(cfg.weights_path())?;
        let by_name: BTreeMap<&str, &Tensor> =
            tensors.iter().map(|t| (t.name.as_str(), t)).collect();
        let mut weight_bufs = Vec::with_capacity(cfg.param_order.len());
        for name in &cfg.param_order {
            let t = by_name
                .get(name.as_str())
                .with_context(|| format!("weights.bin missing tensor {name}"))?;
            let buf = client
                .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
                .with_context(|| format!("uploading {name}"))?;
            weight_bufs.push(buf);
        }

        let mut executables = BTreeMap::new();
        let mut conf_batches = Vec::new();
        let mut window_batches = Vec::new();
        let mut accept_batches = Vec::new();
        let mut gather_raw = Vec::new();
        for (name, v) in &cfg.variants {
            let path = cfg.hlo_path(v);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling variant {name}"))?;
            executables.insert(name.clone(), exe);
            if let Some(b) = name.strip_prefix("fwd_conf_b") {
                conf_batches.push(b.parse::<usize>().context("variant batch suffix")?);
            }
            // NB: checked before "fwd_window_b", which is a prefix of it
            if let Some(b) = name.strip_prefix("fwd_window_accept_b") {
                accept_batches
                    .push(b.parse::<usize>().context("variant batch suffix")?);
            } else if let Some(b) = name.strip_prefix("fwd_window_b") {
                window_batches
                    .push(b.parse::<usize>().context("variant batch suffix")?);
            }
            if let Some(b) = name.strip_prefix("kv_gather_b") {
                gather_raw.push(b.parse::<usize>().context("variant batch suffix")?);
            }
        }
        conf_batches.sort_unstable();
        window_batches.sort_unstable();
        accept_batches.sort_unstable();
        let mut gather_batches: Vec<usize> = gather_raw
            .iter()
            .copied()
            .filter(|b| window_batches.contains(b))
            .collect();
        gather_batches.sort_unstable();
        let mut accept_gather_batches: Vec<usize> = gather_raw
            .into_iter()
            .filter(|b| accept_batches.contains(b))
            .collect();
        accept_gather_batches.sort_unstable();
        if conf_batches.is_empty() {
            bail!("no fwd_conf_b* variants in model_config.json");
        }
        if !accept_batches.is_empty()
            && (cfg.vocab_size > 0xFFFF || cfg.block_len > 0x7FFF)
        {
            // the compact accept payload packs (pos << 16) | token into one
            // i32 — a geometry the packing cannot represent loses the fused
            // fast path (every legacy path keeps working); aot.py skips
            // emitting the variants for such models, so this only fires on
            // a config/artifact mismatch
            log::warn!(
                "fused accept disabled: packing needs vocab_size < 65536 and \
                 block_len < 32768 (got {} / {})",
                cfg.vocab_size,
                cfg.block_len
            );
            accept_batches.clear();
            accept_gather_batches.clear();
        }
        let cache_dims = [cfg.n_layers, cfg.n_heads, cfg.seq_len, cfg.head_dim];
        let pool_cap = 2 * conf_batches.last().copied().unwrap_or(1).max(
            window_batches.last().copied().unwrap_or(1),
        );
        log::info!(
            "runtime ready: {} weights, {} variants (gather batches {:?}, \
             accept batches {:?}), {:.2}s",
            weight_bufs.len(),
            executables.len(),
            gather_batches,
            accept_batches,
            t0.elapsed().as_secs_f64()
        );
        Ok(ModelRuntime {
            client,
            cfg: cfg.clone(),
            weight_bufs,
            executables,
            conf_batches,
            window_batches,
            gather_batches,
            accept_batches,
            accept_gather_batches,
            residency: std::cell::Cell::new(Residency::default()),
            pool: CachePool::new(cache_dims, pool_cap),
            stats: std::cell::Cell::new(RuntimeStats::default()),
            scratch: std::cell::RefCell::new(WindowScratch::default()),
        })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.get()
    }

    /// Where this runtime keeps minted KV caches. Default:
    /// [`Residency::Device`]. Handles minted before a switch stay valid —
    /// the window passes dispatch on each handle's own residency.
    pub fn residency(&self) -> Residency {
        self.residency.get()
    }

    pub fn set_residency(&self, r: Residency) {
        self.residency.set(r);
    }

    /// The cache-storage recycler backing this runtime's handles.
    pub fn pool(&self) -> &CachePool {
        &self.pool
    }

    /// Largest compiled fwd_conf batch size.
    pub fn max_batch(&self) -> usize {
        *self.conf_batches.last().unwrap()
    }

    /// Compiled window/fused batch sizes, ascending — the scheduler's
    /// bucket ladder. Parsed from the variant table at load, so artifact
    /// sets with wider buckets (b8/b16/b32) flow through without code
    /// changes.
    pub fn window_buckets(&self) -> Vec<usize> {
        self.window_batches.clone()
    }

    /// Smallest compiled batch size that fits `n` sequences.
    pub fn pick_batch(&self, n: usize) -> usize {
        self.conf_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch())
    }

    fn cache_dims(&self) -> [usize; 4] {
        [
            self.cfg.n_layers,
            self.cfg.n_heads,
            self.cfg.seq_len,
            self.cfg.head_dim,
        ]
    }

    fn bump(&self, f: impl FnOnce(&mut RuntimeStats)) {
        let mut s = self.stats.get();
        f(&mut s);
        self.stats.set(s);
    }

    fn bump_entry(&self, e: Entry, f: impl FnOnce(&mut EntryStats)) {
        self.bump(|s| {
            f(match e {
                Entry::Conf => &mut s.conf,
                Entry::FullKv => &mut s.full_kv,
                Entry::Window => &mut s.window,
                Entry::Gather => &mut s.gather,
                Entry::Accept => &mut s.accept,
            })
        });
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .with_context(|| format!("variant {name} not loaded"))
    }

    fn upload_i32(&self, e: Entry, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self
            .client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .context("uploading i32 buffer")?;
        let us = t0.elapsed().as_micros() as u64;
        self.bump_entry(e, |s| {
            s.upload_micros += us;
            s.upload_bytes += 4 * data.len() as u64;
        });
        Ok(buf)
    }

    /// Upload an f32 array, additionally counting it as K/V-payload bytes
    /// when `is_cache` — the counter the residency acceptance test pins at
    /// zero for the device path.
    fn upload_f32(
        &self,
        e: Entry,
        data: &[f32],
        dims: &[usize],
        is_cache: bool,
    ) -> Result<xla::PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .context("uploading f32 buffer")?;
        let us = t0.elapsed().as_micros() as u64;
        let bytes = 4 * data.len() as u64;
        self.bump_entry(e, |s| {
            s.upload_micros += us;
            s.upload_bytes += bytes;
        });
        if is_cache {
            self.bump(|s| s.cache_upload_bytes += bytes);
        }
        Ok(buf)
    }

    /// Run one executable, keeping every output tuple element as a device
    /// buffer. `extra` follows the weights (unless `with_weights` is false
    /// — the stacking executables take no parameters beyond the caches);
    /// `donate_extra` indexes into `extra` for arguments whose buffers are
    /// donated to the execution.
    fn exec(
        &self,
        name: &str,
        e: Entry,
        extra: &[&xla::PjRtBuffer],
        donate_extra: &[usize],
        with_weights: bool,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.exe(name)?;
        let mut args: Vec<&xla::PjRtBuffer> = if with_weights {
            self.weight_bufs.iter().collect()
        } else {
            Vec::with_capacity(extra.len())
        };
        let off = args.len();
        args.extend(extra.iter().copied());
        let donate: Vec<usize> = donate_extra.iter().map(|i| i + off).collect();
        let t0 = Instant::now();
        let parts = exe
            .execute_b_parts(&args, &donate)
            .with_context(|| format!("executing {name}"))?;
        let us = t0.elapsed().as_micros() as u64;
        self.bump_entry(e, |s| {
            s.calls += 1;
            s.exec_micros += us;
        });
        Ok(parts)
    }

    /// Download one f32 buffer into pooled/reused storage, with accounting.
    fn download_f32(
        &self,
        e: Entry,
        buf: &xla::PjRtBuffer,
        out: &mut Vec<f32>,
        is_cache: bool,
    ) -> Result<()> {
        let t0 = Instant::now();
        buf.to_literal_sync()
            .and_then(|l| l.read_into(out))
            .context("downloading f32 buffer")?;
        let us = t0.elapsed().as_micros() as u64;
        let bytes = 4 * out.len() as u64;
        self.bump_entry(e, |s| {
            s.download_micros += us;
            s.download_bytes += bytes;
        });
        if is_cache {
            self.bump(|s| s.cache_download_bytes += bytes);
        }
        Ok(())
    }

    /// Download (conf, argmax) output buffers into a flat [`ConfOut`],
    /// keeping only the first `n` rows.
    fn download_conf(
        &self,
        e: Entry,
        conf_buf: &xla::PjRtBuffer,
        arg_buf: &xla::PjRtBuffer,
        n: usize,
        s: usize,
    ) -> Result<ConfOut> {
        let t0 = Instant::now();
        let conf_lit = conf_buf.to_literal_sync().context("fetching conf")?;
        let arg_lit = arg_buf.to_literal_sync().context("fetching argmax")?;
        let us = t0.elapsed().as_micros() as u64;
        // the full padded batch crosses the boundary, not just the n rows
        let bytes = 4 * (conf_lit.element_count() + arg_lit.element_count()) as u64;
        let out = unpack_conf(&[conf_lit, arg_lit], n, s)?;
        self.bump_entry(e, |st| {
            st.download_micros += us;
            st.download_bytes += bytes;
        });
        Ok(out)
    }

    /// Full forward over a batch of borrowed token sequences (each of len
    /// seq_len): per-position confidence + greedy candidate. Any batch size
    /// is accepted: sequences are padded up to the smallest compiled batch
    /// shape that fits, and batches beyond the largest compiled variant are
    /// chunked into result-identical stacked passes (mirroring
    /// `fwd_window_batch` — `pick_batch` no longer silently truncates).
    pub fn fwd_conf(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut> {
        let s = self.cfg.seq_len;
        let n = batch_tokens.len();
        if n == 0 {
            return Ok(ConfOut::new(s));
        }
        let bmax = self.max_batch();
        if n <= bmax {
            return self.fwd_conf_chunk(batch_tokens);
        }
        let mut out = ConfOut::with_capacity(s, n);
        for chunk in batch_tokens.chunks(bmax) {
            out.append(self.fwd_conf_chunk(chunk)?);
        }
        Ok(out)
    }

    /// One compiled-variant-sized fwd_conf pass (`n <= max_batch`).
    fn fwd_conf_chunk(&self, batch_tokens: &[&[u32]]) -> Result<ConfOut> {
        let n = batch_tokens.len();
        let s = self.cfg.seq_len;
        let b = self.pick_batch(n);
        debug_assert!(n <= b, "chunking failed: {n} > {b}");
        let mut flat = Vec::with_capacity(b * s);
        for seq in batch_tokens {
            if seq.len() != s {
                bail!("sequence length {} != {s}", seq.len());
            }
            flat.extend(seq.iter().map(|&t| t as i32));
        }
        flat.resize(b * s, self.cfg.pad_id as i32); // padding rows
        let tok_buf = self.upload_i32(Entry::Conf, &flat, &[b, s])?;
        let parts = self.exec(&format!("fwd_conf_b{b}"), Entry::Conf, &[&tok_buf], &[], true)?;
        if parts.len() < 2 {
            bail!("fwd_conf output arity {} < 2", parts.len());
        }
        self.download_conf(Entry::Conf, &parts[0], &parts[1], n, s)
    }

    /// Block-boundary forward (batch 1): conf/argmax plus a refreshed dual
    /// KV cache behind an opaque [`CacheHandle`]. On [`Residency::Device`]
    /// the k/v outputs are retained as device buffers (nothing downloaded);
    /// on [`Residency::Host`] they are downloaded into pool-recycled host
    /// vectors, reproducing the legacy round-trip path.
    pub fn fwd_full_kv(&self, tokens: &[u32]) -> Result<(ConfOut, CacheHandle)> {
        let s = self.cfg.seq_len;
        if tokens.len() != s {
            bail!("sequence length {} != {s}", tokens.len());
        }
        let flat: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.upload_i32(Entry::FullKv, &flat, &[1, s])?;
        let parts =
            self.exec("fwd_full_kv_b1", Entry::FullKv, &[&tok_buf], &[], true)?;
        let [conf_buf, arg_buf, k_buf, v_buf]: [xla::PjRtBuffer; 4] = parts
            .try_into()
            .map_err(|p: Vec<_>| anyhow::anyhow!("fwd_full_kv output arity {} != 4", p.len()))?;
        let out = self.download_conf(Entry::FullKv, &conf_buf, &arg_buf, 1, s)?;
        let dims = self.cache_dims();
        let want: usize = dims.iter().product();
        let handle = match self.residency.get() {
            Residency::Device => {
                // same artifact-drift guard the host arm gets from its
                // size check: a stale HLO set must fail loudly, not mint a
                // mis-shaped cache stamped with config dims
                if k_buf.dims() != dims.as_slice() || v_buf.dims() != dims.as_slice() {
                    bail!(
                        "fwd_full_kv cache shape {:?}/{:?} != {dims:?}",
                        k_buf.dims(),
                        v_buf.dims()
                    );
                }
                self.pool.wrap_device(k_buf, v_buf)
            }
            Residency::Host => {
                let mut kv = self.pool.take_host_storage();
                self.download_f32(Entry::FullKv, &k_buf, &mut kv.k, true)?;
                self.download_f32(Entry::FullKv, &v_buf, &mut kv.v, true)?;
                if kv.k.len() != want || kv.v.len() != want {
                    bail!("kv cache size {} != {want}", kv.k.len());
                }
                self.pool.wrap_host(kv)
            }
        };
        Ok((out, handle))
    }

    /// Within-block forward (batch 1): recompute only the `block_len`
    /// window at absolute position `start`, attending against the cache.
    /// Host-resident handles upload their k/v payload (legacy path);
    /// device-resident handles pass their buffers straight through — zero
    /// K/V transfer.
    pub fn fwd_window(
        &self,
        window_tokens: &[u32],
        start: usize,
        cache: &CacheHandle,
    ) -> Result<ConfOut> {
        let w = self.cfg.block_len;
        if window_tokens.len() != w {
            bail!("window length {} != {w}", window_tokens.len());
        }
        let dims = self.cache_dims();
        if cache.dims() != dims {
            bail!("cache dims {:?} != {:?}", cache.dims(), dims);
        }
        let flat: Vec<i32> = window_tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.upload_i32(Entry::Window, &flat, &[1, w])?;
        let start_buf = self.upload_i32(Entry::Window, &[start as i32], &[])?;
        let parts = match cache.as_device() {
            Some((k, v)) => self.exec(
                "fwd_window_b1",
                Entry::Window,
                &[&tok_buf, &start_buf, k, v],
                &[],
                true,
            )?,
            None => {
                // host or paged storage; paged assembles its pages here
                let kv = cache.host_kv().expect("host-visible or device");
                let k_buf = self.upload_f32(Entry::Window, &kv.k, &dims, true)?;
                let v_buf = self.upload_f32(Entry::Window, &kv.v, &dims, true)?;
                self.exec(
                    "fwd_window_b1",
                    Entry::Window,
                    &[&tok_buf, &start_buf, &k_buf, &v_buf],
                    &[],
                    true,
                )?
            }
        };
        if parts.len() < 2 {
            bail!("fwd_window output arity {} < 2", parts.len());
        }
        self.download_conf(Entry::Window, &parts[0], &parts[1], 1, w)
    }

    /// Batched within-block forward: `n` same-shape windows from different
    /// sequences share one pass. Dispatch, by handle residency:
    ///
    /// - all **device** + a `kv_gather_b{B}` variant compiled: the caches
    ///   are stacked on device (per-row buffer arguments into the gather
    ///   executable, whose stacked outputs are **donated** into
    ///   `fwd_window_b{B}`) — no host K/V traffic at all;
    /// - all **host** + a `fwd_window_b{B}` variant: the legacy stacked
    ///   upload through [`WindowScratch`];
    /// - otherwise (n == 1, no batched variant, mixed residency):
    ///   result-identical sequential batch-1 window passes.
    ///
    /// Batches beyond the largest compiled variant are chunked.
    pub fn fwd_window_batch(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
    ) -> Result<ConfOut> {
        let n = windows.len();
        if n != starts.len() || n != caches.len() {
            bail!(
                "window batch arity mismatch: {} windows, {} starts, {} caches",
                n,
                starts.len(),
                caches.len()
            );
        }
        if n == 0 {
            return Ok(ConfOut::new(self.cfg.block_len));
        }
        let all_device = caches.iter().all(|c| c.residency() == Residency::Device);
        let all_host = caches.iter().all(|c| c.residency() == Residency::Host);
        if n > 1 && all_device {
            let bmax = self.gather_batches.last().copied().unwrap_or(1);
            if bmax > 1 {
                return self
                    .window_chunks(windows, starts, caches, bmax, Self::fwd_window_gathered);
            }
        }
        if n > 1 && all_host {
            let bmax = self.window_batches.last().copied().unwrap_or(1);
            if bmax > 1 {
                return self
                    .window_chunks(windows, starts, caches, bmax, Self::fwd_window_stacked);
            }
        }
        // exact batch-1 path: n == 1, no batched variant, or mixed residency
        let mut out = ConfOut::with_capacity(self.cfg.block_len, n);
        for ((window, &start), cache) in windows.iter().zip(starts).zip(caches) {
            out.append(self.fwd_window(window, start, cache)?);
        }
        Ok(out)
    }

    /// Split a window batch into `bmax`-sized chunks through `f`.
    fn window_chunks(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        bmax: usize,
        f: impl Fn(&Self, &[&[u32]], &[usize], &[&CacheHandle]) -> Result<ConfOut>,
    ) -> Result<ConfOut> {
        let n = windows.len();
        if n <= bmax {
            return f(self, windows, starts, caches);
        }
        let mut out = ConfOut::with_capacity(self.cfg.block_len, n);
        let mut at = 0;
        while at < n {
            let end = (at + bmax).min(n);
            out.append(f(
                self,
                &windows[at..end],
                &starts[at..end],
                &caches[at..end],
            )?);
            at = end;
        }
        Ok(out)
    }

    /// Stage the token/start rows of a window chunk into scratch, padded to
    /// the compiled batch `b`; returns the uploaded (tokens, starts),
    /// accounted against entry `e`.
    fn upload_window_rows(
        &self,
        scratch: &mut WindowScratch,
        windows: &[&[u32]],
        starts: &[usize],
        b: usize,
        e: Entry,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let w = self.cfg.block_len;
        scratch.tok.clear();
        scratch.start.clear();
        scratch.tok.reserve(b * w);
        for (window, &start) in windows.iter().zip(starts) {
            if window.len() != w {
                bail!("window length {} != {w}", window.len());
            }
            scratch.tok.extend(window.iter().map(|&t| t as i32));
            scratch.start.push(start as i32);
        }
        // padding rows: pad tokens, start 0
        scratch.tok.resize(b * w, self.cfg.pad_id as i32);
        scratch.start.resize(b, 0);
        let tok_buf = self.upload_i32(e, &scratch.tok, &[b, w])?;
        let start_buf = self.upload_i32(e, &scratch.start, &[b])?;
        Ok((tok_buf, start_buf))
    }

    /// Stack per-sequence **device** cache buffers into one batched
    /// (b, L, H, S, Dh) pair via `kv_gather_b{b}` — padding rows reuse a
    /// retired pool pair (else repeat row 0; their output rows are
    /// dropped), and the pairs are handed back to the pool on every path.
    /// The caller donates the stacked pair into the consuming pass.
    fn gather_stack(
        &self,
        caches: &[&CacheHandle],
        b: usize,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let n = caches.len();
        let dims = self.cache_dims();
        let mut rows: Vec<(&xla::PjRtBuffer, &xla::PjRtBuffer)> = Vec::with_capacity(b);
        for cache in caches {
            if cache.dims() != dims {
                bail!("cache dims {:?} != {:?}", cache.dims(), dims);
            }
            rows.push(cache.as_device().expect("gather path is all-device"));
        }
        let pad_rows: Vec<DeviceKv> = (n..b)
            .filter_map(|_| self.pool.take_device_pair())
            .collect();
        for pair in &pad_rows {
            rows.push((&pair.k, &pair.v));
        }
        while rows.len() < b {
            let first = rows[0]; // padding: any cache-shaped buffer serves
            rows.push(first);
        }
        let mut gather_args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 * b);
        gather_args.extend(rows.iter().map(|&(k, _)| k));
        gather_args.extend(rows.iter().map(|&(_, v)| v));
        let stacked_res = self.exec(
            &format!("kv_gather_b{b}"),
            Entry::Gather,
            &gather_args,
            &[],
            false, // stacking executable takes no weight parameters
        );
        drop(gather_args);
        drop(rows);
        // padding pairs were only borrowed for the gather — hand them back
        // (on the error path too) so the pool's retained set isn't drained
        // by padded batches
        for pair in pad_rows {
            self.pool.restore_device_pair(pair);
        }
        let [k_stacked, v_stacked]: [xla::PjRtBuffer; 2] = stacked_res?
            .try_into()
            .map_err(|p: Vec<_>| {
                anyhow::anyhow!("kv_gather output arity {} != 2", p.len())
            })?;
        Ok((k_stacked, v_stacked))
    }

    /// Stage + upload **host** caches as one stacked (b, L, H, S, Dh) pair
    /// (zero-padded rows), accounted against entry `e` as K/V payload.
    fn upload_host_kv_stack(
        &self,
        scratch: &mut WindowScratch,
        caches: &[&CacheHandle],
        b: usize,
        e: Entry,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let cache_dims = self.cache_dims();
        let cache_len: usize = cache_dims.iter().product();
        let WindowScratch { k: flat_k, v: flat_v, .. } = scratch;
        flat_k.clear();
        flat_v.clear();
        flat_k.reserve(b * cache_len);
        flat_v.reserve(b * cache_len);
        for cache in caches {
            if cache.dims() != cache_dims {
                bail!("cache dims {:?} != {:?}", cache.dims(), cache_dims);
            }
            if let Some(table) = cache.as_paged() {
                // stack the page table straight into the staging area —
                // no intermediate whole-sequence buffer
                let at = flat_k.len();
                flat_k.resize(at + cache_len, 0.0);
                flat_v.resize(at + cache_len, 0.0);
                table.copy_into(&mut flat_k[at..], &mut flat_v[at..])?;
            } else {
                let kv = cache.as_host().expect("stacked path is all-host");
                flat_k.extend_from_slice(&kv.k);
                flat_v.extend_from_slice(&kv.v);
            }
        }
        // padding rows: zero caches
        flat_k.resize(b * cache_len, 0.0);
        flat_v.resize(b * cache_len, 0.0);
        let stacked = [
            b,
            cache_dims[0],
            cache_dims[1],
            cache_dims[2],
            cache_dims[3],
        ];
        let k_buf = self.upload_f32(e, flat_k, &stacked, true)?;
        let v_buf = self.upload_f32(e, flat_v, &stacked, true)?;
        Ok((k_buf, v_buf))
    }

    /// One stacked window pass over **device-resident** caches
    /// (n <= the largest compiled gather batch): per-sequence cache buffers
    /// are stacked on device by [`ModelRuntime::gather_stack`] and the
    /// stacked k/v outputs are donated into `fwd_window_b{B}`. The host
    /// never touches a K/V byte.
    fn fwd_window_gathered(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
    ) -> Result<ConfOut> {
        let n = windows.len();
        let b = self
            .gather_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.gather_batches.last().copied().unwrap_or(1));
        let w = self.cfg.block_len;
        let (tok_buf, start_buf) = {
            let mut scratch = self.scratch.borrow_mut();
            self.upload_window_rows(&mut scratch, windows, starts, b, Entry::Window)?
        };
        let (k_stacked, v_stacked) = self.gather_stack(caches, b)?;
        // the stacked pair is a per-call temporary: donate it so the window
        // outputs can alias its device memory instead of allocating
        let parts = self.exec(
            &format!("fwd_window_b{b}"),
            Entry::Window,
            &[&tok_buf, &start_buf, &k_stacked, &v_stacked],
            &[2, 3],
            true,
        )?;
        if parts.len() < 2 {
            bail!("fwd_window output arity {} < 2", parts.len());
        }
        self.download_conf(Entry::Window, &parts[0], &parts[1], n, w)
    }

    /// One stacked window pass over **host-resident** caches (the legacy
    /// upload path, kept for `--cache-residency host` A/B). Staging goes
    /// through the runtime's reusable [`WindowScratch`] — no per-call
    /// reallocation of the flat token/start/k/v buffers.
    fn fwd_window_stacked(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
    ) -> Result<ConfOut> {
        let n = windows.len();
        let b = self
            .window_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.window_batches.last().copied().unwrap_or(1));
        if b == 1 {
            // fwd_window_b1 takes a scalar start — a size-1 tail chunk
            // must go through the batch-1 entry point
            return self.fwd_window(windows[0], starts[0], caches[0]);
        }
        let w = self.cfg.block_len;
        let mut scratch = self.scratch.borrow_mut();
        let (tok_buf, start_buf) =
            self.upload_window_rows(&mut scratch, windows, starts, b, Entry::Window)?;
        let (k_buf, v_buf) =
            self.upload_host_kv_stack(&mut scratch, caches, b, Entry::Window)?;
        let parts = self.exec(
            &format!("fwd_window_b{b}"),
            Entry::Window,
            &[&tok_buf, &start_buf, &k_buf, &v_buf],
            &[],
            true,
        )?;
        if parts.len() < 2 {
            bail!("fwd_window output arity {} < 2", parts.len());
        }
        self.download_conf(Entry::Window, &parts[0], &parts[1], n, w)
    }

    /// Fused batched window pass + on-device threshold acceptance
    /// (DESIGN.md §11): the per-row [`AcceptRule`] and the argmax liveness
    /// fallback run inside the `fwd_window_accept_b{B}` executables, and
    /// only compact acceptance crosses the device→host boundary — counts,
    /// fallback flags, the per-row masked-mean confidence, and
    /// `ceil(max_count / ACCEPT_CHUNK)` packed-commit chunks. Steady-state
    /// window steps therefore download O(accepted tokens), never full
    /// confidence rows. Dispatch mirrors [`ModelRuntime::fwd_window_batch`]
    /// (gathered device path with donated stacking / stacked host upload /
    /// exact batch-1 loop, chunked beyond the largest compiled variant);
    /// artifact sets without accept variants fall back to a full window
    /// pass reduced by the host reference [`accept_rows`] — identical
    /// tokens, legacy transfer profile.
    pub fn fwd_window_accept(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
    ) -> Result<AcceptOut> {
        let n = windows.len();
        if n != starts.len() || n != caches.len() || n != rules.len() {
            bail!(
                "accept batch arity mismatch: {} windows, {} starts, {} caches, \
                 {} rules",
                n,
                starts.len(),
                caches.len(),
                rules.len()
            );
        }
        if n == 0 {
            return Ok(AcceptOut::default());
        }
        if !self.accept_batches.is_empty() {
            let all_device =
                caches.iter().all(|c| c.residency() == Residency::Device);
            let all_host = caches.iter().all(|c| c.residency() == Residency::Host);
            if n > 1 && all_device {
                let bmax = self.accept_gather_batches.last().copied().unwrap_or(1);
                if bmax > 1 {
                    return self.accept_chunks(
                        windows,
                        starts,
                        caches,
                        rules,
                        bmax,
                        Self::fwd_window_accept_gathered,
                    );
                }
            }
            if n > 1 && all_host {
                let bmax = self.accept_batches.last().copied().unwrap_or(1);
                if bmax > 1 {
                    return self.accept_chunks(
                        windows,
                        starts,
                        caches,
                        rules,
                        bmax,
                        Self::fwd_window_accept_stacked,
                    );
                }
            }
            if self.accept_batches.contains(&1) {
                let mut out = AcceptOut::with_capacity(n);
                for i in 0..n {
                    out.append(self.fwd_window_accept_one(
                        windows[i],
                        starts[i],
                        caches[i],
                        rules[i],
                    )?);
                }
                return Ok(out);
            }
        }
        // no compatible accept variant compiled: full window pass + host
        // reference rule (token-identical, legacy download profile)
        let out = self.fwd_window_batch(windows, starts, caches)?;
        Ok(accept_rows(&out, windows, self.cfg.mask_id, rules))
    }

    /// Split an accept batch into `bmax`-sized chunks through `f`.
    #[allow(clippy::too_many_arguments)]
    fn accept_chunks(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
        bmax: usize,
        f: impl Fn(
            &Self,
            &[&[u32]],
            &[usize],
            &[&CacheHandle],
            &[AcceptRule],
        ) -> Result<AcceptOut>,
    ) -> Result<AcceptOut> {
        let n = windows.len();
        if n <= bmax {
            return f(self, windows, starts, caches, rules);
        }
        let mut out = AcceptOut::with_capacity(n);
        let mut at = 0;
        while at < n {
            let end = (at + bmax).min(n);
            out.append(f(
                self,
                &windows[at..end],
                &starts[at..end],
                &caches[at..end],
                &rules[at..end],
            )?);
            at = end;
        }
        Ok(out)
    }

    /// Upload the per-row (tau, factor) rule arrays, padded to batch `b`
    /// with never-accepting `+inf` sentinel rows.
    fn upload_rules(
        &self,
        rules: &[AcceptRule],
        b: usize,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let mut taus: Vec<f32> = rules.iter().map(|r| r.tau).collect();
        let mut factors: Vec<f32> = rules.iter().map(|r| r.factor).collect();
        taus.resize(b, f32::INFINITY);
        factors.resize(b, f32::INFINITY);
        let tau_buf = self.upload_f32(Entry::Accept, &taus, &[b], false)?;
        let factor_buf = self.upload_f32(Entry::Accept, &factors, &[b], false)?;
        Ok((tau_buf, factor_buf))
    }

    /// Upload the `row_live` mask of a padded accept batch: 1 for the `n`
    /// live rows, 0 for padding. The batched `fwd_window_accept_b{B}`
    /// executables zero dead rows' commits, fallback flags, and step means
    /// on device, so padding never surfaces as phantom work.
    fn upload_live(&self, n: usize, b: usize) -> Result<xla::PjRtBuffer> {
        let mut live = vec![0i32; b];
        for x in live.iter_mut().take(n) {
            *x = 1;
        }
        self.upload_i32(Entry::Accept, &live, &[b])
    }

    /// Batch-1 fused pass (`fwd_window_accept_b1`), either cache residency.
    fn fwd_window_accept_one(
        &self,
        window: &[u32],
        start: usize,
        cache: &CacheHandle,
        rule: AcceptRule,
    ) -> Result<AcceptOut> {
        let w = self.cfg.block_len;
        if window.len() != w {
            bail!("window length {} != {w}", window.len());
        }
        let dims = self.cache_dims();
        if cache.dims() != dims {
            bail!("cache dims {:?} != {:?}", cache.dims(), dims);
        }
        let flat: Vec<i32> = window.iter().map(|&t| t as i32).collect();
        let tok_buf = self.upload_i32(Entry::Accept, &flat, &[1, w])?;
        let start_buf = self.upload_i32(Entry::Accept, &[start as i32], &[])?;
        let (tau_buf, factor_buf) =
            self.upload_rules(std::slice::from_ref(&rule), 1)?;
        let parts = match cache.as_device() {
            Some((k, v)) => self.exec(
                "fwd_window_accept_b1",
                Entry::Accept,
                &[&tok_buf, &start_buf, k, v, &tau_buf, &factor_buf],
                &[],
                true,
            )?,
            None => {
                let kv = cache.host_kv().expect("host-visible or device");
                let k_buf = self.upload_f32(Entry::Accept, &kv.k, &dims, true)?;
                let v_buf = self.upload_f32(Entry::Accept, &kv.v, &dims, true)?;
                self.exec(
                    "fwd_window_accept_b1",
                    Entry::Accept,
                    &[&tok_buf, &start_buf, &k_buf, &v_buf, &tau_buf, &factor_buf],
                    &[],
                    true,
                )?
            }
        };
        self.download_accept(&parts, 1)
    }

    /// One fused pass over **device-resident** caches: `kv_gather_b{B}`
    /// stacking (donated) into `fwd_window_accept_b{B}` — zero host K/V
    /// traffic *and* zero confidence-row downloads.
    fn fwd_window_accept_gathered(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
    ) -> Result<AcceptOut> {
        let n = windows.len();
        let b = self
            .accept_gather_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| {
                self.accept_gather_batches.last().copied().unwrap_or(1)
            });
        let (tok_buf, start_buf) = {
            let mut scratch = self.scratch.borrow_mut();
            self.upload_window_rows(&mut scratch, windows, starts, b, Entry::Accept)?
        };
        let (tau_buf, factor_buf) = self.upload_rules(rules, b)?;
        let live_buf = self.upload_live(n, b)?;
        let (k_stacked, v_stacked) = self.gather_stack(caches, b)?;
        let parts = self.exec(
            &format!("fwd_window_accept_b{b}"),
            Entry::Accept,
            &[
                &tok_buf,
                &start_buf,
                &k_stacked,
                &v_stacked,
                &tau_buf,
                &factor_buf,
                &live_buf,
            ],
            &[2, 3],
            true,
        )?;
        self.download_accept(&parts, n)
    }

    /// One fused pass over **host-resident** caches (`--cache-residency
    /// host` A/B): stacked K/V upload, compact acceptance download.
    fn fwd_window_accept_stacked(
        &self,
        windows: &[&[u32]],
        starts: &[usize],
        caches: &[&CacheHandle],
        rules: &[AcceptRule],
    ) -> Result<AcceptOut> {
        let n = windows.len();
        let b = self
            .accept_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.accept_batches.last().copied().unwrap_or(1));
        if b == 1 {
            // the b1 executable has scalar-start, no-row_live arity — a
            // size-1 tail chunk must go through the batch-1 entry point
            return self.fwd_window_accept_one(windows[0], starts[0], caches[0], rules[0]);
        }
        let mut scratch = self.scratch.borrow_mut();
        let (tok_buf, start_buf) =
            self.upload_window_rows(&mut scratch, windows, starts, b, Entry::Accept)?;
        let (k_buf, v_buf) =
            self.upload_host_kv_stack(&mut scratch, caches, b, Entry::Accept)?;
        let (tau_buf, factor_buf) = self.upload_rules(rules, b)?;
        let live_buf = self.upload_live(n, b)?;
        let parts = self.exec(
            &format!("fwd_window_accept_b{b}"),
            Entry::Accept,
            &[
                &tok_buf,
                &start_buf,
                &k_buf,
                &v_buf,
                &tau_buf,
                &factor_buf,
                &live_buf,
            ],
            &[],
            true,
        )?;
        self.download_accept(&parts, n)
    }

    /// Decode the compact outputs of an accept executable: the three
    /// per-row scalar vectors always come down; packed-commit chunks are
    /// downloaded **lazily** — only the first `ceil(max_count / C)` of the
    /// chunk buffers cross the boundary, the rest stay on device. This is
    /// what makes per-step D2H O(accepted tokens) rather than O(block).
    fn download_accept(&self, parts: &[xla::PjRtBuffer], n: usize) -> Result<AcceptOut> {
        if parts.len() < 4 {
            bail!("fwd_window_accept output arity {} < 4", parts.len());
        }
        let w = self.cfg.block_len;
        let t0 = Instant::now();
        let count_lit = parts[0].to_literal_sync().context("fetching accept counts")?;
        let fb_lit = parts[1].to_literal_sync().context("fetching fallback flags")?;
        let mean_lit = parts[2].to_literal_sync().context("fetching step means")?;
        let counts = count_lit.as_slice::<i32>().context("accept count payload")?;
        let fbs = fb_lit.as_slice::<i32>().context("fallback payload")?;
        let means = mean_lit.as_slice::<f32>().context("step mean payload")?;
        if counts.len() < n || fbs.len() < n || means.len() < n {
            bail!("accept scalar payloads shorter than {n} rows");
        }
        let max_count = counts[..n].iter().copied().max().unwrap_or(0);
        if max_count < 0 || max_count as usize > w {
            bail!("accept count {max_count} out of range 0..={w}");
        }
        let max_count = max_count as usize;
        // per-chunk geometry from each buffer's own shape — the FINAL
        // chunk is narrower whenever block_len % ACCEPT_CHUNK != 0, so
        // every chunk carries its own column width
        let mut widths = Vec::with_capacity(parts.len() - 3);
        let mut capacity = 0usize;
        for p in &parts[3..] {
            match p.dims() {
                [rows, cols] if *cols > 0 && *rows >= n => {
                    widths.push(*cols);
                    capacity += *cols;
                }
                other => {
                    bail!("accept chunk shape {other:?} unusable for {n} rows")
                }
            }
        }
        if max_count > capacity {
            bail!("accept count {max_count} exceeds chunk capacity {capacity}");
        }
        // download only the chunk prefix that covers max_count entries
        let mut need = 0;
        let mut covered = 0;
        while covered < max_count {
            covered += widths[need];
            need += 1;
        }
        let mut chunk_lits = Vec::with_capacity(need);
        for p in &parts[3..3 + need] {
            chunk_lits.push(p.to_literal_sync().context("fetching accept chunk")?);
        }
        let us = t0.elapsed().as_micros() as u64;
        let elems = count_lit.element_count()
            + fb_lit.element_count()
            + mean_lit.element_count()
            + chunk_lits.iter().map(xla::Literal::element_count).sum::<usize>();
        self.bump_entry(Entry::Accept, |s| {
            s.download_micros += us;
            s.download_bytes += 4 * elems as u64;
        });
        let mut chunk_slices = Vec::with_capacity(need);
        for l in &chunk_lits {
            chunk_slices.push(l.as_slice::<i32>().context("accept chunk payload")?);
        }
        let mut out = AcceptOut::with_capacity(n);
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(max_count);
        for r in 0..n {
            pairs.clear();
            let c = counts[r].max(0) as usize;
            let mut e = 0usize;
            'chunks: for (slice, &cols) in chunk_slices.iter().zip(&widths) {
                for col in 0..cols {
                    if e >= c {
                        break 'chunks;
                    }
                    let packed = slice[r * cols + col];
                    if packed < 0 {
                        bail!(
                            "accept chunk entry {e} of row {r} empty below \
                             count {c}"
                        );
                    }
                    let pos = (packed >> 16) as u32;
                    if pos as usize >= w {
                        bail!(
                            "accepted position {pos} outside the {w}-token window"
                        );
                    }
                    pairs.push((pos, (packed & 0xFFFF) as u32));
                    e += 1;
                }
            }
            debug_assert_eq!(e, c, "downloaded chunk prefix covers max_count");
            out.push_row(&pairs, means[r], fbs[r] != 0);
        }
        Ok(out)
    }

    /// Debug entry: full logits for one sequence, row-major (seq, vocab).
    pub fn logits(&self, tokens: &[u32]) -> Result<Vec<f32>> {
        let s = self.cfg.seq_len;
        if tokens.len() != s {
            bail!("sequence length {} != {s}", tokens.len());
        }
        let flat: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_buf = self.upload_i32(Entry::Conf, &flat, &[1, s])?;
        let parts = self.exec("logits_b1", Entry::Conf, &[&tok_buf], &[], true)?;
        if parts.is_empty() {
            bail!("logits output arity 0");
        }
        let mut out = Vec::new();
        self.download_f32(Entry::Conf, &parts[0], &mut out, false)?;
        Ok(out)
    }
}

/// Split (conf f32[B,S], argmax i32[B,S]) literals into a flat row-view
/// [`ConfOut`], keeping only the first `n` rows (the rest is batch
/// padding). Exactly one allocation per side — the payloads are borrowed
/// via [`xla::Literal::as_slice`] and written straight into `ConfOut`'s
/// flat storage (no intermediate `to_vec` copy).
fn unpack_conf(parts: &[xla::Literal], n: usize, s: usize) -> Result<ConfOut> {
    if parts.len() < 2 {
        bail!("expected (conf, argmax) outputs, got {}", parts.len());
    }
    let conf_src = parts[0].as_slice::<f32>().context("conf payload")?;
    let arg_src = parts[1].as_slice::<i32>().context("argmax payload")?;
    if conf_src.len() < n * s || arg_src.len() < n * s {
        bail!(
            "conf/argmax payload too small: {} / {} < {}",
            conf_src.len(),
            arg_src.len(),
            n * s
        );
    }
    let conf = conf_src[..n * s].to_vec();
    let argmax: Vec<u32> = arg_src[..n * s].iter().map(|&x| x as u32).collect();
    ConfOut::from_flat(conf, argmax, n, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpack_conf_splits_rows() {
        let conf = xla::Literal::vec1(&[0.1f32, 0.2, 0.3, 0.4]);
        let arg = xla::Literal::vec1(&[1i32, 2, 3, 4]);
        let out = unpack_conf(&[conf, arg], 2, 2).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out.conf_row(0), &[0.1, 0.2]);
        assert_eq!(out.conf_row(1), &[0.3, 0.4]);
        assert_eq!(out.argmax_row(0), &[1, 2]);
        assert_eq!(out.argmax_row(1), &[3, 4]);
    }

    #[test]
    fn unpack_conf_drops_padding_rows() {
        let conf = xla::Literal::vec1(&[0.1f32, 0.2, 0.3, 0.4]);
        let arg = xla::Literal::vec1(&[1i32, 2, 3, 4]);
        let out = unpack_conf(&[conf, arg], 1, 2).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out.conf_row(0), &[0.1, 0.2]);
    }

    #[test]
    fn unpack_conf_rejects_short_payload() {
        let conf = xla::Literal::vec1(&[0.1f32]);
        let arg = xla::Literal::vec1(&[1i32]);
        assert!(unpack_conf(&[conf, arg], 1, 2).is_err());
    }

    #[test]
    fn conf_out_push_and_append() {
        let mut a = ConfOut::new(2);
        a.push_row(&[0.1, 0.2], &[1, 2]);
        let mut b = ConfOut::new(2);
        b.push_row(&[0.3, 0.4], &[3, 4]);
        a.append(b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.conf_row(1), &[0.3, 0.4]);
        assert_eq!(a.argmax_row(0), &[1, 2]);
        assert_eq!(a.row_len(), 2);
        assert!(!a.is_empty());
        assert!(ConfOut::new(3).is_empty());
    }

    #[test]
    #[should_panic]
    fn conf_out_row_out_of_bounds_panics() {
        ConfOut::new(2).conf_row(0);
    }

    #[test]
    fn conf_out_from_flat_checks_size() {
        assert!(ConfOut::from_flat(vec![0.0; 4], vec![0; 4], 2, 2).is_ok());
        assert!(ConfOut::from_flat(vec![0.0; 3], vec![0; 4], 2, 2).is_err());
    }

    #[test]
    fn runtime_stats_aggregate() {
        let mut s = RuntimeStats::default();
        s.conf.upload_bytes = 10;
        s.window.upload_bytes = 5;
        s.full_kv.download_bytes = 7;
        s.gather.exec_micros = 3;
        s.window.exec_micros = 4;
        s.accept.download_bytes = 2;
        s.accept.exec_micros = 1;
        assert_eq!(s.upload_bytes(), 15);
        assert_eq!(s.download_bytes(), 9);
        assert_eq!(s.transfer_bytes(), 24);
        assert_eq!(s.exec_micros(), 8);
    }

    // ---- fused acceptance: host reference rule ---------------------------

    const MASK: u32 = 1;

    fn conf_out(rows: &[(&[f32], &[u32])]) -> ConfOut {
        let mut out = ConfOut::new(rows[0].0.len());
        for (c, a) in rows {
            out.push_row(c, a);
        }
        out
    }

    #[test]
    fn accept_rows_threshold_rule() {
        let window = [MASK, 5, MASK, MASK];
        let out = conf_out(&[(&[0.95, 0.99, 0.5, 0.91], &[10, 11, 12, 13])]);
        let res = accept_rows(
            &out,
            &[&window],
            MASK,
            &[AcceptRule::threshold(0.9)],
        );
        // position 1 is committed (not masked) — excluded despite conf 0.99
        assert_eq!(res.row(0), &[(0, 10), (3, 13)]);
        assert!(!res.fell_back(0));
        // masked-mean over positions 0, 2, 3
        let want = (0.95f64 + 0.5 + 0.91) / 3.0;
        assert!((f64::from(res.step_mean(0)) - want).abs() < 1e-6);
    }

    #[test]
    fn accept_rows_factor_rule_includes_max() {
        let window = [MASK, MASK, MASK];
        let out = conf_out(&[(&[0.8, 0.75, 0.1], &[7, 8, 9])]);
        let res =
            accept_rows(&out, &[&window], MASK, &[AcceptRule::factor_max(0.9)]);
        // cmax 0.8 -> cut 0.72: positions 0 and 1
        assert_eq!(res.row(0), &[(0, 7), (1, 8)]);
        assert!(!res.fell_back(0));
    }

    #[test]
    fn accept_rows_fallback_tie_breaks_low() {
        // impossible threshold + equal confidences: exactly the lowest
        // masked index commits (= policy::argmax), flagged as fallback
        let window = [5, MASK, MASK, MASK];
        let out = conf_out(&[(&[0.9, 0.5, 0.5, 0.5], &[1, 2, 3, 4])]);
        let res = accept_rows(
            &out,
            &[&window],
            MASK,
            &[AcceptRule::threshold(f32::INFINITY)],
        );
        assert_eq!(res.row(0), &[(1, 2)]);
        assert!(res.fell_back(0));
    }

    #[test]
    fn accept_rows_empty_masked_set_is_empty() {
        let window = [5u32, 6, 7];
        let out = conf_out(&[(&[0.9, 0.9, 0.9], &[1, 2, 3])]);
        let res = accept_rows(&out, &[&window], MASK, &[AcceptRule::threshold(0.1)]);
        assert_eq!(res.len(), 1);
        assert!(res.row(0).is_empty());
        assert!(!res.fell_back(0));
    }

    #[test]
    fn accept_rows_disabled_disjuncts_never_accept() {
        // a pure-threshold rule must be unaffected by any cmax, and a pure
        // factor rule by any tau — the +inf sentinels can never accept
        let window = [MASK, MASK];
        let out = conf_out(&[(&[0.4, 0.6], &[1, 2])]);
        let thr = accept_rows(&out, &[&window], MASK, &[AcceptRule::threshold(0.5)]);
        assert_eq!(thr.row(0), &[(1, 2)]);
        let fac = accept_rows(&out, &[&window], MASK, &[AcceptRule::factor_max(0.5)]);
        // cut = 0.3: both
        assert_eq!(fac.row(0), &[(0, 1), (1, 2)]);
    }

    #[test]
    fn accept_out_rows_and_append() {
        let mut a = AcceptOut::with_capacity(2);
        a.push_row(&[(0, 5)], 0.5, false);
        a.push_row(&[], 0.0, false);
        let mut b = AcceptOut::with_capacity(1);
        b.push_row(&[(1, 6), (2, 7)], 0.8, true);
        a.append(b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.row(0), &[(0, 5)]);
        assert!(a.row(1).is_empty());
        assert_eq!(a.row(2), &[(1, 6), (2, 7)]);
        assert!(a.fell_back(2));
        assert!((a.step_mean(2) - 0.8).abs() < 1e-6);
        assert!(!a.is_empty());
        assert!(AcceptOut::default().is_empty());
    }

    #[test]
    fn accept_rule_constructors_use_inf_sentinels() {
        let t = AcceptRule::threshold(0.9);
        assert_eq!(t.tau, 0.9);
        assert_eq!(t.factor, f32::INFINITY);
        let f = AcceptRule::factor_max(0.95);
        assert_eq!(f.tau, f32::INFINITY);
        assert_eq!(f.factor, 0.95);
    }
}
