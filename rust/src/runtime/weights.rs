//! Loader for the `OSDTW001` tensor container emitted by
//! `python/compile/aot.py::write_weights_bin`.
//!
//! Format (little-endian):
//!   magic    8 bytes  "OSDTW001"
//!   count    u32
//!   repeat count times:
//!     name_len u32, name bytes (utf-8)
//!     dtype    u8   (0 = f32; the only dtype this model uses)
//!     ndim     u8
//!     dims     u32 * ndim
//!     payload  f32 * prod(dims), C order

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One named tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(
            // scalars: ndim == 0 -> one element
            if self.shape.is_empty() { 1 } else { 0 },
        )
    }
}

/// All tensors in file order (which is the frozen `param_order`).
pub fn load_weights(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_weights(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse_weights(bytes: &[u8]) -> Result<Vec<Tensor>> {
    let mut r = bytes;
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("truncated magic")?;
    if &magic != b"OSDTW001" {
        bail!("bad magic {:?}", String::from_utf8_lossy(&magic));
    }
    let count = read_u32(&mut r)? as usize;
    if count > 100_000 {
        bail!("implausible tensor count {count}");
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("implausible name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name).context("truncated name")?;
        let name = String::from_utf8(name).context("name not utf-8")?;
        let mut head = [0u8; 2];
        r.read_exact(&mut head).context("truncated header")?;
        let (dtype, ndim) = (head[0], head[1] as usize);
        if dtype != 0 {
            bail!("tensor {name}: unsupported dtype code {dtype}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = if shape.is_empty() { 1 } else { shape.iter().product() };
        if n > 1 << 28 {
            bail!("tensor {name}: implausible element count {n}");
        }
        let mut payload = vec![0u8; 4 * n];
        r.read_exact(&mut payload)
            .with_context(|| format!("truncated payload for {name}"))?;
        let data: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(Tensor { name, shape, data });
    }
    if !r.is_empty() {
        bail!("{} trailing bytes after last tensor", r.len());
    }
    Ok(out)
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("truncated u32")?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mirror of the python writer, for roundtrip tests.
    pub fn write_weights(tensors: &[Tensor]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"OSDTW001");
        out.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for t in tensors {
            out.extend_from_slice(&(t.name.len() as u32).to_le_bytes());
            out.extend_from_slice(t.name.as_bytes());
            out.push(0);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &x in &t.data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    fn demo() -> Vec<Tensor> {
        vec![
            Tensor {
                name: "a".into(),
                shape: vec![2, 3],
                data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            },
            Tensor { name: "scalar".into(), shape: vec![], data: vec![7.5] },
        ]
    }

    #[test]
    fn roundtrip() {
        let bytes = write_weights(&demo());
        let back = parse_weights(&bytes).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "a");
        assert_eq!(back[0].shape, vec![2, 3]);
        assert_eq!(back[0].data, demo()[0].data);
        assert_eq!(back[1].shape, Vec::<usize>::new());
        assert_eq!(back[1].element_count(), 1);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = write_weights(&demo());
        bytes[0] = b'X';
        assert!(parse_weights(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = write_weights(&demo());
        for cut in [4, 11, 13, 20, bytes.len() - 1] {
            assert!(parse_weights(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = write_weights(&demo());
        bytes.push(0);
        assert!(parse_weights(&bytes).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let mut t = demo();
        t.truncate(1);
        let mut bytes = write_weights(&t);
        // dtype byte is right after magic+count+name_len+name
        let idx = 8 + 4 + 4 + 1;
        bytes[idx] = 9;
        assert!(parse_weights(&bytes).is_err());
    }
}
