//! Paged dual-KV storage with prompt-prefix sharing (DESIGN.md §13).
//!
//! [`PagedKvPool`] slices the per-sequence (L, H, S, Dh) dual cache along
//! the sequence axis into fixed-length **pages** (L, H, page_len, Dh),
//! held in refcounted pool slots. A sequence's cache becomes a
//! [`PageTable`] — an ordered list of page ids — instead of two owned
//! whole-sequence buffers, so identical content can be *shared by
//! reference*:
//!
//! - [`SharedKv`] keys a prefix index by the hash of a sequence's full
//!   token layout at its first block-boundary refresh. At that point the
//!   layout is `prompt ‖ all-[MASK] gen region`, byte-identical across
//!   requests with the same prompt, so the refreshed K/V (and its
//!   conf/argmax rows) are byte-identical too — a hit reuses the stored
//!   pages and skips the `fwd_full_kv` pass entirely.
//! - Shared pages are immutable. A hit clones the template's page table
//!   by reference and **copy-on-write splits exactly one page**: the
//!   first decode page (the page containing the first gen position),
//!   which is where any in-block cache update would land. Later refreshes
//!   mint fresh tables, so divergence after block 0 never aliases.
//!
//! Page slots live behind one mutex; refcounts drop pages back onto a
//! free list the moment their last table releases them (retirement,
//! block rollover, index eviction). The pool is capacity-bounded —
//! exhaustion is a loud error (docs/RUNBOOK.md "Page-pool exhaustion"),
//! never a silent eviction of live pages.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::handle::KvCache;

/// Cumulative paged-pool counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagedStats {
    /// Page slots ever allocated (fresh, not free-list reuses).
    pub pages_allocated: u64,
    /// Pages returned to the free list by their last reference.
    pub pages_freed: u64,
    /// Copy-on-write splits of shared pages.
    pub cow_splits: u64,
    /// Failed allocations (pool at capacity).
    pub exhausted: u64,
    /// Pages currently referenced by at least one table.
    pub pages_in_use: usize,
}

struct Slot {
    k: Vec<f32>,
    v: Vec<f32>,
    refs: u32,
}

struct SlotsInner {
    slots: Vec<Slot>,
    free: Vec<u32>,
}

struct PagedInner {
    /// Per-sequence cache shape (layers, heads, seq, head_dim).
    dims: [usize; 4],
    /// Sequence positions per page.
    page_len: usize,
    /// Hard cap on live + free page slots.
    max_pages: usize,
    slots: Mutex<SlotsInner>,
    pages_allocated: AtomicU64,
    pages_freed: AtomicU64,
    cow_splits: AtomicU64,
    exhausted: AtomicU64,
}

impl PagedInner {
    /// f32 elements per page side (k or v).
    fn page_side_len(&self) -> usize {
        self.dims[0] * self.dims[1] * self.page_len * self.dims[3]
    }

    fn n_pages(&self) -> usize {
        self.dims[2].div_ceil(self.page_len)
    }

    /// Allocate one page slot (zeroed free-list reuse or fresh), with the
    /// slots lock held.
    fn alloc_locked(&self, g: &mut SlotsInner) -> Result<u32> {
        if let Some(id) = g.free.pop() {
            g.slots[id as usize].refs = 1;
            return Ok(id);
        }
        if g.slots.len() >= self.max_pages {
            self.exhausted.fetch_add(1, Ordering::Relaxed);
            bail!(
                "paged KV pool exhausted ({} pages, max {}) — see \
                 docs/RUNBOOK.md \"Page-pool exhaustion\"",
                g.slots.len(),
                self.max_pages
            );
        }
        let n = self.page_side_len();
        g.slots.push(Slot { k: vec![0.0; n], v: vec![0.0; n], refs: 1 });
        self.pages_allocated.fetch_add(1, Ordering::Relaxed);
        Ok((g.slots.len() - 1) as u32)
    }

    fn unref_locked(&self, g: &mut SlotsInner, id: u32) {
        let slot = &mut g.slots[id as usize];
        debug_assert!(slot.refs > 0, "unref of a free page");
        slot.refs -= 1;
        if slot.refs == 0 {
            g.free.push(id);
            self.pages_freed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Refcounted fixed-page KV storage shared across sequences. Cheap to
/// clone (`Arc` inside); every [`PageTable`] keeps its pool alive.
#[derive(Clone)]
pub struct PagedKvPool {
    inner: Arc<PagedInner>,
}

impl std::fmt::Debug for PagedKvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("PagedKvPool")
            .field("dims", &self.inner.dims)
            .field("page_len", &self.inner.page_len)
            .field("pages_in_use", &s.pages_in_use)
            .finish()
    }
}

impl PagedKvPool {
    /// `dims` is the per-sequence cache shape (layers, heads, seq,
    /// head_dim); `page_len` the sequence positions per page (clamped to
    /// `[1, seq]`); `max_pages` bounds total slots.
    pub fn new(dims: [usize; 4], page_len: usize, max_pages: usize) -> PagedKvPool {
        let page_len = page_len.clamp(1, dims[2].max(1));
        PagedKvPool {
            inner: Arc::new(PagedInner {
                dims,
                page_len,
                max_pages,
                slots: Mutex::new(SlotsInner { slots: Vec::new(), free: Vec::new() }),
                pages_allocated: AtomicU64::new(0),
                pages_freed: AtomicU64::new(0),
                cow_splits: AtomicU64::new(0),
                exhausted: AtomicU64::new(0),
            }),
        }
    }

    pub fn dims(&self) -> [usize; 4] {
        self.inner.dims
    }

    pub fn page_len(&self) -> usize {
        self.inner.page_len
    }

    /// Pages per sequence table (`ceil(seq / page_len)`).
    pub fn pages_per_seq(&self) -> usize {
        self.inner.n_pages()
    }

    pub fn stats(&self) -> PagedStats {
        let i = &self.inner;
        let in_use = {
            let g = i.slots.lock().unwrap();
            g.slots.len() - g.free.len()
        };
        PagedStats {
            pages_allocated: i.pages_allocated.load(Ordering::Relaxed),
            pages_freed: i.pages_freed.load(Ordering::Relaxed),
            cow_splits: i.cow_splits.load(Ordering::Relaxed),
            exhausted: i.exhausted.load(Ordering::Relaxed),
            pages_in_use: in_use,
        }
    }

    /// Split a contiguous whole-sequence cache into a fresh page table.
    /// The last page's tail (when `seq % page_len != 0`) stays zero and is
    /// never read back.
    pub fn paginate(&self, kv: &KvCache) -> Result<PageTable> {
        let i = &self.inner;
        if kv.dims != i.dims {
            bail!("paginate dims {:?} != pool dims {:?}", kv.dims, i.dims);
        }
        let want: usize = i.dims.iter().product();
        if kv.k.len() != want || kv.v.len() != want {
            bail!("paginate payload {} != {want}", kv.k.len());
        }
        let [l, h, s, dh] = i.dims;
        let p = i.page_len;
        let mut g = i.slots.lock().unwrap();
        let mut pages = Vec::with_capacity(i.n_pages());
        for pi in 0..i.n_pages() {
            let id = match i.alloc_locked(&mut g) {
                Ok(id) => id,
                Err(e) => {
                    // roll back partial allocation before surfacing
                    for &id in &pages {
                        i.unref_locked(&mut g, id);
                    }
                    return Err(e);
                }
            };
            let s0 = pi * p;
            let cur = p.min(s - s0);
            let slot = &mut g.slots[id as usize];
            for li in 0..l {
                for hi in 0..h {
                    let src = ((li * h + hi) * s + s0) * dh;
                    let dst = (li * h + hi) * p * dh;
                    slot.k[dst..dst + cur * dh]
                        .copy_from_slice(&kv.k[src..src + cur * dh]);
                    slot.v[dst..dst + cur * dh]
                        .copy_from_slice(&kv.v[src..src + cur * dh]);
                }
            }
            pages.push(id);
        }
        drop(g);
        Ok(PageTable { pool: i.clone(), pages })
    }
}

/// One sequence's cache as an ordered list of refcounted page ids.
/// Cloning shares every page by reference; dropping releases them.
pub struct PageTable {
    pool: Arc<PagedInner>,
    pages: Vec<u32>,
}

impl std::fmt::Debug for PageTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PageTable")
            .field("pages", &self.pages)
            .finish()
    }
}

impl Clone for PageTable {
    fn clone(&self) -> Self {
        let mut g = self.pool.slots.lock().unwrap();
        for &id in &self.pages {
            g.slots[id as usize].refs += 1;
        }
        drop(g);
        PageTable { pool: self.pool.clone(), pages: self.pages.clone() }
    }
}

impl Drop for PageTable {
    fn drop(&mut self) {
        let mut g = self.pool.slots.lock().unwrap();
        for &id in &self.pages {
            self.pool.unref_locked(&mut g, id);
        }
    }
}

impl PageTable {
    pub fn dims(&self) -> [usize; 4] {
        self.pool.dims
    }

    pub fn page_len(&self) -> usize {
        self.pool.page_len
    }

    pub fn len(&self) -> usize {
        self.pages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pages.is_empty()
    }

    /// How many tables currently reference page `idx` (test/debug).
    pub fn page_refs(&self, idx: usize) -> u32 {
        let g = self.pool.slots.lock().unwrap();
        g.slots[self.pages[idx] as usize].refs
    }

    /// Ensure page `idx` is privately owned: if shared, allocate a fresh
    /// page, copy the contents, and swap it in (releasing the shared
    /// original). Returns whether a split happened. The serving path
    /// splits exactly one page per prefix hit — the first decode page.
    pub fn cow_split(&mut self, idx: usize) -> Result<bool> {
        let id = self.pages[idx];
        let mut g = self.pool.slots.lock().unwrap();
        if g.slots[id as usize].refs == 1 {
            return Ok(false);
        }
        let fresh = self.pool.alloc_locked(&mut g)?;
        // two-index split borrow: fresh was just allocated, so ids differ
        let (a, b) = (id as usize, fresh as usize);
        debug_assert_ne!(a, b);
        let (k_src, v_src) = {
            let s = &g.slots[a];
            (s.k.clone(), s.v.clone())
        };
        g.slots[b].k.copy_from_slice(&k_src);
        g.slots[b].v.copy_from_slice(&v_src);
        self.pool.unref_locked(&mut g, id);
        drop(g);
        self.pages[idx] = fresh;
        self.pool.cow_splits.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// Mutate page `idx` in place. Refuses shared pages — callers must
    /// [`PageTable::cow_split`] first, which is what keeps "no stale rows
    /// after a COW split" structurally true.
    pub fn patch(&self, idx: usize, f: impl FnOnce(&mut [f32], &mut [f32])) -> Result<()> {
        let id = self.pages[idx] as usize;
        let mut g = self.pool.slots.lock().unwrap();
        let slot = &mut g.slots[id];
        if slot.refs != 1 {
            bail!("patch of a shared page (refs {}); cow_split first", slot.refs);
        }
        f(&mut slot.k, &mut slot.v);
        Ok(())
    }

    /// Write this table's cache back as contiguous (L, H, S, Dh) rows —
    /// the staging primitive the runtime uses to stack page tables
    /// directly into a batched upload without intermediate whole-sequence
    /// buffers. `k_out`/`v_out` must each hold exactly `L*H*S*Dh` floats.
    pub fn copy_into(&self, k_out: &mut [f32], v_out: &mut [f32]) -> Result<()> {
        let [l, h, s, dh] = self.pool.dims;
        let want = l * h * s * dh;
        if k_out.len() != want || v_out.len() != want {
            bail!("copy_into target {} != {want}", k_out.len());
        }
        let p = self.pool.page_len;
        let g = self.pool.slots.lock().unwrap();
        for (pi, &id) in self.pages.iter().enumerate() {
            let s0 = pi * p;
            let cur = p.min(s - s0);
            let slot = &g.slots[id as usize];
            for li in 0..l {
                for hi in 0..h {
                    let dst = ((li * h + hi) * s + s0) * dh;
                    let src = (li * h + hi) * p * dh;
                    k_out[dst..dst + cur * dh]
                        .copy_from_slice(&slot.k[src..src + cur * dh]);
                    v_out[dst..dst + cur * dh]
                        .copy_from_slice(&slot.v[src..src + cur * dh]);
                }
            }
        }
        Ok(())
    }

    /// Materialize a contiguous host copy (batch-1 upload path, tests).
    pub fn assemble(&self) -> KvCache {
        let n: usize = self.pool.dims.iter().product();
        let mut kv = KvCache { k: vec![0.0; n], v: vec![0.0; n], dims: self.pool.dims };
        self.copy_into(&mut kv.k, &mut kv.v)
            .expect("sized to dims above");
        kv
    }
}

/// Hash of a full token layout — the prefix-index key. Taken at the first
/// block-boundary refresh, where the layout is `prompt ‖ all-[MASK]`, so
/// equal hashes ⇒ byte-identical model input ⇒ identical refresh output.
pub fn layout_hash(tokens: &[u32]) -> u64 {
    let mut h = DefaultHasher::new();
    tokens.hash(&mut h);
    h.finish()
}

struct PrefixEntry {
    table: PageTable,
    conf: Vec<f32>,
    argmax: Vec<u32>,
}

/// Everything a prefix hit needs to stand in for a `fwd_full_kv` call:
/// the shared page table (first decode page already COW-split) plus the
/// stored conf/argmax rows of the identical refresh.
pub struct PrefixHit {
    pub table: PageTable,
    pub conf: Vec<f32>,
    pub argmax: Vec<u32>,
    /// Pages reused by reference (table length minus the COW'd page).
    pub shared_pages: usize,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SharedKvStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub pool: PagedStats,
}

struct SharedInner {
    pool: PagedKvPool,
    /// First gen-region position — the page containing it is the COW page.
    prompt_len: usize,
    /// Bound on distinct templates retained.
    cap: usize,
    index: Mutex<HashMap<u64, PrefixEntry>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// The content-keyed prompt-prefix index + its paged pool. Cheap to clone
/// (`Arc` inside); share one instance across an engine's schedulers for
/// cross-request sharing.
#[derive(Clone)]
pub struct SharedKv {
    inner: Arc<SharedInner>,
}

impl std::fmt::Debug for SharedKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("SharedKv")
            .field("entries", &s.entries)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .finish()
    }
}

/// Default cap on retained prefix entries (distinct templates).
pub const PREFIX_INDEX_CAP: usize = 256;

impl SharedKv {
    /// `dims` per-sequence cache shape; `prompt_len` the first gen
    /// position; `page_len` / `max_pages` size the underlying pool.
    pub fn new(
        dims: [usize; 4],
        prompt_len: usize,
        page_len: usize,
        max_pages: usize,
    ) -> SharedKv {
        SharedKv {
            inner: Arc::new(SharedInner {
                pool: PagedKvPool::new(dims, page_len, max_pages),
                prompt_len,
                cap: PREFIX_INDEX_CAP,
                index: Mutex::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
            }),
        }
    }

    pub fn pool(&self) -> &PagedKvPool {
        &self.inner.pool
    }

    /// Index of the page containing the first decode position.
    fn first_decode_page(&self) -> usize {
        self.inner.prompt_len / self.inner.pool.page_len()
    }

    /// Whether a layout is already indexed (admission-time probe; no
    /// pages are touched).
    pub fn contains(&self, tokens: &[u32]) -> bool {
        self.inner
            .index
            .lock()
            .unwrap()
            .contains_key(&layout_hash(tokens))
    }

    /// Look the layout up; a hit returns shared pages (COW-split at the
    /// first decode page) plus the stored conf/argmax rows. A miss — or a
    /// hit the pool cannot COW (exhaustion) — returns `None` and counts.
    pub fn probe(&self, tokens: &[u32]) -> Option<PrefixHit> {
        let i = &self.inner;
        let (mut table, conf, argmax) = {
            let g = i.index.lock().unwrap();
            match g.get(&layout_hash(tokens)) {
                Some(e) => (e.table.clone(), e.conf.clone(), e.argmax.clone()),
                None => {
                    i.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            }
        };
        let split = match table.cow_split(self.first_decode_page()) {
            Ok(s) => s,
            Err(_) => {
                // pool exhausted mid-hit: fall back to a plain refresh
                i.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        i.hits.fetch_add(1, Ordering::Relaxed);
        let shared_pages = table.len() - usize::from(split);
        Some(PrefixHit { table, conf, argmax, shared_pages })
    }

    /// Publish a refresh's output for followers: paginate the host KV,
    /// store `(pages, conf, argmax)` under the layout hash, and return a
    /// table sharing those pages for the inserting sequence itself. `None`
    /// when the index is at capacity or the pool cannot hold the pages —
    /// the caller keeps its original handle and nothing is shared.
    pub fn insert(
        &self,
        tokens: &[u32],
        conf: &[f32],
        argmax: &[u32],
        kv: &KvCache,
    ) -> Option<PageTable> {
        let i = &self.inner;
        let key = layout_hash(tokens);
        {
            let g = i.index.lock().unwrap();
            if g.len() >= i.cap && !g.contains_key(&key) {
                return None;
            }
        }
        let table = i.pool.paginate(kv).ok()?;
        let entry = PrefixEntry {
            table: table.clone(),
            conf: conf.to_vec(),
            argmax: argmax.to_vec(),
        };
        i.index.lock().unwrap().insert(key, entry);
        Some(table)
    }

    pub fn stats(&self) -> SharedKvStats {
        let i = &self.inner;
        SharedKvStats {
            hits: i.hits.load(Ordering::Relaxed),
            misses: i.misses.load(Ordering::Relaxed),
            entries: i.index.lock().unwrap().len(),
            pool: i.pool.stats(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 4] = [2, 2, 10, 3];

    fn kv(fill: f32) -> KvCache {
        let n: usize = DIMS.iter().product();
        let k: Vec<f32> = (0..n).map(|i| fill + i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| -fill - i as f32).collect();
        KvCache { k, v, dims: DIMS }
    }

    #[test]
    fn paginate_assemble_roundtrip() {
        // page_len 4 over seq 10: pages of 4, 4, 2 — the ragged tail must
        // survive the round trip
        let pool = PagedKvPool::new(DIMS, 4, 64);
        assert_eq!(pool.pages_per_seq(), 3);
        let src = kv(1.0);
        let table = pool.paginate(&src).unwrap();
        assert_eq!(table.len(), 3);
        let back = table.assemble();
        assert_eq!(back.k, src.k);
        assert_eq!(back.v, src.v);
        assert_eq!(pool.stats().pages_in_use, 3);
    }

    #[test]
    fn drop_reclaims_pages_on_retirement() {
        let pool = PagedKvPool::new(DIMS, 4, 64);
        let t1 = pool.paginate(&kv(1.0)).unwrap();
        let t2 = pool.paginate(&kv(2.0)).unwrap();
        assert_eq!(pool.stats().pages_in_use, 6);
        drop(t1);
        let s = pool.stats();
        assert_eq!(s.pages_in_use, 3);
        assert_eq!(s.pages_freed, 3);
        // freed slots are reused, not re-allocated
        let t3 = pool.paginate(&kv(3.0)).unwrap();
        assert_eq!(pool.stats().pages_allocated, 6, "free list reused");
        assert_eq!(pool.stats().pages_in_use, 6);
        drop((t2, t3));
        assert_eq!(pool.stats().pages_in_use, 0);
    }

    #[test]
    fn no_page_freed_while_shared() {
        let pool = PagedKvPool::new(DIMS, 4, 64);
        let t1 = pool.paginate(&kv(1.0)).unwrap();
        let t2 = t1.clone();
        assert_eq!(t1.page_refs(0), 2);
        drop(t1);
        // t2 still owns every page: nothing may hit the free list
        let s = pool.stats();
        assert_eq!(s.pages_freed, 0);
        assert_eq!(s.pages_in_use, 3);
        assert_eq!(t2.assemble().k, kv(1.0).k, "shared pages intact");
        drop(t2);
        assert_eq!(pool.stats().pages_in_use, 0);
    }

    #[test]
    fn cow_split_leaves_no_stale_rows() {
        let pool = PagedKvPool::new(DIMS, 4, 64);
        let src = kv(1.0);
        let base = pool.paginate(&src).unwrap();
        let mut fork = base.clone();
        assert!(fork.cow_split(1).unwrap(), "shared page must split");
        assert_eq!(base.page_refs(1), 1, "original page released by the fork");
        assert_eq!(fork.page_refs(1), 1, "fork owns a private copy");
        // the private copy starts content-identical...
        assert_eq!(fork.assemble().k, src.k);
        // ...and mutating it must not leak into the template
        fork.patch(1, |k, _v| k[0] = 999.0).unwrap();
        assert_eq!(base.assemble().k, src.k, "template sees no stale rows");
        assert_eq!(fork.assemble().k[4 * DIMS[3]], 999.0);
        // splitting an already-private page is a no-op
        assert!(!fork.cow_split(1).unwrap());
    }

    #[test]
    fn patch_refuses_shared_pages() {
        let pool = PagedKvPool::new(DIMS, 4, 64);
        let t1 = pool.paginate(&kv(1.0)).unwrap();
        let _t2 = t1.clone();
        assert!(t1.patch(0, |_, _| {}).is_err(), "shared pages are immutable");
    }

    #[test]
    fn exhaustion_fails_loudly_and_rolls_back() {
        let pool = PagedKvPool::new(DIMS, 4, 4);
        let t1 = pool.paginate(&kv(1.0)).unwrap(); // 3 of 4 pages
        let err = pool.paginate(&kv(2.0)).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        assert!(err.to_string().contains("RUNBOOK"), "{err}");
        // partial allocation rolled back: only t1's pages remain live
        assert_eq!(pool.stats().pages_in_use, 3);
        assert_eq!(pool.stats().exhausted, 1);
        drop(t1);
        assert!(pool.paginate(&kv(3.0)).is_ok(), "recovers after release");
    }

    #[test]
    fn prefix_probe_shares_and_cows() {
        // prompt_len 5, page_len 4 => first decode page is index 1
        let shared = SharedKv::new(DIMS, 5, 4, 64);
        let layout: Vec<u32> = (0..10).collect();
        assert!(shared.probe(&layout).is_none(), "cold index misses");
        let conf = vec![0.5; 10];
        let argmax = vec![7u32; 10];
        let table = shared.insert(&layout, &conf, &argmax, &kv(4.0)).unwrap();
        assert!(shared.contains(&layout));
        let hit = shared.probe(&layout).expect("indexed layout hits");
        assert_eq!(hit.conf, conf);
        assert_eq!(hit.argmax, argmax);
        assert_eq!(hit.shared_pages, 2, "3 pages minus the COW'd decode page");
        assert_eq!(hit.table.page_refs(0), 3, "entry + inserter + hit");
        assert_eq!(hit.table.page_refs(1), 1, "decode page privately owned");
        assert_eq!(hit.table.assemble().k, kv(4.0).k, "hit sees template KV");
        // different layout: miss
        let other: Vec<u32> = (1..11).collect();
        assert!(shared.probe(&other).is_none());
        let s = shared.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
        drop((table, hit));
    }

    #[test]
    fn probe_survives_pool_exhaustion() {
        // pool sized so the entry fits but the hit's COW page does not
        let shared = SharedKv::new(DIMS, 5, 4, 3);
        let layout: Vec<u32> = (0..10).collect();
        shared
            .insert(&layout, &[0.5; 10], &[1u32; 10], &kv(1.0))
            .unwrap();
        assert!(shared.probe(&layout).is_none(), "COW alloc fails => miss");
        assert_eq!(shared.stats().pool.exhausted, 1);
    }
}
