//! [`CachePool`]: recycles dual-KV-cache storage across block rollovers and
//! retired sequences (DESIGN.md §10).
//!
//! Every [`CacheHandle`] minted through a pool returns its storage here on
//! drop. Host-resident storage (two `Vec<f32>`s per sequence, each
//! layers × heads × seq × head_dim floats) is handed back out for the next
//! `fwd_full_kv` download — the dominant transient allocation of the
//! host-residency path. Device-resident buffer pairs are retained for reuse
//! as padding rows of the stacked `kv_gather` pass (a padding row needs
//! *some* cache-shaped device buffer; its output row is dropped, so any
//! retired cache serves — without it the runtime would have to upload a
//! zeros tensor, putting a host transfer back on the step path).
//!
//! Free lists are capacity-bounded; reclaims beyond capacity (or with
//! mismatched dims) are dropped to the allocator. All counters are atomic —
//! the pool is shared across a runtime's handles via `Arc` and may see
//! drops from any thread that owned a task.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::handle::{CacheHandle, CacheStorage, DeviceKv, KvCache};

/// Pool observability counters (cumulative).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Handles minted, by residency.
    pub minted_host: u64,
    pub minted_device: u64,
    /// Storage returned by dropped handles and kept on a free list.
    pub reclaimed_host: u64,
    pub reclaimed_device: u64,
    /// Reclaimed storage handed back out (host: refresh downloads;
    /// device: gather padding rows).
    pub reused_host: u64,
    pub reused_device: u64,
    /// Reclaims dropped to the allocator (capacity or dims mismatch).
    pub dropped: u64,
}

#[derive(Debug)]
pub(crate) struct PoolInner {
    dims: [usize; 4],
    capacity: usize,
    host_free: Mutex<Vec<KvCache>>,
    device_free: Mutex<Vec<DeviceKv>>,
    minted_host: AtomicU64,
    minted_device: AtomicU64,
    reclaimed_host: AtomicU64,
    reclaimed_device: AtomicU64,
    reused_host: AtomicU64,
    reused_device: AtomicU64,
    dropped: AtomicU64,
}

impl PoolInner {
    pub(crate) fn reclaim(&self, storage: CacheStorage) {
        match storage {
            CacheStorage::Host(kv) => {
                let mut free = self.host_free.lock().unwrap();
                if kv.dims == self.dims && free.len() < self.capacity {
                    free.push(kv);
                    self.reclaimed_host.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            CacheStorage::Device(d) => {
                let mut free = self.device_free.lock().unwrap();
                if d.dims == self.dims && free.len() < self.capacity {
                    free.push(d);
                    self.reclaimed_device.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            // paged storage self-reclaims via PageTable::drop (page refs);
            // it is never minted with a whole-buffer pool link
            CacheStorage::Paged(table) => {
                drop(table);
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Per-runtime recycler of dual-KV-cache storage. Cheap to clone (shared
/// `Arc`); one instance per forward model, shared by every handle it mints.
#[derive(Clone, Debug)]
pub struct CachePool {
    inner: Arc<PoolInner>,
}

impl CachePool {
    /// `dims` is the per-sequence cache shape (layers, heads, seq,
    /// head_dim); `capacity` bounds each free list.
    pub fn new(dims: [usize; 4], capacity: usize) -> CachePool {
        CachePool {
            inner: Arc::new(PoolInner {
                dims,
                capacity,
                host_free: Mutex::new(Vec::new()),
                device_free: Mutex::new(Vec::new()),
                minted_host: AtomicU64::new(0),
                minted_device: AtomicU64::new(0),
                reclaimed_host: AtomicU64::new(0),
                reclaimed_device: AtomicU64::new(0),
                reused_host: AtomicU64::new(0),
                reused_device: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    pub fn dims(&self) -> [usize; 4] {
        self.inner.dims
    }

    /// Host k/v storage for the next refresh download: a recycled pair
    /// (cleared, capacity retained) when one is free, else fresh vectors.
    pub fn take_host_storage(&self) -> KvCache {
        if let Some(mut kv) = self.inner.host_free.lock().unwrap().pop() {
            self.inner.reused_host.fetch_add(1, Ordering::Relaxed);
            kv.k.clear();
            kv.v.clear();
            return kv;
        }
        let n: usize = self.inner.dims.iter().product();
        KvCache {
            k: Vec::with_capacity(n),
            v: Vec::with_capacity(n),
            dims: self.inner.dims,
        }
    }

    /// Mint a pooled host-resident handle.
    pub fn wrap_host(&self, kv: KvCache) -> CacheHandle {
        debug_assert_eq!(kv.dims, self.inner.dims, "pool wraps one cache shape");
        self.inner.minted_host.fetch_add(1, Ordering::Relaxed);
        CacheHandle::new(CacheStorage::Host(kv), Some(self.inner.clone()))
    }

    /// Mint a pooled device-resident handle over retained buffers.
    pub fn wrap_device(&self, k: xla::PjRtBuffer, v: xla::PjRtBuffer) -> CacheHandle {
        self.inner.minted_device.fetch_add(1, Ordering::Relaxed);
        CacheHandle::new(
            CacheStorage::Device(DeviceKv { k, v, dims: self.inner.dims }),
            Some(self.inner.clone()),
        )
    }

    /// Borrow a retired device pair, for use as a stacked-gather padding
    /// row (its output row is dropped, so stale contents are harmless).
    /// Return it with [`CachePool::restore_device_pair`] once the pass is
    /// issued — otherwise padded batches would drain the retained set.
    pub fn take_device_pair(&self) -> Option<DeviceKv> {
        let d = self.inner.device_free.lock().unwrap().pop()?;
        self.inner.reused_device.fetch_add(1, Ordering::Relaxed);
        Some(d)
    }

    /// Hand back a pair borrowed via [`CachePool::take_device_pair`]
    /// (capacity- and dims-checked like any reclaim).
    pub fn restore_device_pair(&self, d: DeviceKv) {
        self.inner.reclaim(CacheStorage::Device(d));
    }

    /// Free-list depths (host, device) — test/debug visibility.
    pub fn free_len(&self) -> (usize, usize) {
        (
            self.inner.host_free.lock().unwrap().len(),
            self.inner.device_free.lock().unwrap().len(),
        )
    }

    pub fn stats(&self) -> PoolStats {
        let i = &self.inner;
        PoolStats {
            minted_host: i.minted_host.load(Ordering::Relaxed),
            minted_device: i.minted_device.load(Ordering::Relaxed),
            reclaimed_host: i.reclaimed_host.load(Ordering::Relaxed),
            reclaimed_device: i.reclaimed_device.load(Ordering::Relaxed),
            reused_host: i.reused_host.load(Ordering::Relaxed),
            reused_device: i.reused_device.load(Ordering::Relaxed),
            dropped: i.dropped.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [usize; 4] = [2, 2, 8, 4];

    fn filled(pool: &CachePool, fill: f32) -> KvCache {
        let mut kv = pool.take_host_storage();
        let n: usize = DIMS.iter().product();
        kv.k.resize(n, fill);
        kv.v.resize(n, -fill);
        kv
    }

    #[test]
    fn dropped_handle_recycles_host_storage() {
        let pool = CachePool::new(DIMS, 4);
        let h = pool.wrap_host(filled(&pool, 1.0));
        assert_eq!(pool.free_len(), (0, 0));
        drop(h);
        assert_eq!(pool.free_len(), (1, 0));
        let kv = pool.take_host_storage();
        assert!(kv.k.is_empty(), "recycled storage must come back cleared");
        assert!(kv.k.capacity() >= DIMS.iter().product());
        let s = pool.stats();
        assert_eq!((s.reclaimed_host, s.reused_host), (1, 1));
    }

    #[test]
    fn capacity_bounds_the_free_list() {
        let pool = CachePool::new(DIMS, 1);
        let a = pool.wrap_host(filled(&pool, 1.0));
        let b = pool.wrap_host(filled(&pool, 2.0));
        drop(a);
        drop(b);
        assert_eq!(pool.free_len(), (1, 0));
        assert_eq!(pool.stats().dropped, 1);
    }

    #[test]
    fn dims_mismatch_is_dropped_not_pooled() {
        let pool = CachePool::new(DIMS, 4);
        let other = KvCache { k: vec![0.0; 4], v: vec![0.0; 4], dims: [1, 1, 4, 1] };
        pool.inner.reclaim(CacheStorage::Host(other));
        assert_eq!(pool.free_len(), (0, 0));
        assert_eq!(pool.stats().dropped, 1);
        // unpooled handles never touch a pool
        drop(CacheHandle::host(filled(&pool, 3.0)));
        assert_eq!(pool.free_len(), (0, 0));
    }

    #[test]
    fn device_pairs_recycle_for_padding() {
        let pool = CachePool::new(DIMS, 4);
        let client = xla::PjRtClient::cpu().unwrap();
        let n: usize = DIMS.iter().product();
        let buf = |x: f32| {
            client
                .buffer_from_host_buffer::<f32>(&vec![x; n], &DIMS, None)
                .unwrap()
        };
        assert!(pool.take_device_pair().is_none());
        let h = pool.wrap_device(buf(1.0), buf(2.0));
        assert_eq!(h.residency(), crate::cache::Residency::Device);
        drop(h);
        assert_eq!(pool.free_len(), (0, 1));
        let pair = pool.take_device_pair().unwrap();
        assert_eq!(pair.k.dims(), &DIMS);
        assert!(pool.take_device_pair().is_none());
        let s = pool.stats();
        assert_eq!((s.reclaimed_device, s.reused_device), (1, 1));
        // borrowed pairs come back: padded batches must not drain the set
        pool.restore_device_pair(pair);
        assert_eq!(pool.free_len(), (0, 1));
        assert_eq!(pool.stats().reclaimed_device, 2);
    }
}
