//! Fast-dLLM dual KV-cache management: configuration (when to refresh),
//! residency ([`handle`] — where K/V lives between refreshes, DESIGN.md
//! §10), storage recycling ([`pool`]), accounting (passes, analytic FLOPs
//! saved), and the cost model used in EXPERIMENTS.md to report the cache's
//! effect independently of CPU noise.
//!
//! Mechanism recap (Fast-dLLM "DualCache"): at each block boundary a full
//! forward refreshes K/V for *all* positions (prefix and suffix — suffix
//! K/V of still-masked future blocks change slowly); within the block, only
//! the active `block_len` window is recomputed, attending against the
//! cached K/V. Optionally the cache can be re-refreshed every
//! `refresh_interval` window steps to bound staleness (an ablation knob;
//! the paper's baseline uses block-boundary refresh only).

pub mod handle;
pub mod paged;
pub mod pool;

pub use handle::{CacheHandle, DeviceKv, KvCache, Residency};
pub use paged::{PageTable, PagedKvPool, PagedStats, PrefixHit, SharedKv, SharedKvStats};
pub use pool::{CachePool, PoolStats};

use crate::model::ModelConfig;

/// Default paged-pool capacity when prefix sharing is enabled (page
/// slots, not sequences — see docs/RUNBOOK.md "Page-pool exhaustion").
pub const DEFAULT_MAX_KV_PAGES: usize = 4096;

/// Cache behaviour for the decode engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheConfig {
    pub enabled: bool,
    /// If > 0: force a full refresh after this many consecutive window
    /// steps within a block. 0 = refresh at block boundaries only.
    pub refresh_interval: usize,
    /// Sequence positions per KV page for the paged pool (DESIGN.md §13).
    /// 0 = whole-sequence handles only (legacy layout, no paging).
    pub kv_page_len: usize,
    /// Share block-0 refresh output (pages + conf/argmax) across requests
    /// with an identical prompt layout. Requires `kv_page_len > 0`.
    pub prefix_sharing: bool,
}

impl CacheConfig {
    pub fn disabled() -> Self {
        CacheConfig {
            enabled: false,
            refresh_interval: 0,
            kv_page_len: 0,
            prefix_sharing: false,
        }
    }

    pub fn block_boundary() -> Self {
        CacheConfig { enabled: true, ..CacheConfig::disabled() }
    }

    pub fn with_refresh_interval(n: usize) -> Self {
        CacheConfig { refresh_interval: n, ..CacheConfig::block_boundary() }
    }

    /// Builder: set the KV page length (0 disables paging).
    pub fn paged(mut self, page_len: usize) -> Self {
        self.kv_page_len = page_len;
        self
    }

    /// Builder: toggle prompt-prefix sharing (defaults the page length
    /// when paging wasn't sized explicitly).
    pub fn with_prefix_sharing(mut self, on: bool) -> Self {
        self.prefix_sharing = on;
        if on && self.kv_page_len == 0 {
            self.kv_page_len = 16;
        }
        self
    }

    /// Prefix sharing is active only with the cache on and pages sized.
    pub fn sharing_active(&self) -> bool {
        self.enabled && self.prefix_sharing && self.kv_page_len > 0
    }
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig::disabled()
    }
}

/// Analytic FLOP model of the two forward variants (used for the cache
/// ablation and the §Perf roofline discussion; counts multiply-adds as 2).
pub fn flops_full(cfg: &ModelConfig) -> f64 {
    let s = cfg.seq_len as f64;
    let d = cfg.d_model as f64;
    let ff = cfg.d_ff as f64;
    let v = cfg.vocab_size as f64;
    let l = cfg.n_layers as f64;
    // per layer: qkv+out projections (4*d^2) + mlp (2*d*ff); attention
    // scores+mix: 4*s*d per query row
    let per_tok = l * (2.0 * 4.0 * d * d + 2.0 * 2.0 * d * ff + 2.0 * 2.0 * s * d);
    s * (per_tok + 2.0 * d * v)
}

/// Window pass: only `block_len` query rows, but attention still spans the
/// full cached sequence.
pub fn flops_window(cfg: &ModelConfig) -> f64 {
    let s = cfg.seq_len as f64;
    let w = cfg.block_len as f64;
    let d = cfg.d_model as f64;
    let ff = cfg.d_ff as f64;
    let v = cfg.vocab_size as f64;
    let l = cfg.n_layers as f64;
    let per_tok = l * (2.0 * 4.0 * d * d + 2.0 * 2.0 * d * ff + 2.0 * 2.0 * s * d);
    w * (per_tok + 2.0 * d * v)
}

/// Pass accounting for one decode (or an aggregated run).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub full_passes: u64,
    pub window_passes: u64,
}

impl CacheStats {
    pub fn add_decode(&mut self, full: usize, window: usize) {
        self.full_passes += full as u64;
        self.window_passes += window as u64;
    }

    /// Total analytic FLOPs under this pass mix.
    pub fn total_flops(&self, cfg: &ModelConfig) -> f64 {
        self.full_passes as f64 * flops_full(cfg)
            + self.window_passes as f64 * flops_window(cfg)
    }

    /// FLOPs if every pass had been a full forward (the no-cache cost of
    /// the same number of policy steps).
    pub fn nocache_flops(&self, cfg: &ModelConfig) -> f64 {
        (self.full_passes + self.window_passes) as f64 * flops_full(cfg)
    }

    /// Fraction of forward-pass compute the cache eliminated.
    pub fn savings(&self, cfg: &ModelConfig) -> f64 {
        let base = self.nocache_flops(cfg);
        if base == 0.0 {
            0.0
        } else {
            1.0 - self.total_flops(cfg) / base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::fixtures::tiny_config;

    #[test]
    fn window_cheaper_than_full() {
        let cfg = tiny_config();
        let full = flops_full(&cfg);
        let win = flops_window(&cfg);
        assert!(win < full);
        // ratio should be ~ block_len / seq_len = 0.2 for this geometry
        let ratio = win / full;
        assert!((0.15..0.3).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn stats_savings() {
        let cfg = tiny_config();
        let mut st = CacheStats::default();
        st.add_decode(3, 27); // 3 blocks refreshed, 27 window steps
        assert_eq!(st.full_passes, 3);
        let s = st.savings(&cfg);
        assert!(s > 0.5, "savings {s}");
        assert!(s < 1.0);
    }

    #[test]
    fn no_window_passes_no_savings() {
        let cfg = tiny_config();
        let mut st = CacheStats::default();
        st.add_decode(10, 0);
        assert_eq!(st.savings(&cfg), 0.0);
    }

    #[test]
    fn config_constructors() {
        assert!(!CacheConfig::disabled().enabled);
        assert!(CacheConfig::block_boundary().enabled);
        assert_eq!(CacheConfig::with_refresh_interval(4).refresh_interval, 4);
    }

    #[test]
    fn paging_and_sharing_config() {
        let c = CacheConfig::block_boundary();
        assert_eq!(c.kv_page_len, 0);
        assert!(!c.prefix_sharing);
        assert!(!c.sharing_active());
        let c = c.paged(8).with_prefix_sharing(true);
        assert_eq!(c.kv_page_len, 8, "explicit page length kept");
        assert!(c.sharing_active());
        // sharing without an explicit page size picks a default
        let c = CacheConfig::block_boundary().with_prefix_sharing(true);
        assert_eq!(c.kv_page_len, 16);
        // sharing never activates with the cache off
        assert!(!CacheConfig::disabled().with_prefix_sharing(true).sharing_active());
    }
}
