//! Cache residency: where a sequence's dual KV cache lives (DESIGN.md §10).
//!
//! [`CacheHandle`] is the opaque per-sequence cache token the decode layer
//! carries between `fwd_full_kv` (producer) and `fwd_window`/
//! `fwd_window_batch` (consumers). The decode engine never looks inside:
//! only the forward model that minted a handle knows whether it wraps host
//! vectors (the legacy round-trip path, kept as an A/B escape hatch) or
//! device-resident `PjRtBuffer`s (the default — K/V never crosses the
//! host↔device boundary between block refreshes).
//!
//! Handles are pool-aware: dropping one returns its storage to the
//! [`super::pool::CachePool`] it was minted from, so block rollovers and
//! sequence retirement recycle cache storage instead of churning the
//! allocator.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::paged::PageTable;
use super::pool::PoolInner;

/// Where forward passes keep the dual KV cache between block refreshes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Residency {
    /// K/V downloaded to host `Vec<f32>`s after every refresh and
    /// re-uploaded for every window pass (the pre-residency behaviour).
    Host,
    /// K/V stays on device as retained `PjRtBuffer`s; window passes take
    /// the buffers as arguments with zero per-step K/V transfer.
    #[default]
    Device,
}

impl Residency {
    pub fn parse(s: &str) -> Result<Residency> {
        match s {
            "host" => Ok(Residency::Host),
            "device" => Ok(Residency::Device),
            other => bail!("unknown cache residency {other:?} (host|device)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Residency::Host => "host",
            Residency::Device => "device",
        }
    }
}

/// Host-side copy of the dual KV cache (layers, heads, seq, head_dim).
/// The payload of a host-resident [`CacheHandle`]; also what `SimModel`
/// mints (its cache carries no information).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub dims: [usize; 4],
}

impl KvCache {
    /// Total f32 element count per side (k or v).
    pub fn side_len(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Device-resident dual KV cache: two retained `PjRtBuffer`s.
#[derive(Debug)]
pub struct DeviceKv {
    pub k: xla::PjRtBuffer,
    pub v: xla::PjRtBuffer,
    pub dims: [usize; 4],
}

#[derive(Debug)]
pub(crate) enum CacheStorage {
    Host(KvCache),
    Device(DeviceKv),
    /// Refcounted pages in a [`super::paged::PagedKvPool`] — possibly
    /// shared with other sequences via the prompt-prefix index. Host-side
    /// storage (reported as [`Residency::Host`] so dispatch routes it to
    /// the upload paths), reassembled or stacked on demand.
    Paged(PageTable),
}

/// Opaque per-sequence dual-KV-cache token. Produced by
/// `ForwardModel::fwd_full_kv`, owned by `DecodeTask`, consumed by the
/// window passes. Dropping the handle recycles its storage into the pool
/// it came from. Deliberately **not** `Clone`: with a real PJRT binding a
/// clone would alias one device allocation into two pool-reclaiming
/// owners.
#[derive(Debug)]
pub struct CacheHandle {
    storage: Option<CacheStorage>,
    pool: Option<Arc<PoolInner>>,
}

impl CacheHandle {
    /// A host-resident handle outside any pool (tests, ad-hoc callers).
    pub fn host(kv: KvCache) -> CacheHandle {
        CacheHandle { storage: Some(CacheStorage::Host(kv)), pool: None }
    }

    /// A paged handle. No `pool` link: the [`PageTable`] releases its own
    /// page refs on drop, so the whole-buffer pool is never involved.
    pub fn paged(table: PageTable) -> CacheHandle {
        CacheHandle { storage: Some(CacheStorage::Paged(table)), pool: None }
    }

    pub(crate) fn new(storage: CacheStorage, pool: Option<Arc<PoolInner>>) -> CacheHandle {
        CacheHandle { storage: Some(storage), pool }
    }

    fn storage(&self) -> &CacheStorage {
        self.storage.as_ref().expect("storage present until drop")
    }

    pub fn residency(&self) -> Residency {
        match self.storage() {
            CacheStorage::Host(_) | CacheStorage::Paged(_) => Residency::Host,
            CacheStorage::Device(_) => Residency::Device,
        }
    }

    pub fn dims(&self) -> [usize; 4] {
        match self.storage() {
            CacheStorage::Host(kv) => kv.dims,
            CacheStorage::Device(d) => d.dims,
            CacheStorage::Paged(t) => t.dims(),
        }
    }

    /// Host payload, if host-resident (contiguous storage only; paged
    /// handles answer through [`CacheHandle::host_kv`]).
    pub fn as_host(&self) -> Option<&KvCache> {
        match self.storage() {
            CacheStorage::Host(kv) => Some(kv),
            _ => None,
        }
    }

    /// Page table, if paged.
    pub fn as_paged(&self) -> Option<&PageTable> {
        match self.storage() {
            CacheStorage::Paged(t) => Some(t),
            _ => None,
        }
    }

    /// Host-visible K/V: borrowed for contiguous host storage, assembled
    /// on the fly for paged storage, `None` for device residency.
    pub fn host_kv(&self) -> Option<Cow<'_, KvCache>> {
        match self.storage() {
            CacheStorage::Host(kv) => Some(Cow::Borrowed(kv)),
            CacheStorage::Paged(t) => Some(Cow::Owned(t.assemble())),
            CacheStorage::Device(_) => None,
        }
    }

    /// Device buffers (k, v), if device-resident.
    pub fn as_device(&self) -> Option<(&xla::PjRtBuffer, &xla::PjRtBuffer)> {
        match self.storage() {
            CacheStorage::Device(d) => Some((&d.k, &d.v)),
            _ => None,
        }
    }
}

impl Drop for CacheHandle {
    fn drop(&mut self) {
        if let (Some(storage), Some(pool)) = (self.storage.take(), self.pool.take()) {
            pool.reclaim(storage);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n: usize) -> KvCache {
        KvCache { k: vec![1.0; n], v: vec![2.0; n], dims: [1, 1, n, 1] }
    }

    #[test]
    fn residency_parses() {
        assert_eq!(Residency::parse("host").unwrap(), Residency::Host);
        assert_eq!(Residency::parse("device").unwrap(), Residency::Device);
        assert!(Residency::parse("gpu").is_err());
        assert_eq!(Residency::default(), Residency::Device);
        assert_eq!(Residency::Device.as_str(), "device");
    }

    #[test]
    fn host_handle_exposes_payload() {
        let h = CacheHandle::host(kv(4));
        assert_eq!(h.residency(), Residency::Host);
        assert_eq!(h.dims(), [1, 1, 4, 1]);
        assert_eq!(h.as_host().unwrap().k, vec![1.0; 4]);
        assert!(h.as_device().is_none());
    }

    #[test]
    fn unpooled_drop_is_a_noop() {
        drop(CacheHandle::host(kv(2)));
    }

    #[test]
    fn paged_handle_reads_as_host() {
        use crate::cache::paged::PagedKvPool;

        let pool = PagedKvPool::new([1, 1, 4, 1], 2, 8);
        let src = kv(4);
        let h = CacheHandle::paged(pool.paginate(&src).unwrap());
        assert_eq!(h.residency(), Residency::Host, "routes to upload paths");
        assert_eq!(h.dims(), [1, 1, 4, 1]);
        assert!(h.as_host().is_none(), "not contiguous");
        assert!(h.as_device().is_none());
        assert!(h.as_paged().is_some());
        let kv = h.host_kv().expect("assembles on demand");
        assert_eq!(kv.k, src.k);
        assert_eq!(kv.v, src.v);
        drop(h);
        assert_eq!(pool.stats().pages_in_use, 0, "drop releases pages");
    }
}
