//! Table 1 reproduction: OSDT vs Fast-dLLM fixed (τ=0.9) vs Fast-dLLM
//! factor, accuracy & throughput on the three task benchmarks, plus the
//! sequential LLaDA baseline for reference.
//!
//!     cargo bench --bench table1 [-- --n 48]
//!
//! Per-task OSDT configurations are the paper's §4.1 choices:
//!   GPQA→synth-qa    : step-block, q2, κ=0.75, ε=0.20
//!   GSM8K→synth-math : block,      q1, κ=0.75, ε=0.20
//!   HumanEval→synth-code : block,  q1, κ=0.80, ε=0.10
//!
//! Expected shape (not absolute numbers — CPU testbed): OSDT ≥ fixed-τ
//! throughput at comparable accuracy on every task.

use anyhow::Result;

use osdt::bench::{render_table, run_eval, write_csv, RunOpts};
use osdt::config::Args;
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

/// (task, OSDT spec from the paper)
const OSDT_SPECS: [(&str, &str); 3] = [
    ("synth-qa", "osdt:step-block:q2:0.75:0.2"),
    ("synth-math", "osdt:block:q1:0.75:0.2"),
    ("synth-code", "osdt:block:q1:0.8:0.1"),
];

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["n"])?;
    let n: usize = args.get_parse("n", 48)?;

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (task, osdt_spec) in OSDT_SPECS {
        let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;
        let opts = RunOpts { n, ..Default::default() };
        for spec in [osdt_spec, "static:0.9", "factor:0.95", "sequential:1"] {
            let row = run_eval(&rt, &tok, &ds, spec, &opts)?;
            eprintln!(
                "[table1] {task} {spec}: acc {:.1}% thru {:.1} tok/s",
                row.accuracy * 100.0,
                row.tokens_per_sec
            );
            rows.push(vec![
                task.to_string(),
                short_name(spec),
                format!("{:.2}", row.accuracy * 100.0),
                format!("{:.1}", row.tokens_per_sec),
                format!("{:.1}", row.mean_steps),
            ]);
            csv.push(vec![
                task.to_string(),
                spec.to_string(),
                format!("{}", row.n),
                format!("{}", row.accuracy),
                format!("{}", row.tokens_per_sec),
                format!("{}", row.mean_steps),
                format!("{}", row.mean_latency_ms),
            ]);
        }
        rows.push(vec![String::new(); 5]);
    }
    println!("\n=== Table 1: accuracy & throughput (n={n} per task) ===");
    println!(
        "{}",
        render_table(&["benchmark", "policy", "acc%", "tokens/s", "steps/seq"], &rows)
    );
    write_csv(
        "results/table1.csv",
        &["task", "policy", "n", "accuracy", "tokens_per_sec", "steps", "latency_ms"],
        &csv,
    )?;
    println!("csv -> results/table1.csv");

    // the paper's headline claims, as checks (shape, not magnitude)
    check_shape(&csv);
    Ok(())
}

fn short_name(spec: &str) -> String {
    if spec.starts_with("osdt") {
        "OSDT (ours)".into()
    } else if spec.starts_with("static") {
        "Fast-dLLM fixed".into()
    } else if spec.starts_with("factor") {
        "Fast-dLLM factor".into()
    } else {
        "LLaDA sequential".into()
    }
}

fn check_shape(csv: &[Vec<String>]) {
    println!("\n=== shape checks vs paper ===");
    for task in ["synth-qa", "synth-math", "synth-code"] {
        let get = |pol: &str| -> Option<(f64, f64)> {
            csv.iter()
                .find(|r| r[0] == task && r[1].starts_with(pol))
                .map(|r| (r[3].parse().unwrap(), r[4].parse().unwrap()))
        };
        let (Some((acc_o, thr_o)), Some((acc_s, thr_s))) = (get("osdt"), get("static"))
        else {
            continue;
        };
        let speedup = thr_o / thr_s;
        let acc_gap = (acc_o - acc_s) * 100.0;
        let ok = speedup >= 1.0 && acc_gap > -10.0;
        println!(
            "{} {task}: OSDT/static speedup {:.2}x, acc gap {:+.1}pp (paper: +24-50% thru, |gap| small)",
            if ok { "PASS" } else { "WARN" },
            speedup,
            acc_gap
        );
    }
}
