//! L3 microbenchmark: per-step cost of policy selection and of the decode
//! engine's bookkeeping, versus a forward pass. OSDT's claim is "negligible
//! overhead" — this bench quantifies it (policy decisions must be orders of
//! magnitude below the fwd pass; see EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench policy_overhead

use std::time::Instant;

use anyhow::Result;

use osdt::decode::Engine;
use osdt::policy::{
    FactorThreshold, Metric, Osdt, Policy, Profile, SequentialTopK, StaticThreshold,
    StepContext,
};
use osdt::sim::SimModel;
use osdt::util::rng::Rng;

fn bench_policy(name: &str, p: &dyn Policy, confs: &[Vec<f32>]) {
    // warm
    for c in confs.iter().take(100) {
        std::hint::black_box(p.select(&StepContext { block: 0, step: 0, conf: c }));
    }
    let t0 = Instant::now();
    let mut total = 0usize;
    for (i, c) in confs.iter().enumerate() {
        let ctx = StepContext { block: i % 3, step: i % 20, conf: c };
        total += std::hint::black_box(p.select(&ctx)).len();
    }
    let dt = t0.elapsed();
    println!(
        "  {name:<28} {:>8.1} ns/step   ({} selections)",
        dt.as_nanos() as f64 / confs.len() as f64,
        total
    );
}

fn main() -> Result<()> {
    let mut rng = Rng::new(7);
    // realistic step shapes: 1..32 masked positions
    let confs: Vec<Vec<f32>> = (0..200_000)
        .map(|_| {
            let n = 1 + rng.below(32) as usize;
            (0..n).map(|_| rng.next_f32()).collect()
        })
        .collect();

    println!("=== L3 policy selection cost (200k steps) ===");
    bench_policy("sequential-top1", &SequentialTopK::new(1), &confs);
    bench_policy("static-0.9", &StaticThreshold::new(0.9), &confs);
    bench_policy("factor-0.95", &FactorThreshold::new(0.95), &confs);
    let profile = Profile::step_block(
        vec![vec![0.5; 32], vec![0.6; 32], vec![0.7; 32]],
        Metric::Median,
    );
    bench_policy(
        "osdt-step-block",
        &Osdt::from_profile(profile, 0.75, 0.2),
        &confs,
    );

    // whole-engine step cost on the zero-cost simulator = L3 bookkeeping
    let m = SimModel::math_like(3);
    let engine = Engine::new(&m);
    let p = StaticThreshold::new(0.9);
    let n_decodes = 200;
    let t0 = Instant::now();
    let mut steps = 0usize;
    for i in 0..n_decodes {
        let res = engine.decode(m.layout_from_seed(i as u64), &p)?;
        steps += res.steps;
    }
    let per_step_us = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;
    println!("\n=== decode-engine bookkeeping (simulator fwd ~ free) ===");
    println!("  {per_step_us:.2} us/step over {steps} steps ({n_decodes} decodes)");
    println!(
        "  (PJRT fwd pass on this testbed is ~3-6 ms/step -> L3 overhead {:.3}%)",
        per_step_us / 4000.0 * 100.0
    );
    Ok(())
}
