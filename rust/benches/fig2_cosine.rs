//! Figure 2 reproduction: pairwise cosine similarity of step-block mean
//! confidence vectors across inputs of each task. The paper's observation:
//! values near 1.0 everywhere — a *task-level* confidence signature — which
//! is what licenses one-shot calibration.
//!
//!     cargo bench --bench fig2_cosine [-- --n 10]

use anyhow::Result;

use osdt::bench::{ascii_heatmap, collect_traces, cosine_matrix, write_csv, CALIBRATION_TAU};
use osdt::config::Args;
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::{Dataset, TASKS};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["n"])?;
    let n: usize = args.get_parse("n", 10)?;

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;

    let mut csv = Vec::new();
    println!("=== Figure 2: pairwise cosine similarity (n={n} inputs/task) ===\n");
    for task in TASKS {
        let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;
        let traces = collect_traces(&rt, &tok, &ds, n, CALIBRATION_TAU)?;
        let m = cosine_matrix(&traces);
        let (mut lo, mut sum, mut cnt) = (f64::INFINITY, 0.0, 0.0);
        for i in 0..m.len() {
            for j in 0..m.len() {
                if i != j {
                    lo = lo.min(m[i][j]);
                    sum += m[i][j];
                    cnt += 1.0;
                }
                csv.push(vec![
                    task.to_string(),
                    i.to_string(),
                    j.to_string(),
                    format!("{}", m[i][j]),
                ]);
            }
        }
        let mean = sum / cnt;
        print!("{}", ascii_heatmap(&m, 0.9, 1.0, task));
        println!(
            "  off-diagonal: mean {mean:.4}, min {lo:.4} {}\n",
            if mean > 0.95 { "(near-1: PASS)" } else { "(WARN: below paper's near-1)" }
        );
    }
    write_csv("results/fig2_cosine.csv", &["task", "i", "j", "cosine"], &csv)?;
    println!("csv -> results/fig2_cosine.csv");
    Ok(())
}
