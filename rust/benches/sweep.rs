//! Figures 3–5 reproduction: the OSDT hyperparameter sweep — dynamic mode M
//! × metric μ × cap κ × slack ε — reporting the accuracy/throughput point
//! for every combination, per task.
//!
//!     cargo bench --bench sweep                 # reduced grid, all tasks
//!     cargo bench --bench sweep -- --task math  # one task
//!     cargo bench --bench sweep -- --full       # the paper's full grid
//!
//! Full grid (paper §4.1): μ ∈ {mean,q1,q2,q3,min-whisker},
//! κ ∈ {0.75,0.8,0.85,0.9,0.95}, ε ∈ {0.01,0.05,0.1,0.15,0.2}, M ∈ {block,
//! step-block} = 250 points/task. The reduced default keeps `cargo bench`
//! under a few minutes on CPU.

use anyhow::Result;

use osdt::bench::{render_table, run_eval, write_csv, RunOpts};
use osdt::config::Args;
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(
        std::env::args().skip(1).collect::<Vec<_>>(),
        &["task", "n"],
    )?;
    let n: usize = args.get_parse("n", 6)?;
    let full = args.has("full");
    let task_filter = args.get("task").map(|t| {
        if t.starts_with("synth-") {
            t.to_string()
        } else {
            format!("synth-{t}")
        }
    });

    let (modes, metrics, kappas, epsilons): (
        Vec<&str>,
        Vec<&str>,
        Vec<f64>,
        Vec<f64>,
    ) = if full {
        (
            vec!["block", "step-block"],
            vec!["mean", "q1", "q2", "q3", "min-whisker"],
            vec![0.75, 0.8, 0.85, 0.9, 0.95],
            vec![0.01, 0.05, 0.1, 0.15, 0.2],
        )
    } else {
        (
            vec!["block", "step-block"],
            vec!["q1", "q2"],
            vec![0.75, 0.85, 0.95],
            vec![0.05, 0.2],
        )
    };

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;

    let tasks: Vec<String> = match &task_filter {
        Some(t) => vec![t.clone()],
        None => osdt::workload::TASKS.iter().map(|s| s.to_string()).collect(),
    };

    let mut csv = Vec::new();
    for task in &tasks {
        let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;
        let opts = RunOpts { n, ..Default::default() };
        let mut best: Vec<(f64, f64, String)> = Vec::new(); // (acc, thru, spec)
        let total = modes.len() * metrics.len() * kappas.len() * epsilons.len();
        let mut done = 0usize;
        for mode in &modes {
            for metric in &metrics {
                for &kappa in &kappas {
                    for &eps in &epsilons {
                        let spec = format!("osdt:{mode}:{metric}:{kappa}:{eps}");
                        let row = run_eval(&rt, &tok, &ds, &spec, &opts)?;
                        done += 1;
                        if done % 10 == 0 {
                            eprintln!("[sweep] {task}: {done}/{total}");
                        }
                        csv.push(vec![
                            task.clone(),
                            mode.to_string(),
                            metric.to_string(),
                            format!("{kappa}"),
                            format!("{eps}"),
                            format!("{}", row.accuracy),
                            format!("{}", row.tokens_per_sec),
                            format!("{}", row.mean_steps),
                        ]);
                        best.push((row.accuracy, row.tokens_per_sec, spec));
                    }
                }
            }
        }
        // Pareto frontier: points not dominated in (acc, thru)
        let mut frontier: Vec<&(f64, f64, String)> = best
            .iter()
            .filter(|(a, t, _)| {
                !best
                    .iter()
                    .any(|(a2, t2, _)| (*a2 > *a && *t2 >= *t) || (*a2 >= *a && *t2 > *t))
            })
            .collect();
        frontier.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());
        println!("\n=== {task}: Pareto frontier of the sweep ({} points) ===", best.len());
        let rows: Vec<Vec<String>> = frontier
            .iter()
            .map(|(a, t, s)| {
                vec![s.clone(), format!("{:.2}", a * 100.0), format!("{t:.1}")]
            })
            .collect();
        println!("{}", render_table(&["spec", "acc%", "tokens/s"], &rows));
    }
    write_csv(
        "results/sweep.csv",
        &["task", "mode", "metric", "kappa", "epsilon", "accuracy", "tokens_per_sec", "steps"],
        &csv,
    )?;
    println!("csv -> results/sweep.csv ({} rows)", csv.len());
    Ok(())
}
