//! Whole-stack perf profile (the §Perf measurement tool):
//!   1. per-variant forward-pass latency (exec vs host-transfer split),
//!   2. lockstep batch scaling (b1/b2/b4) — L2+runtime efficiency,
//!   3. dual-KV-cache speedup — the window-pass fast path,
//!   4. end-to-end decode throughput per policy.
//!
//!     cargo bench --bench perf_engine [-- --reps 20]

use std::time::Instant;

use anyhow::Result;

use osdt::cache::{flops_full, flops_window, CacheConfig, Residency};
use osdt::config::Args;
use osdt::decode::Engine;
use osdt::model::ModelConfig;
use osdt::policy::StaticThreshold;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["reps"])?;
    let reps: usize = args.get_parse("reps", 20)?;

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;
    let layout = tok.layout_prompt(&cfg, "Q: 3+4-2=?")?;

    // ---- 1. per-variant latency --------------------------------------------
    println!("=== fwd-pass latency ({reps} reps, f32, seq {}) ===", cfg.seq_len);
    let time_variant = |name: &str, f: &mut dyn FnMut() -> Result<()>| -> Result<f64> {
        f()?; // warm
        let t0 = Instant::now();
        for _ in 0..reps {
            f()?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!("  {name:<18} {ms:8.2} ms");
        Ok(ms)
    };
    let l1 = layout.clone();
    let full_ms = time_variant("fwd_conf b1", &mut || {
        rt.fwd_conf(&[l1.as_slice()]).map(|_| ())
    })?;
    for b in [2usize, 4] {
        let batch: Vec<&[u32]> = (0..b).map(|_| layout.as_slice()).collect();
        let ms = time_variant(&format!("fwd_conf b{b}"), &mut || {
            rt.fwd_conf(&batch).map(|_| ())
        })?;
        println!(
            "    -> batch efficiency: {:.2}x ideal {b}x ({:.1}%)",
            full_ms * b as f64 / ms,
            full_ms * b as f64 / ms / b as f64 * 100.0
        );
    }
    let kv_ms = time_variant("fwd_full_kv b1", &mut || {
        rt.fwd_full_kv(&layout).map(|_| ())
    })?;
    let (_, cache) = rt.fwd_full_kv(&layout)?;
    let win = layout[cfg.block_range(0)].to_vec();
    let win_ms = time_variant("fwd_window b1", &mut || {
        rt.fwd_window(&win, cfg.prompt_len, &cache).map(|_| ())
    })?;
    println!(
        "  window/full cost : measured {:.2} vs analytic FLOP ratio {:.2}",
        win_ms / full_ms,
        flops_window(&cfg) / flops_full(&cfg)
    );
    println!("  full_kv overhead : {:.2}x of fwd_conf (extra K/V outputs)", kv_ms / full_ms);

    // ---- 2. exec vs transfer split, per entry point --------------------------
    let st = rt.stats();
    println!("\n=== runtime split (cumulative) ===");
    for (name, e) in [
        ("fwd_conf", st.conf),
        ("fwd_full_kv", st.full_kv),
        ("fwd_window", st.window),
        ("kv_gather", st.gather),
    ] {
        if e.calls == 0 {
            continue;
        }
        println!(
            "  {name:<12} {:4} calls  exec {:8.1} ms  up {:7.1} KB  down {:7.1} KB",
            e.calls,
            e.exec_micros as f64 / 1e3,
            e.upload_bytes as f64 / 1e3,
            e.download_bytes as f64 / 1e3
        );
    }
    println!(
        "  total exec {:.1} ms, host transfer {:.1} ms ({:.1}% transfer); \
         k/v payload: {:.1} KB up / {:.1} KB down",
        st.exec_micros() as f64 / 1e3,
        st.transfer_micros() as f64 / 1e3,
        st.transfer_micros() as f64 / (st.exec_micros() + st.transfer_micros()).max(1) as f64
            * 100.0,
        st.cache_upload_bytes as f64 / 1e3,
        st.cache_download_bytes as f64 / 1e3,
    );

    // ---- 3/4. end-to-end decode throughput ----------------------------------
    println!("\n=== end-to-end decode (static:0.9) ===");
    for (label, cache_cfg, residency) in [
        ("no cache", CacheConfig::disabled(), Residency::Device),
        ("KV cache (host)", CacheConfig::block_boundary(), Residency::Host),
        ("KV cache (device)", CacheConfig::block_boundary(), Residency::Device),
    ] {
        rt.set_residency(residency);
        let engine = Engine::with_cache(&rt, cache_cfg);
        let p = StaticThreshold::new(0.9);
        let s0 = rt.stats();
        let t0 = Instant::now();
        let mut steps = 0;
        let n = 10;
        for _ in 0..n {
            let res = engine.decode(layout.clone(), &p)?;
            steps += res.steps;
        }
        let dt = t0.elapsed().as_secs_f64();
        let s1 = rt.stats();
        let tokens = (n * cfg.gen_len) as f64;
        println!(
            "  {label:<17} {:7.1} tokens/s  ({:.1} steps/seq, {:.1} ms/seq, {:.0} B/token transferred)",
            tokens / dt,
            steps as f64 / n as f64,
            dt * 1e3 / n as f64,
            (s1.transfer_bytes() - s0.transfer_bytes()) as f64 / tokens,
        );
    }
    Ok(())
}
