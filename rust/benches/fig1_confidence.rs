//! Figure 1 reproduction: step-block mean token confidence trajectories for
//! the three tasks (decoded with the static τ=0.9 policy, averaged over N
//! inputs). The paper's observation: confidence starts low, peaks
//! mid-process, and drops near the final steps, with distinct levels per
//! task.
//!
//!     cargo bench --bench fig1_confidence [-- --n 8]

use anyhow::Result;

use osdt::bench::{ascii_plot, collect_traces, mean_signature, write_csv, CALIBRATION_TAU};
use osdt::config::Args;
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::{Dataset, TASKS};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["n"])?;
    let n: usize = args.get_parse("n", 8)?;

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;

    let mut csv = Vec::new();
    println!("=== Figure 1: step-block mean token confidence (n={n} inputs) ===\n");
    for task in TASKS {
        let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;
        let traces = collect_traces(&rt, &tok, &ds, n, CALIBRATION_TAU)?;
        let sig = mean_signature(&traces);
        print!("{}", ascii_plot(&sig, 12, &format!("{task} ({} steps)", sig.len())));
        println!();
        // structural check: mid of block 0 above its endpoints
        let b0_len = traces[0].per_block[0].len().min(sig.len());
        if b0_len >= 3 {
            let (first, mid, last) =
                (sig[0], sig[b0_len / 2], sig[b0_len - 1]);
            println!(
                "  block-0 shape: start {first:.3} -> mid {mid:.3} -> end {last:.3} {}\n",
                if mid > first && mid > last { "(U-shaped: PASS)" } else { "(WARN: not U-shaped)" }
            );
        }
        for (i, v) in sig.iter().enumerate() {
            csv.push(vec![task.to_string(), i.to_string(), format!("{v}")]);
        }
    }
    write_csv("results/fig1_confidence.csv", &["task", "step", "mean_conf"], &csv)?;
    println!("csv -> results/fig1_confidence.csv");
    Ok(())
}
