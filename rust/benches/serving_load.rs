//! Serving-systems bench: end-to-end latency vs offered load through the
//! coordinator, comparing decode policies under the same arrival trace —
//! and, for each, the dual-KV-cache path against full recomputation. The
//! systems-level restatement of Table 1: a policy that spends fewer
//! forward passes per sequence sustains a higher arrival rate before
//! queueing delay blows up, and the continuous-batching scheduler lets the
//! cache and batching stack (the old lockstep batcher forced batch 1
//! whenever the cache was on).
//!
//!     cargo bench --bench serving_load [-- --n 24 --rates 1,2,4 --workers 1 --max-batch 4]
//!
//! Reported per point: p50/p95 latency, tokens/s, and mean/peak batch
//! occupancy (from the coordinator's scheduler metrics). Runs on the real
//! PJRT model over a mixed multi-task workload.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use osdt::bench::{render_table, write_csv};
use osdt::cache::CacheConfig;
use osdt::config::Args;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::util::stats::Histogram;
use osdt::workload::{mixed_trace, Dataset};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(
        std::env::args().skip(1).collect::<Vec<_>>(),
        &["n", "rates", "workers", "max-batch"],
    )?;
    let n: usize = args.get_parse("n", 24)?;
    let workers: usize = args.get_parse("workers", 1)?;
    let max_batch: usize = args.get_parse("max-batch", 4)?;
    let rates: Vec<f64> = args
        .get_or("rates", "2,6,12")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let cfg = ModelConfig::load("artifacts")?;
    let data_dir = cfg.artifact_dir.join("data");
    // mixed multi-task workload: the same trace drives every configuration
    let datasets = vec![
        Dataset::load(&data_dir, "synth-math")?,
        Dataset::load(&data_dir, "synth-qa")?,
    ];

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for policy in ["osdt:block:q1:0.75:0.2", "static:0.9", "sequential:1"] {
        for (cache_label, cache) in [
            ("off", CacheConfig::disabled()),
            ("on", CacheConfig::block_boundary()),
        ] {
            for &rate in &rates {
                let coord = Arc::new(Coordinator::start(
                    CoordinatorConfig {
                        workers,
                        max_batch,
                        batch_wait: Duration::from_millis(2),
                        cache,
                    },
                    cfg.clone(),
                    |_| {
                        let cfg = ModelConfig::load("artifacts")?;
                        ModelRuntime::load(&cfg)
                    },
                )?);
                // warm the OSDT profiles so calibration isn't in the timed
                // region (one calibration per task)
                for ds in &datasets {
                    let _ = coord.generate(&ds.task, &ds.examples[0].prompt, policy)?;
                }
                // snapshot the scheduler counters so the warm-up's solo
                // decodes don't dilute the timed region's occupancy
                let steps0 = coord.metrics.counter_value("scheduler_steps");
                let seq_steps0 = coord.metrics.counter_value("scheduled_seq_steps");

                let trace = mixed_trace(&datasets, rate, n, 7);
                let mut lat = Histogram::latency();
                let t0 = Instant::now();
                let mut pending = Vec::new();
                for r in &trace {
                    let due = Duration::from_secs_f64(r.at);
                    if let Some(wait) = due.checked_sub(t0.elapsed()) {
                        std::thread::sleep(wait);
                    }
                    pending.push((
                        Instant::now(),
                        coord.submit(Request {
                            id: 0,
                            task: r.task.clone(),
                            prompt: r.prompt.clone(),
                            policy: policy.into(),
                        }),
                    ));
                }
                let mut ok = 0;
                for (sent, rx) in pending {
                    let resp = rx.recv()?;
                    if resp.error.is_none() {
                        ok += 1;
                    }
                    lat.record(sent.elapsed().as_secs_f64() * 1e6);
                }
                let wall = t0.elapsed().as_secs_f64();
                let steps =
                    (coord.metrics.counter_value("scheduler_steps") - steps0).max(1);
                let seq_steps =
                    coord.metrics.counter_value("scheduled_seq_steps") - seq_steps0;
                let occ_mean = seq_steps as f64 / steps as f64;
                let occ_peak = coord
                    .metrics
                    .gauge("batch_occupancy_peak")
                    .load(Ordering::Relaxed);
                let tokens_per_sec = (ok * cfg.gen_len) as f64 / wall;
                let p50 = lat.quantile(0.5) / 1e3;
                let p95 = lat.quantile(0.95) / 1e3;
                eprintln!(
                    "[load] {policy} cache={cache_label} @{rate}rps: \
                     p50 {p50:.0}ms p95 {p95:.0}ms occ {occ_mean:.2} (peak {occ_peak})"
                );
                rows.push(vec![
                    policy.to_string(),
                    cache_label.to_string(),
                    format!("{rate}"),
                    format!("{ok}/{n}"),
                    format!("{p50:.0}"),
                    format!("{p95:.0}"),
                    format!("{tokens_per_sec:.1}"),
                    format!("{occ_mean:.2}"),
                    format!("{occ_peak}"),
                ]);
                csv.push(vec![
                    policy.to_string(),
                    cache_label.to_string(),
                    format!("{rate}"),
                    format!("{}", lat.quantile(0.5)),
                    format!("{}", lat.quantile(0.95)),
                    format!("{tokens_per_sec}"),
                    format!("{occ_mean}"),
                    format!("{occ_peak}"),
                ]);
                drop(coord);
            }
        }
        rows.push(vec![String::new(); 9]);
    }
    println!("\n=== serving latency vs offered load (n={n}/point, mixed workload) ===");
    println!(
        "{}",
        render_table(
            &[
                "policy", "cache", "rps", "ok", "p50 ms", "p95 ms", "tokens/s",
                "occ mean", "occ peak"
            ],
            &rows
        )
    );
    write_csv(
        "results/serving_load.csv",
        &[
            "policy", "cache", "rate", "p50_us", "p95_us", "tokens_per_sec",
            "occ_mean", "occ_peak",
        ],
        &csv,
    )?;
    println!("csv -> results/serving_load.csv");
    Ok(())
}
