//! Serving-systems bench: end-to-end latency vs offered load through the
//! coordinator + router, comparing decode policies under the same Poisson
//! arrival trace. The systems-level restatement of Table 1: a policy that
//! spends fewer forward passes per sequence sustains a higher arrival rate
//! before queueing delay blows up.
//!
//!     cargo bench --bench serving_load [-- --n 24 --rates 1,2,4]
//!
//! Runs on the real PJRT model (1 worker replica, batch 1, matching the
//! paper's serving setup).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use osdt::bench::{render_table, write_csv};
use osdt::config::Args;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::util::stats::Histogram;
use osdt::workload::{poisson_trace, Dataset};

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["n", "rates"])?;
    let n: usize = args.get_parse("n", 24)?;
    let rates: Vec<f64> = args
        .get_or("rates", "2,6,12")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let cfg = ModelConfig::load("artifacts")?;
    let ds = Dataset::load(cfg.artifact_dir.join("data"), "synth-math")?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for policy in ["osdt:block:q1:0.75:0.2", "static:0.9", "sequential:1"] {
        for &rate in &rates {
            let coord = Arc::new(Coordinator::start(
                CoordinatorConfig {
                    workers: 1,
                    max_batch: 1,
                    batch_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                cfg.clone(),
                |_| {
                    let cfg = ModelConfig::load("artifacts")?;
                    ModelRuntime::load(&cfg)
                },
            )?);
            // warm the OSDT profile so calibration isn't in the timed region
            let _ = coord.generate("synth-math", &ds.examples[0].prompt, policy)?;

            let trace = poisson_trace(&ds, rate, n, 7);
            let mut lat = Histogram::latency();
            let t0 = Instant::now();
            let mut pending = Vec::new();
            for r in &trace {
                let due = Duration::from_secs_f64(r.at);
                if let Some(wait) = due.checked_sub(t0.elapsed()) {
                    std::thread::sleep(wait);
                }
                pending.push((
                    Instant::now(),
                    coord.submit(Request {
                        id: 0,
                        task: r.task.clone(),
                        prompt: r.prompt.clone(),
                        policy: policy.into(),
                    }),
                ));
            }
            let mut ok = 0;
            for (sent, rx) in pending {
                let resp = rx.recv()?;
                if resp.error.is_none() {
                    ok += 1;
                }
                lat.record(sent.elapsed().as_secs_f64() * 1e6);
            }
            let wall = t0.elapsed().as_secs_f64();
            let p50 = lat.quantile(0.5) / 1e3;
            let p95 = lat.quantile(0.95) / 1e3;
            eprintln!("[load] {policy} @{rate}rps: p50 {p50:.0}ms p95 {p95:.0}ms");
            rows.push(vec![
                policy.to_string(),
                format!("{rate}"),
                format!("{ok}/{n}"),
                format!("{:.0}", p50),
                format!("{:.0}", p95),
                format!("{:.1}", (ok * cfg.gen_len) as f64 / wall),
            ]);
            csv.push(vec![
                policy.to_string(),
                format!("{rate}"),
                format!("{}", lat.quantile(0.5)),
                format!("{}", lat.quantile(0.95)),
                format!("{}", (ok * cfg.gen_len) as f64 / wall),
            ]);
            drop(coord);
        }
        rows.push(vec![String::new(); 6]);
    }
    println!("\n=== serving latency vs offered load (n={n}/point) ===");
    println!(
        "{}",
        render_table(
            &["policy", "rps", "ok", "p50 ms", "p95 ms", "tokens/s"],
            &rows
        )
    );
    write_csv(
        "results/serving_load.csv",
        &["policy", "rate", "p50_us", "p95_us", "tokens_per_sec"],
        &csv,
    )?;
    println!("csv -> results/serving_load.csv");
    Ok(())
}
