//! Serving-systems bench: end-to-end latency vs offered load through the
//! coordinator, comparing decode policies under the same arrival trace —
//! and, for each, the dual-KV-cache path against full recomputation, with
//! the cached path run at **both residencies** (`--cache-residency`): the
//! legacy host round trip vs device-resident K/V (DESIGN.md §10). The
//! systems-level restatement of Table 1, now with the transfer ledger: a
//! policy that spends fewer forward passes per sequence sustains a higher
//! arrival rate, and a cache that never ships K/V through the host spends
//! fewer bytes per token doing it.
//!
//!     cargo bench --bench serving_load [-- --n 24 --rates 1,2,4 --workers 1
//!         --max-batch 4 --cache-residency both --seed 7 --json BENCH_serving.json]
//!     cargo bench --bench serving_load -- --smoke --json BENCH_serving.json
//!
//! Arrivals are **open-loop**: a seeded Poisson process (`mixed_trace`,
//! `--seed`, default 7) fixes each request's arrival instant up front and
//! the bench submits on schedule regardless of how far the server has
//! fallen behind — so queueing delay shows up in the latency percentiles
//! instead of silently throttling the offered load. The same seed always
//! produces the same trace, which is what makes the committed
//! `bench/trajectory/` snapshots comparable across PRs.
//!
//! Reported per point: p50/p95/p99 end-to-end latency, p50/p95/p99 TTFT
//! (enqueue to first committed token, from the coordinator's `ttft_ms`),
//! p50/p95/p99 per-token latency, tokens/s, bytes transferred per
//! token, per-step K/V upload bytes (must be 0 on the device path), the
//! fused-pass fraction (window steps whose threshold decision ran on
//! device, DESIGN.md §11), mean transfer bytes per scheduler step, and
//! mean/peak batch occupancy. The cached host/device points run the same
//! trace and must produce token-identical completions, which the bench
//! verifies. `--smoke` runs a steps-capped configuration on the analytic
//! `SimModel` (no artifacts needed) so CI can track the serving trajectory
//! and emit `BENCH_serving.json` from every build.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use osdt::bench::{render_table, write_csv};
use osdt::cache::{CacheConfig, Residency};
use osdt::config::Args;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::decode::ForwardModel;
use osdt::fleet::{FleetRouter, ReplicaSpec, RouterConfig};
use osdt::model::{fixtures::tiny_config, ModelConfig};
use osdt::policy::{
    Acquired, DynamicMode, Metric, Profile, ProfileKey, ProfileRegistry,
};
use osdt::runtime::ModelRuntime;
use osdt::server::{Client, RetryPolicy, Server};
use osdt::sim::SimModel;
use osdt::util::json::Json;
use osdt::util::stats::Histogram;
use osdt::workload::{heavy_tail_trace, mixed_trace, Dataset, Example};

/// Give worker loops a beat to publish their final stats deltas before the
/// bench reads the counters (publishing happens on the loop iteration after
/// the response is sent).
const STATS_SETTLE: Duration = Duration::from_millis(60);

/// One measured (policy, cache, residency, rate) point.
struct Point {
    policy: String,
    cache: &'static str,
    residency: &'static str,
    rate: f64,
    ok: usize,
    n: usize,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    /// Time-to-first-token percentiles: enqueue to the first scheduler step
    /// that commits a token for the sequence (coordinator `ttft_ms`).
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    ttft_p99_ms: f64,
    /// Per-token latency percentiles (end-to-end latency / tokens emitted).
    tok_p50_ms: f64,
    tok_p95_ms: f64,
    tok_p99_ms: f64,
    tokens_per_sec: f64,
    bytes_per_token: f64,
    /// K/V payload bytes uploaded during the timed region — the per-step
    /// host round trip the device residency eliminates.
    cache_upload_bytes: u64,
    /// Fraction of window passes that ran through the fused device-
    /// acceptance path (DESIGN.md §11) — 1.0 on the steady-state fused
    /// path, 0.0 for host-full policies like sequential.
    fused_frac: f64,
    /// Mean host↔device bytes per scheduler step — the transfer ledger the
    /// fused path shrinks from O(block) rows to compact acceptance.
    bytes_per_step: f64,
    /// Fraction of completed requests whose block-0 K/V refresh was served
    /// from the shared prompt-prefix index (DESIGN.md §13) instead of
    /// executed; 0 unless `--prefix-sharing` style configs are in play.
    prefix_hit_rate: f64,
    /// Per-sequence forward passes actually executed (full + window) during
    /// the timed region — the denominator the elision planner shrinks.
    steps_executed: u64,
    /// Window passes skipped by the profile-guided elision planner
    /// (DESIGN.md §14); 0 with `--step-elision off`.
    steps_elided: u64,
    /// p95 enqueue → scheduler admission, from the coordinator's
    /// `admission_wait` histogram — the queueing delay predicted-cost
    /// admission (DESIGN.md §15) attacks. Includes the warm-up requests
    /// (idle-server admissions, ~0), which pads the low end identically
    /// on every arm.
    admission_p95_ms: f64,
    /// Median forecast total passes stamped at admission (DESIGN.md §15);
    /// the layout-derived worst case until the task calibrates.
    predicted_steps_p50: f64,
    /// p95 |forecast − executed| passes per retired decode — the cost
    /// model's accuracy on this point's workload.
    forecast_abs_err_p95: f64,
    /// Fraction of timed-region requests rejected by the shed guardrails;
    /// must be 0 with no `--shed-watermark`/SLO configured (the bench
    /// never configures either).
    shed_rate: f64,
    occ_mean: f64,
    occ_peak: i64,
    completions: Vec<String>,
}

struct PointSpec<'a> {
    policy: &'a str,
    cache: CacheConfig,
    cache_label: &'static str,
    residency: &'static str,
    rate: f64,
    n: usize,
    workers: usize,
    max_batch: usize,
    /// Arrival-trace seed: same seed -> same Poisson trace, bit for bit.
    seed: u64,
    /// Enable the profile-guided elision planner (DESIGN.md §14) for
    /// Phase-2 decodes on this point.
    step_elision: bool,
    /// Admission order (DESIGN.md §15): aged shortest-predicted-job-first
    /// when true, plain FIFO when false.
    predictive: bool,
    /// Non-zero selects the heavy-tail trace: this many requests from
    /// `datasets[1]` land behind the first arrival from `datasets[0]`
    /// (`heavy_tail_trace`); 0 keeps the round-robin `mixed_trace`.
    heavy_tail: usize,
}

/// Drive one coordinator configuration through the shared arrival trace.
/// `registry` pre-seeds the profile registry (used by the elision A/B to
/// decode under a hand-built trajectory profile instead of calibrating).
fn run_point<M, F>(
    spec: &PointSpec<'_>,
    model_cfg: &ModelConfig,
    datasets: &[Dataset],
    registry: Option<Arc<ProfileRegistry>>,
    factory: F,
) -> Result<Point>
where
    M: ForwardModel + 'static,
    F: Fn(usize) -> Result<M> + Send + Sync + Clone + 'static,
{
    let coord = Arc::new(Coordinator::start_with_registry(
        CoordinatorConfig {
            workers: spec.workers,
            max_batch: spec.max_batch,
            batch_wait: Duration::from_millis(2),
            cache: spec.cache,
            step_elision: spec.step_elision,
            predictive: spec.predictive,
            ..CoordinatorConfig::default()
        },
        model_cfg.clone(),
        registry.unwrap_or_else(|| Arc::new(ProfileRegistry::in_memory())),
        factory,
    )?);
    // warm the OSDT profiles so calibration isn't in the timed region
    for ds in datasets {
        let _ = coord.generate(&ds.task, &ds.examples[0].prompt, spec.policy)?;
    }
    std::thread::sleep(STATS_SETTLE);
    // snapshot counters so warm-up doesn't dilute the timed region
    let c0 = |name: &str| coord.metrics.counter_value(name);
    let steps0 = c0("scheduler_steps");
    let seq_steps0 = c0("scheduled_seq_steps");
    let up0 = c0("bytes_uploaded");
    let down0 = c0("bytes_downloaded");
    let cache_up0 = c0("cache_bytes_uploaded");
    let window0 = c0("window_passes");
    let fused0 = c0("fused_window_passes");
    let saved0 = c0("prefix_sharing_saved_full_passes");
    let full0 = c0("full_passes");
    let elided0 = c0("steps_elided");
    let shed0 = c0("requests_shed");

    let trace = if spec.heavy_tail > 0 {
        heavy_tail_trace(
            &datasets[0], &datasets[1], spec.rate, spec.n, spec.heavy_tail,
            spec.seed,
        )
    } else {
        mixed_trace(datasets, spec.rate, spec.n, spec.seed)
    };
    let mut lat = Histogram::latency();
    let mut ttft = Histogram::latency();
    let mut tok = Histogram::latency();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for r in &trace {
        let due = Duration::from_secs_f64(r.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        pending.push((
            Instant::now(),
            coord.submit(Request {
                id: 0,
                task: r.task.clone(),
                prompt: r.prompt.clone(),
                policy: spec.policy.into(),
                slo_ms: None,
            }),
        ));
    }
    let mut ok = 0;
    let mut completions = Vec::with_capacity(pending.len());
    for (sent, rx) in pending {
        let resp = rx.recv()?;
        let e2e_us = sent.elapsed().as_secs_f64() * 1e6;
        if resp.error.is_none() {
            ok += 1;
            ttft.record(resp.ttft_ms * 1e3);
            tok.record(e2e_us / model_cfg.gen_len as f64);
        }
        completions.push(resp.completion);
        lat.record(e2e_us);
    }
    let wall = t0.elapsed().as_secs_f64();
    std::thread::sleep(STATS_SETTLE);
    let steps = (c0("scheduler_steps") - steps0).max(1);
    let seq_steps = c0("scheduled_seq_steps") - seq_steps0;
    let transferred = (c0("bytes_uploaded") - up0) + (c0("bytes_downloaded") - down0);
    let cache_upload_bytes = c0("cache_bytes_uploaded") - cache_up0;
    let window_passes = c0("window_passes") - window0;
    let fused_passes = c0("fused_window_passes") - fused0;
    let saved_passes = c0("prefix_sharing_saved_full_passes") - saved0;
    let full_passes = c0("full_passes") - full0;
    let steps_elided = c0("steps_elided") - elided0;
    let shed = c0("requests_shed") - shed0;
    let tokens = (ok * model_cfg.gen_len).max(1);
    // forecast-quality histograms (DESIGN.md §15); per-coordinator, so the
    // only samples outside the timed region are this point's own warm-ups
    let hq = |name: &str, q: f64| {
        coord.metrics.histogram(name).lock().unwrap().quantile(q)
    };
    Ok(Point {
        policy: spec.policy.to_string(),
        cache: spec.cache_label,
        residency: spec.residency,
        rate: spec.rate,
        ok,
        n: spec.n,
        p50_ms: lat.quantile(0.5) / 1e3,
        p95_ms: lat.quantile(0.95) / 1e3,
        p99_ms: lat.quantile(0.99) / 1e3,
        ttft_p50_ms: ttft.quantile(0.5) / 1e3,
        ttft_p95_ms: ttft.quantile(0.95) / 1e3,
        ttft_p99_ms: ttft.quantile(0.99) / 1e3,
        tok_p50_ms: tok.quantile(0.5) / 1e3,
        tok_p95_ms: tok.quantile(0.95) / 1e3,
        tok_p99_ms: tok.quantile(0.99) / 1e3,
        tokens_per_sec: (ok * model_cfg.gen_len) as f64 / wall,
        bytes_per_token: transferred as f64 / tokens as f64,
        cache_upload_bytes,
        fused_frac: fused_passes as f64 / window_passes.max(1) as f64,
        bytes_per_step: transferred as f64 / steps as f64,
        prefix_hit_rate: saved_passes as f64 / ok.max(1) as f64,
        steps_executed: full_passes + window_passes,
        steps_elided,
        admission_p95_ms: hq("admission_wait", 0.95) / 1e3,
        predicted_steps_p50: hq("predicted_steps", 0.5),
        forecast_abs_err_p95: hq("forecast_error", 0.95),
        shed_rate: shed as f64 / spec.n as f64,
        occ_mean: seq_steps as f64 / steps as f64,
        occ_peak: coord
            .metrics
            .gauge("batch_occupancy_peak")
            .load(Ordering::Relaxed),
        completions,
    })
}

/// The cached host/device runs see the same trace with deterministic
/// policies — scheduling must not change tokens (DESIGN.md §5, §10).
fn check_token_identity(points: &[Point]) -> Result<usize> {
    let mut checked = 0;
    for a in points {
        if a.cache != "on" || a.residency != "host" {
            continue;
        }
        if let Some(b) = points.iter().find(|b| {
            b.cache == "on"
                && b.residency == "device"
                && b.policy == a.policy
                && b.rate == a.rate
        }) {
            if a.completions != b.completions {
                bail!(
                    "host/device completions diverge for {} @{}rps",
                    a.policy,
                    a.rate
                );
            }
            checked += 1;
        }
    }
    Ok(checked)
}

fn point_rows(points: &[Point]) -> (Vec<Vec<String>>, Vec<Vec<String>>) {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut last_policy = String::new();
    for p in points {
        if !last_policy.is_empty() && p.policy != last_policy {
            rows.push(vec![String::new(); 13]);
        }
        last_policy = p.policy.clone();
        rows.push(vec![
            p.policy.clone(),
            format!("{}{}", p.cache, if p.cache == "on" { format!(":{}", p.residency) } else { String::new() }),
            format!("{}", p.rate),
            format!("{}/{}", p.ok, p.n),
            format!("{:.0}", p.p50_ms),
            format!("{:.0}", p.p95_ms),
            format!("{:.0}", p.p99_ms),
            format!("{:.0}", p.ttft_p50_ms),
            format!("{:.0}", p.ttft_p95_ms),
            format!("{:.1}", p.tokens_per_sec),
            format!("{:.0}", p.bytes_per_token),
            format!("{:.2}", p.occ_mean),
            format!("{}", p.occ_peak),
        ]);
        csv.push(vec![
            p.policy.clone(),
            p.cache.to_string(),
            p.residency.to_string(),
            format!("{}", p.rate),
            format!("{}", p.p50_ms * 1e3),
            format!("{}", p.p95_ms * 1e3),
            format!("{}", p.p99_ms * 1e3),
            format!("{}", p.ttft_p50_ms * 1e3),
            format!("{}", p.ttft_p95_ms * 1e3),
            format!("{}", p.ttft_p99_ms * 1e3),
            format!("{}", p.tok_p50_ms * 1e3),
            format!("{}", p.tok_p95_ms * 1e3),
            format!("{}", p.tok_p99_ms * 1e3),
            format!("{}", p.tokens_per_sec),
            format!("{}", p.bytes_per_token),
            format!("{}", p.cache_upload_bytes),
            format!("{}", p.fused_frac),
            format!("{}", p.bytes_per_step),
            format!("{}", p.prefix_hit_rate),
            format!("{}", p.steps_executed),
            format!("{}", p.steps_elided),
            format!("{}", p.admission_p95_ms * 1e3),
            format!("{}", p.predicted_steps_p50),
            format!("{}", p.forecast_abs_err_p95),
            format!("{}", p.shed_rate),
            format!("{}", p.occ_mean),
            format!("{}", p.occ_peak),
        ]);
    }
    (rows, csv)
}

/// Schema version of the committed `bench/trajectory/` artifact. Bump it
/// whenever a row field changes meaning; `scripts/bench_diff.py` refuses to
/// compare mismatched schemas. v2 added seeded open-loop arrivals plus
/// p99 / TTFT / per-token percentile fields. `steps_executed` /
/// `steps_elided` — and the predictive-scheduling fields `admission_p95_ms`
/// / `predicted_steps_p50` / `forecast_abs_err_p95` / `shed_rate`
/// (DESIGN.md §15) — are additive within v2: diffing tools treat their
/// absence in an older artifact as "not recorded", never as zero.
const BENCH_SCHEMA: f64 = 2.0;

fn points_json(points: &[Point], mode: &str, seed: u64) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("serving_load".into())),
        ("schema", Json::Num(BENCH_SCHEMA)),
        ("mode", Json::Str(mode.into())),
        ("seed", Json::Num(seed as f64)),
        ("provenance", Json::Str("measured".into())),
        (
            "rows",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("policy", Json::Str(p.policy.clone())),
                            ("cache", Json::Str(p.cache.into())),
                            ("residency", Json::Str(p.residency.into())),
                            ("rate", Json::Num(p.rate)),
                            ("ok", Json::Num(p.ok as f64)),
                            ("n", Json::Num(p.n as f64)),
                            ("p50_ms", Json::Num(p.p50_ms)),
                            ("p95_ms", Json::Num(p.p95_ms)),
                            ("p99_ms", Json::Num(p.p99_ms)),
                            ("ttft_p50_ms", Json::Num(p.ttft_p50_ms)),
                            ("ttft_p95_ms", Json::Num(p.ttft_p95_ms)),
                            ("ttft_p99_ms", Json::Num(p.ttft_p99_ms)),
                            ("tok_p50_ms", Json::Num(p.tok_p50_ms)),
                            ("tok_p95_ms", Json::Num(p.tok_p95_ms)),
                            ("tok_p99_ms", Json::Num(p.tok_p99_ms)),
                            ("tokens_per_sec", Json::Num(p.tokens_per_sec)),
                            ("bytes_per_token", Json::Num(p.bytes_per_token)),
                            (
                                "cache_upload_bytes",
                                Json::Num(p.cache_upload_bytes as f64),
                            ),
                            ("fused_frac", Json::Num(p.fused_frac)),
                            ("bytes_per_step", Json::Num(p.bytes_per_step)),
                            ("prefix_hit_rate", Json::Num(p.prefix_hit_rate)),
                            ("steps_executed", Json::Num(p.steps_executed as f64)),
                            ("steps_elided", Json::Num(p.steps_elided as f64)),
                            ("admission_p95_ms", Json::Num(p.admission_p95_ms)),
                            (
                                "predicted_steps_p50",
                                Json::Num(p.predicted_steps_p50),
                            ),
                            (
                                "forecast_abs_err_p95",
                                Json::Num(p.forecast_abs_err_p95),
                            ),
                            ("shed_rate", Json::Num(p.shed_rate)),
                            ("occ_mean", Json::Num(p.occ_mean)),
                            ("occ_peak", Json::Num(p.occ_peak as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Synthetic in-memory datasets for the artifact-free smoke run.
fn sim_datasets() -> Vec<Dataset> {
    ["synth-math", "synth-qa"]
        .iter()
        .map(|task| Dataset {
            task: task.to_string(),
            examples: (0..6)
                .map(|i| Example {
                    task: task.to_string(),
                    prompt: format!("Q: {i}+1=?"),
                    answer: format!("{}", i + 1),
                    code_op: None,
                })
                .collect(),
        })
        .collect()
}

/// Single-block smoke config: one K/V refresh per decode, so a shared-
/// prefix run's executed-refresh count is directly comparable to its
/// request count.
fn one_block_config() -> ModelConfig {
    let mut cfg = tiny_config();
    cfg.gen_len = cfg.block_len;
    cfg.num_blocks = 1;
    cfg.seq_len = cfg.prompt_len + cfg.gen_len;
    cfg
}

/// N requests over `k` distinct prompt templates — the workload where the
/// prompt-prefix index (DESIGN.md §13) pays: a re-used template costs page
/// references instead of a block-0 K/V refresh.
fn shared_prefix_datasets(k: usize) -> Vec<Dataset> {
    vec![Dataset {
        task: "synth-qa".to_string(),
        examples: (0..k)
            .map(|i| Example {
                task: "synth-qa".to_string(),
                prompt: format!("Template {i}: 2+{i}=?"),
                answer: format!("{}", i + 2),
                code_op: None,
            })
            .collect(),
    }]
}

/// One fleet-tier run plus the router counters the §16 inline assertions
/// need alongside the measured point.
struct FleetOutcome {
    point: Point,
    retries: u64,
    replica_failures: u64,
}

/// Drive the shared arrival trace through the process-tier router
/// (DESIGN.md §16): two in-process sim replicas on the same seed behind a
/// real `FleetRouter` on TCP, measured from a retrying line-protocol
/// client. `kill_at` tears down replica 0 (server + coordinator)
/// immediately before that trace index, so the router must notice the
/// transport failure mid-trace and fail the request over to the survivor.
///
/// The admission/forecast histograms live inside each replica's
/// coordinator and are not observable through the wire, so those Point
/// fields are recorded as 0 here — diff tooling never gates them on
/// fleet rows.
fn run_fleet_point(
    label: &'static str,
    kill_at: Option<usize>,
    model_cfg: &ModelConfig,
    datasets: &[Dataset],
    n: usize,
    rate: f64,
    seed: u64,
) -> Result<FleetOutcome> {
    let mut replicas: Vec<Option<(Server, Arc<Coordinator>)>> = Vec::new();
    let mut specs = Vec::new();
    for id in 0..2 {
        // both replicas share the sim seed, so completions are
        // token-identical no matter which one serves a request
        let coord = Arc::new(Coordinator::start(
            CoordinatorConfig::default(),
            model_cfg.clone(),
            |_| Ok(SimModel::math_like(5)),
        )?);
        let server = Server::start("127.0.0.1:0", coord.clone())?;
        specs.push(ReplicaSpec { id, addr: server.addr.to_string() });
        replicas.push(Some((server, coord)));
    }
    // metric handles outlive the teardown of their coordinator: the dead
    // replica's counters freeze at death and still sum correctly
    let coords: Vec<Arc<Coordinator>> = replicas
        .iter()
        .map(|r| r.as_ref().unwrap().1.clone())
        .collect();
    let router = FleetRouter::start(RouterConfig {
        replicas: specs,
        health_interval: Duration::from_millis(50),
        request_timeout: Duration::from_secs(10),
        max_retries: 4,
        backoff_base: Duration::from_millis(5),
        backoff_max: Duration::from_millis(40),
        ..RouterConfig::default()
    })?;
    let mut client = Client::connect(router.addr)?;
    let retry = RetryPolicy {
        max_retries: 6,
        backoff_base: Duration::from_millis(10),
        backoff_max: Duration::from_millis(80),
        seed,
    };
    let policy = "static:0.9";
    // warm-up outside the timed region, mirroring `run_point`
    for ds in datasets {
        let r = client.generate_with_retry(
            &ds.task,
            &ds.examples[0].prompt,
            policy,
            &retry,
        )?;
        if let Some(e) = r.error {
            bail!("fleet warm-up failed: {e}");
        }
    }
    std::thread::sleep(STATS_SETTLE);
    let c0 = |name: &str| -> u64 {
        coords.iter().map(|c| c.metrics.counter_value(name)).sum()
    };
    let steps0 = c0("scheduler_steps");
    let seq_steps0 = c0("scheduled_seq_steps");
    let up0 = c0("bytes_uploaded");
    let down0 = c0("bytes_downloaded");
    let cache_up0 = c0("cache_bytes_uploaded");
    let window0 = c0("window_passes");
    let fused0 = c0("fused_window_passes");
    let saved0 = c0("prefix_sharing_saved_full_passes");
    let full0 = c0("full_passes");
    let elided0 = c0("steps_elided");

    let trace = mixed_trace(datasets, rate, n, seed);
    let mut lat = Histogram::latency();
    let mut ttft = Histogram::latency();
    let mut tok = Histogram::latency();
    let t0 = Instant::now();
    let mut ok = 0;
    let mut completions = Vec::with_capacity(trace.len());
    for (i, r) in trace.iter().enumerate() {
        if Some(i) == kill_at {
            if let Some((server, coord)) = replicas[0].take() {
                // closing the listener is what kills the replica from the
                // router's perspective; the idle coordinator's workers are
                // joined when `coords` drops at the end of the run
                server.stop();
                drop(coord);
            }
        }
        let due = Duration::from_secs_f64(r.at);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let sent = Instant::now();
        let resp =
            client.generate_with_retry(&r.task, &r.prompt, policy, &retry)?;
        let e2e_us = sent.elapsed().as_secs_f64() * 1e6;
        if resp.error.is_none() {
            ok += 1;
            ttft.record(resp.ttft_ms * 1e3);
            tok.record(e2e_us / model_cfg.gen_len as f64);
        }
        completions.push(resp.completion);
        lat.record(e2e_us);
    }
    let wall = t0.elapsed().as_secs_f64();
    std::thread::sleep(STATS_SETTLE);
    let steps = (c0("scheduler_steps") - steps0).max(1);
    let seq_steps = c0("scheduled_seq_steps") - seq_steps0;
    let transferred =
        (c0("bytes_uploaded") - up0) + (c0("bytes_downloaded") - down0);
    let cache_upload_bytes = c0("cache_bytes_uploaded") - cache_up0;
    let window_passes = c0("window_passes") - window0;
    let fused_passes = c0("fused_window_passes") - fused0;
    let saved_passes = c0("prefix_sharing_saved_full_passes") - saved0;
    let full_passes = c0("full_passes") - full0;
    let steps_elided = c0("steps_elided") - elided0;
    let tokens = (ok * model_cfg.gen_len).max(1);
    let rm = router.metrics();
    let outcome = FleetOutcome {
        point: Point {
            policy: policy.to_string(),
            cache: label,
            residency: "sim",
            rate,
            ok,
            n,
            p50_ms: lat.quantile(0.5) / 1e3,
            p95_ms: lat.quantile(0.95) / 1e3,
            p99_ms: lat.quantile(0.99) / 1e3,
            ttft_p50_ms: ttft.quantile(0.5) / 1e3,
            ttft_p95_ms: ttft.quantile(0.95) / 1e3,
            ttft_p99_ms: ttft.quantile(0.99) / 1e3,
            tok_p50_ms: tok.quantile(0.5) / 1e3,
            tok_p95_ms: tok.quantile(0.95) / 1e3,
            tok_p99_ms: tok.quantile(0.99) / 1e3,
            tokens_per_sec: (ok * model_cfg.gen_len) as f64 / wall,
            bytes_per_token: transferred as f64 / tokens as f64,
            cache_upload_bytes,
            fused_frac: fused_passes as f64 / window_passes.max(1) as f64,
            bytes_per_step: transferred as f64 / steps as f64,
            prefix_hit_rate: saved_passes as f64 / ok.max(1) as f64,
            steps_executed: full_passes + window_passes,
            steps_elided,
            admission_p95_ms: 0.0,
            predicted_steps_p50: 0.0,
            forecast_abs_err_p95: 0.0,
            shed_rate: rm.counter_value("fleet_requests_shed") as f64
                / n as f64,
            occ_mean: seq_steps as f64 / steps as f64,
            occ_peak: coords
                .iter()
                .map(|c| {
                    c.metrics
                        .gauge("batch_occupancy_peak")
                        .load(Ordering::Relaxed)
                })
                .max()
                .unwrap_or(0),
            completions,
        },
        retries: rm.counter_value("fleet_request_retries"),
        replica_failures: rm.counter_value("fleet_replica_failures"),
    };
    router.stop();
    for slot in replicas.iter_mut() {
        if let Some((server, coord)) = slot.take() {
            server.stop();
            drop(coord);
        }
    }
    // last Arcs: dropping them joins each coordinator's workers
    drop(coords);
    Ok(outcome)
}

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(
        std::env::args().skip(1).collect::<Vec<_>>(),
        &["n", "rates", "workers", "max-batch", "cache-residency", "seed", "json"],
    )?;
    let smoke = args.has("smoke");
    let n: usize = args.get_parse("n", if smoke { 6 } else { 24 })?;
    let seed: u64 = args.get_parse("seed", 7u64)?;
    let workers: usize = args.get_parse("workers", 1)?;
    let max_batch: usize = args.get_parse("max-batch", 4)?;
    let rates: Vec<f64> = args
        .get_or("rates", if smoke { "8" } else { "2,6,12" })
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();
    // smoke runs on SimModel, which mints host handles regardless — one
    // cached point per policy, no vacuous host/device duplicate rows
    let default_residency = if smoke { "device" } else { "both" };
    let residencies: Vec<Residency> = match args.get_or("cache-residency", default_residency) {
        "both" => vec![Residency::Host, Residency::Device],
        one => vec![Residency::parse(one)?],
    };
    let policies = ["osdt:block:q1:0.75:0.2", "static:0.9", "sequential:1"];

    let (model_cfg, datasets) = if smoke {
        // steps-capped CI configuration on the analytic simulator: every
        // decode is bounded by gen_len policy steps and n is small, so the
        // whole bench is a few thousand scheduler steps
        (tiny_config(), sim_datasets())
    } else {
        let cfg = ModelConfig::load("artifacts")?;
        let data_dir = cfg.artifact_dir.join("data");
        let datasets = vec![
            Dataset::load(&data_dir, "synth-math")?,
            Dataset::load(&data_dir, "synth-qa")?,
        ];
        (cfg, datasets)
    };

    let mut points = Vec::new();
    for policy in policies {
        // cache off: residency is irrelevant (no K/V exists) — one point
        let mut configs: Vec<(&'static str, CacheConfig, Residency)> =
            vec![("off", CacheConfig::disabled(), Residency::Device)];
        for &r in &residencies {
            configs.push(("on", CacheConfig::block_boundary(), r));
        }
        for (cache_label, cache, residency) in configs {
            for &rate in &rates {
                let spec = PointSpec {
                    policy,
                    cache,
                    cache_label,
                    // SimModel has no device path: label honestly so the
                    // JSON artifact can't be read as a residency A/B
                    residency: if smoke { "sim" } else { residency.as_str() },
                    rate,
                    n,
                    workers,
                    max_batch,
                    seed,
                    step_elision: false,
                    predictive: true,
                    heavy_tail: 0,
                };
                let p = if smoke {
                    run_point(&spec, &model_cfg, &datasets, None, |_wid| {
                        Ok(SimModel::math_like(5))
                    })?
                } else {
                    run_point(&spec, &model_cfg, &datasets, None, move |_wid| {
                        let cfg = ModelConfig::load("artifacts")?;
                        let rt = ModelRuntime::load(&cfg)?;
                        rt.set_residency(residency);
                        Ok(rt)
                    })?
                };
                eprintln!(
                    "[load] {policy} cache={cache_label}:{} @{rate}rps: \
                     p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms ttft p95 {:.0}ms \
                     {:.1} tok/s {:.0} B/tok \
                     (kv up {} B, fused {:.0}%, {:.0} B/step) occ {:.2} \
                     (peak {})",
                    spec.residency,
                    p.p50_ms,
                    p.p95_ms,
                    p.p99_ms,
                    p.ttft_p95_ms,
                    p.tokens_per_sec,
                    p.bytes_per_token,
                    p.cache_upload_bytes,
                    p.fused_frac * 100.0,
                    p.bytes_per_step,
                    p.occ_mean,
                    p.occ_peak
                );
                points.push(p);
            }
        }
    }

    // --- shared-prefix workload (DESIGN.md §13): the same template-heavy
    // trace with prefix sharing off ("unshared") vs on ("shared"). Smoke
    // runs a single-block config on one counter-instrumented SimModel
    // (clones share `full_kv_calls`) and asserts the sharing run executed
    // strictly fewer K/V refreshes than it served requests; both runs must
    // produce identical completions — sharing is a transport optimisation,
    // never an approximation.
    let shared_policy = "static:0.9";
    let shared_data = shared_prefix_datasets(3);
    let shared_cfg = if smoke { one_block_config() } else { model_cfg.clone() };
    let sim_shared = SimModel::math_like(5).with_config(shared_cfg.clone());
    let mut shared_points = Vec::new();
    let mut calls_before_shared = 0;
    for (label, cache) in [
        ("unshared", CacheConfig::block_boundary()),
        (
            "shared",
            CacheConfig::block_boundary().paged(8).with_prefix_sharing(true),
        ),
    ] {
        if label == "shared" {
            calls_before_shared = sim_shared.full_kv_calls();
        }
        let spec = PointSpec {
            policy: shared_policy,
            cache,
            cache_label: label,
            residency: if smoke { "sim" } else { "host" },
            rate: rates[0],
            n,
            workers,
            max_batch,
            seed,
            step_elision: false,
            predictive: true,
            heavy_tail: 0,
        };
        let p = if smoke {
            let proto = sim_shared.clone();
            run_point(&spec, &shared_cfg, &shared_data, None, move |_wid| {
                Ok(proto.clone())
            })?
        } else {
            run_point(&spec, &shared_cfg, &shared_data, None, move |_wid| {
                let cfg = ModelConfig::load("artifacts")?;
                let rt = ModelRuntime::load(&cfg)?;
                // prefix-index inserts need host-visible K/V (DESIGN.md §13)
                rt.set_residency(Residency::Host);
                Ok(rt)
            })?
        };
        eprintln!(
            "[shared-prefix] {shared_policy} cache={label} @{}rps: \
             {:.1} tok/s, prefix hit rate {:.0}%",
            spec.rate,
            p.tokens_per_sec,
            p.prefix_hit_rate * 100.0
        );
        shared_points.push(p);
    }
    if shared_points[0].completions != shared_points[1].completions {
        bail!("prefix sharing changed completions on the shared-prefix trace");
    }
    println!("token identity: shared == unshared on the shared-prefix trace");
    if smoke && workers == 1 {
        // warm-up (one per dataset) + timed requests, one refresh each on
        // the single-block config
        let requests = (n + shared_data.len()) as u64;
        let executed = sim_shared.full_kv_calls() - calls_before_shared;
        if executed >= requests {
            bail!(
                "prefix sharing executed {executed} fwd_full_kv calls for \
                 {requests} requests — the prompt-prefix index is not sharing"
            );
        }
        println!(
            "prefix sharing: {executed} executed K/V refreshes for {requests} \
             requests (hit rate {:.0}%)",
            shared_points[1].prefix_hit_rate * 100.0
        );
    }
    points.extend(shared_points);

    // --- profile-guided step elision A/B (DESIGN.md §14): the same arrival
    // trace decoded under the same hand-built step-block profile with the
    // elision planner off vs on. The profile stages a three-step empty run
    // inside every block and the plateau simulator's confidences are
    // position-pure, so the planner's predictions hold exactly: the elide-on
    // point must emit token-identical completions in strictly fewer executed
    // passes. Always runs on the analytic simulator — the claim under test
    // is the schedule, not device timing — so the rows are labelled "sim".
    let elision_policy = "osdt:step-block:q1:1:0";
    let elision_cfg = tiny_config();
    let elision_data = vec![Dataset {
        task: "synth-qa".to_string(),
        examples: (0..3)
            .map(|i| Example {
                task: "synth-qa".to_string(),
                prompt: format!("Plateau {i}: 2+{i}=?"),
                answer: format!("{}", i + 2),
                code_op: None,
            })
            .collect(),
    }];
    // Per-block schedule: full-KV step commits the high-confidence
    // positions, three steps predicted empty (accepts ~1 = fallback only),
    // then a cheap landing step drains the rest.
    let elidable = Profile::step_block(
        vec![vec![0.5, 0.995, 0.995, 0.995, 0.25]; elision_cfg.num_blocks],
        Metric::Q1,
    )
    .with_accepts(vec![vec![8.0, 1.0, 1.0, 1.0, 9.0]; elision_cfg.num_blocks]);
    let mut elision_points = Vec::new();
    for (label, elide) in [("elide-off", false), ("elide-on", true)] {
        // fresh registry per point: both runs decode from the seeded
        // profile, neither pays a calibration in the timed region
        let registry = Arc::new(ProfileRegistry::in_memory());
        match registry.acquire(&ProfileKey::new(
            "synth-qa",
            DynamicMode::StepBlock,
            Metric::Q1,
        )) {
            Acquired::Lease(lease) => lease.fulfill(elidable.clone(), vec![0.5; 4]),
            _ => bail!("seeding the elision profile must grant the lease"),
        }
        let spec = PointSpec {
            policy: elision_policy,
            cache: CacheConfig::block_boundary(),
            cache_label: label,
            residency: "sim",
            rate: rates[0],
            n,
            workers,
            max_batch,
            seed,
            step_elision: elide,
            predictive: true,
            heavy_tail: 0,
        };
        let p = run_point(&spec, &elision_cfg, &elision_data, Some(registry), |_wid| {
            Ok(SimModel::plateau_like(7))
        })?;
        eprintln!(
            "[elision] {elision_policy} {label} @{}rps: {:.1} tok/s, \
             {} executed passes, {} elided",
            spec.rate, p.tokens_per_sec, p.steps_executed, p.steps_elided
        );
        elision_points.push(p);
    }
    {
        let (off, on) = (&elision_points[0], &elision_points[1]);
        if off.completions != on.completions {
            bail!("step elision changed completions on the plateau trace");
        }
        if on.steps_elided == 0 {
            bail!("elide-on executed the full schedule — the planner never fired");
        }
        if on.steps_executed >= off.steps_executed {
            bail!(
                "elision saved nothing: {} executed passes with the planner on \
                 vs {} off",
                on.steps_executed,
                off.steps_executed
            );
        }
        println!(
            "step elision: token-identical, {} -> {} executed passes \
             ({} elided)",
            off.steps_executed, on.steps_executed, on.steps_elided
        );
    }
    points.extend(elision_points);

    // --- FIFO vs predictive admission A/B (DESIGN.md §15): the same
    // mixed-length heavy-tail burst admitted in arrival order vs by
    // predicted cost. Two tasks decode under seeded step-block profiles
    // whose trajectories differ ~4x in depth (short: 18 forecast passes,
    // long: 78); the trace lands the two long jobs right behind the first
    // short arrival, so under FIFO the whole short class queues behind
    // them while predicted-cost admission defers exactly the tail. A
    // single serial slot (workers=1, max-batch=1) and a burst arrival rate
    // make the queueing deterministic. Admission order is pure scheduling:
    // completions and executed passes must be identical across arms; only
    // the waiting moves.
    let sched_policy = "osdt:step-block:q1:1:0";
    let sched_cfg = tiny_config();
    let short_profile = Profile::step_block(
        vec![vec![0.5, 0.995, 0.995, 0.995, 0.25]; sched_cfg.num_blocks],
        Metric::Q1,
    )
    .with_accepts(vec![vec![8.0, 1.0, 1.0, 1.0, 9.0]; sched_cfg.num_blocks]);
    let mut long_taus = vec![0.5];
    long_taus.extend(std::iter::repeat(0.995).take(23));
    long_taus.push(0.25);
    let mut long_accepts = vec![8.0];
    // accepts 2.0 sit above the default elide floor so the long task's
    // forecast stays at full depth even if elision is ever turned on here
    long_accepts.extend(std::iter::repeat(2.0).take(23));
    long_accepts.push(9.0);
    let long_profile = Profile::step_block(
        vec![long_taus; sched_cfg.num_blocks],
        Metric::Q1,
    )
    .with_accepts(vec![long_accepts; sched_cfg.num_blocks]);
    let tail_data: Vec<Dataset> = [("synth-short", 0), ("synth-long", 1)]
        .iter()
        .map(|(task, salt)| Dataset {
            task: task.to_string(),
            examples: (0..3)
                .map(|i| Example {
                    task: task.to_string(),
                    prompt: format!("Tail {salt}.{i}: 2+{i}=?"),
                    answer: format!("{}", i + 2),
                    code_op: None,
                })
                .collect(),
        })
        .collect();
    // tail fraction must stay under 5% of the trace so the overall p95
    // lands in the short class: 2 long jobs in 48 requests
    let (ab_n, ab_heavy) = (48, 2);
    let mut sched_points = Vec::new();
    for (label, predictive) in [("fifo", false), ("predictive", true)] {
        // fresh registry per arm, both tasks pre-seeded: no calibration in
        // the timed region, and every forecast comes from a real trajectory
        let registry = Arc::new(ProfileRegistry::in_memory());
        for (task, profile) in
            [("synth-short", &short_profile), ("synth-long", &long_profile)]
        {
            match registry.acquire(&ProfileKey::new(
                task,
                DynamicMode::StepBlock,
                Metric::Q1,
            )) {
                Acquired::Lease(lease) => {
                    lease.fulfill(profile.clone(), vec![0.5; 4])
                }
                _ => bail!("seeding the {task} profile must grant the lease"),
            }
        }
        let spec = PointSpec {
            policy: sched_policy,
            cache: CacheConfig::block_boundary(),
            cache_label: label,
            residency: "sim",
            // burst: every arrival is due ~immediately, so the backlog the
            // two arms order differently is the whole trace
            rate: 1e6,
            n: ab_n,
            workers: 1,
            max_batch: 1,
            seed,
            step_elision: false,
            predictive,
            heavy_tail: ab_heavy,
        };
        let p = run_point(&spec, &sched_cfg, &tail_data, Some(registry), |_wid| {
            Ok(SimModel::plateau_like(7))
        })?;
        eprintln!(
            "[admission] {label}: admission p95 {:.2}ms, predicted p50 \
             {:.0} passes, forecast |err| p95 {:.1}, {:.1} tok/s, shed \
             {:.0}%",
            p.admission_p95_ms,
            p.predicted_steps_p50,
            p.forecast_abs_err_p95,
            p.tokens_per_sec,
            p.shed_rate * 100.0
        );
        sched_points.push(p);
    }
    {
        let (fifo, pred) = (&sched_points[0], &sched_points[1]);
        if fifo.completions != pred.completions {
            bail!("admission order changed completions on the heavy-tail trace");
        }
        if pred.steps_executed != fifo.steps_executed {
            bail!(
                "admission order changed executed passes: {} predictive vs \
                 {} fifo",
                pred.steps_executed,
                fifo.steps_executed
            );
        }
        if pred.admission_p95_ms > fifo.admission_p95_ms {
            bail!(
                "predicted-cost admission did not lower p95 admission wait: \
                 {:.2}ms predictive vs {:.2}ms fifo",
                pred.admission_p95_ms,
                fifo.admission_p95_ms
            );
        }
        // executed passes are asserted identical above, so throughput can
        // only differ by scheduling overhead plus timer noise on a short
        // timed region — gate the overhead, not the noise
        if pred.tokens_per_sec < 0.75 * fifo.tokens_per_sec {
            bail!(
                "predictive admission cost throughput: {:.1} tok/s vs {:.1} \
                 fifo",
                pred.tokens_per_sec,
                fifo.tokens_per_sec
            );
        }
        if fifo.shed_rate != 0.0 || pred.shed_rate != 0.0 {
            bail!("requests were shed with no watermark or SLO configured");
        }
        if !pred.forecast_abs_err_p95.is_finite()
            || !fifo.forecast_abs_err_p95.is_finite()
        {
            bail!("forecast error histogram is empty or non-finite");
        }
        // the median submitted request is a short one — its forecast must
        // come from the short trajectory, not the worst-case prior
        if pred.predicted_steps_p50 >= 78.0 {
            bail!(
                "predicted_steps p50 {:.0} sits at the long/worst-case tier \
                 — forecasts are not reading the calibrated trajectories",
                pred.predicted_steps_p50
            );
        }
        println!(
            "predictive admission: token-identical, p95 admission wait \
             {:.2}ms -> {:.2}ms on the heavy-tail burst",
            fifo.admission_p95_ms, pred.admission_p95_ms
        );
    }
    points.extend(sched_points);

    // --- Fleet tier A/B (DESIGN.md §16): the same trace driven through
    // the process-tier router over TCP, steady (both replicas up) vs
    // failover (replica 0 torn down mid-trace). Both arms run burst
    // arrivals on sim replicas sharing one seed, so failover is pure
    // rerouting: completions must be token-identical across arms and no
    // request may be dropped — the client's jittered-backoff retries plus
    // the router's transport-failure retries absorb the death entirely.
    // The arms always run on the simulator (the fleet tier is process
    // topology, not a model path), so full mode needs no artifacts here.
    let fleet_cfg = tiny_config();
    let fleet_data = sim_datasets();
    let (fleet_n, fleet_rate) = (n, 1e6);
    let steady = run_fleet_point(
        "fleet-steady",
        None,
        &fleet_cfg,
        &fleet_data,
        fleet_n,
        fleet_rate,
        seed,
    )?;
    let failover = run_fleet_point(
        "fleet-failover",
        Some(fleet_n / 2),
        &fleet_cfg,
        &fleet_data,
        fleet_n,
        fleet_rate,
        seed,
    )?;
    {
        let (s, f) = (&steady.point, &failover.point);
        if s.ok != fleet_n {
            bail!("fleet steady arm dropped requests: {}/{fleet_n}", s.ok);
        }
        if f.ok != fleet_n {
            bail!(
                "fleet failover dropped requests: {}/{fleet_n} — retries \
                 did not absorb the replica death",
                f.ok
            );
        }
        if s.completions != f.completions {
            bail!(
                "failover changed completions — rerouting to the survivor \
                 corrupted tokens"
            );
        }
        if steady.replica_failures != 0 || steady.retries != 0 {
            bail!(
                "steady fleet arm saw {} replica failure(s) and {} \
                 retrie(s) with nobody killed",
                steady.replica_failures,
                steady.retries
            );
        }
        // the killed replica is noticed either by a failed forward (which
        // increments the retry counter) or by the next health ping; burst
        // arrivals make the former overwhelmingly likely, but only the
        // disjunction is deterministic
        if failover.replica_failures == 0 && failover.retries == 0 {
            bail!("replica death mid-trace was never noticed by the router");
        }
        eprintln!(
            "[fleet] steady {:.1} tok/s; failover {:.1} tok/s, {} router \
             retrie(s), {} replica failure(s), token-identical, 0 dropped",
            s.tokens_per_sec,
            f.tokens_per_sec,
            failover.retries,
            failover.replica_failures
        );
    }
    points.push(steady.point);
    points.push(failover.point);

    let checked = check_token_identity(&points)?;
    if checked > 0 {
        println!("token identity: host == device for {checked} cached point(s)");
    }

    let (rows, csv) = point_rows(&points);
    println!("\n=== serving latency vs offered load (n={n}/point, mixed workload) ===");
    println!(
        "{}",
        render_table(
            &[
                "policy", "cache", "rps", "ok", "p50 ms", "p95 ms", "p99 ms",
                "ttft p50", "ttft p95", "tokens/s", "B/token", "occ mean",
                "occ peak"
            ],
            &rows
        )
    );
    write_csv(
        "results/serving_load.csv",
        &[
            "policy", "cache", "residency", "rate", "p50_us", "p95_us",
            "p99_us", "ttft_p50_us", "ttft_p95_us", "ttft_p99_us",
            "tok_p50_us", "tok_p95_us", "tok_p99_us",
            "tokens_per_sec", "bytes_per_token", "cache_upload_bytes",
            "fused_frac", "bytes_per_step", "prefix_hit_rate",
            "steps_executed", "steps_elided", "admission_p95_us",
            "predicted_steps_p50", "forecast_abs_err_p95", "shed_rate",
            "occ_mean", "occ_peak",
        ],
        &csv,
    )?;
    println!("csv -> results/serving_load.csv");
    if let Some(path) = args.get("json") {
        let doc = points_json(&points, if smoke { "smoke" } else { "full" }, seed);
        std::fs::write(path, format!("{doc}\n"))
            .with_context(|| format!("writing {path}"))?;
        println!("json -> {path}");
    }
    Ok(())
}
