//! Design-choice ablations (DESIGN.md A1–A4):
//!   calib_choice  sensitivity to WHICH sequence calibrates (A1)
//!   fallback      how often the argmax fallback fires per policy (A2)
//!   cache         dual KV cache on/off: throughput, accuracy, FLOPs (A3)
//!   metric        threshold metric μ at fixed κ, ε (A4)
//!
//!     cargo bench --bench ablations            # all
//!     cargo bench --bench ablations -- cache   # one

use anyhow::Result;

use osdt::bench::{render_table, run_eval, write_csv, RunOpts};
use osdt::cache::{CacheConfig, CacheStats};
use osdt::config::Args;
use osdt::model::ModelConfig;
use osdt::runtime::ModelRuntime;
use osdt::tokenizer::Tokenizer;
use osdt::workload::Dataset;

fn main() -> Result<()> {
    osdt::util::logging::init();
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &["n"])?;
    let n: usize = args.get_parse("n", 16)?;
    let which: Vec<&str> = if args.positional.is_empty() {
        vec!["calib_choice", "fallback", "cache", "metric", "adaptive"]
    } else {
        args.positional.iter().map(String::as_str).collect()
    };

    let cfg = ModelConfig::load("artifacts")?;
    let rt = ModelRuntime::load(&cfg)?;
    let tok = Tokenizer::from_config(&cfg)?;

    for name in which {
        match name {
            "calib_choice" => calib_choice(&rt, &tok, &cfg, n)?,
            "fallback" => fallback(&rt, &tok, &cfg, n)?,
            "cache" => cache(&rt, &tok, &cfg, n)?,
            "metric" => metric(&rt, &tok, &cfg, n)?,
            "adaptive" => adaptive(&rt, &tok, &cfg, n)?,
            other => eprintln!("unknown ablation {other:?}"),
        }
    }
    Ok(())
}

/// A1: calibrate on sequence k for several k; the paper's claim is that ONE
/// sequence suffices because signatures are task-level — so rows should be
/// near-identical.
fn calib_choice(
    rt: &ModelRuntime,
    tok: &Tokenizer,
    cfg: &ModelConfig,
    n: usize,
) -> Result<()> {
    let ds = Dataset::load(cfg.artifact_dir.join("data"), "synth-math")?;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for k in [0usize, 1, 2, 4, 8, 16] {
        let opts = RunOpts { n, calibration_index: k, ..Default::default() };
        let row = run_eval(rt, tok, &ds, "osdt:block:q1:0.75:0.2", &opts)?;
        rows.push(vec![
            k.to_string(),
            format!("{:.2}", row.accuracy * 100.0),
            format!("{:.1}", row.tokens_per_sec),
            format!("{:.1}", row.mean_steps),
        ]);
        csv.push(vec![
            k.to_string(),
            format!("{}", row.accuracy),
            format!("{}", row.tokens_per_sec),
        ]);
    }
    println!("\n=== A1: calibration-sequence choice (synth-math, n={n}) ===");
    println!(
        "{}",
        render_table(&["calib idx", "acc%", "tokens/s", "steps/seq"], &rows)
    );
    write_csv("results/ablation_calib_choice.csv", &["calib_idx", "accuracy", "tokens_per_sec"], &csv)?;
    Ok(())
}

/// A2: argmax-fallback activation rate per policy — the liveness mechanism
/// is load-bearing for strict thresholds and nearly idle for lax ones.
fn fallback(rt: &ModelRuntime, tok: &Tokenizer, cfg: &ModelConfig, n: usize) -> Result<()> {
    let ds = Dataset::load(cfg.artifact_dir.join("data"), "synth-math")?;
    let mut rows = Vec::new();
    for spec in [
        "static:0.99",
        "static:0.9",
        "osdt:block:q1:0.75:0.2",
        "osdt:block:q3:0.95:0.01",
        "factor:0.95",
    ] {
        let row = run_eval(rt, tok, &ds, spec, &RunOpts { n, ..Default::default() })?;
        rows.push(vec![
            spec.to_string(),
            format!("{:.1}", row.mean_steps),
            format!("{:.1}", row.mean_fallback),
            format!(
                "{:.0}%",
                row.mean_fallback / row.mean_steps.max(1e-9) * 100.0
            ),
        ]);
    }
    println!("\n=== A2: argmax fallback activations (synth-math, n={n}) ===");
    println!(
        "{}",
        render_table(&["policy", "steps/seq", "fallbacks/seq", "fallback rate"], &rows)
    );
    Ok(())
}

/// A3: Fast-dLLM dual KV cache on/off under the same policy.
fn cache(rt: &ModelRuntime, tok: &Tokenizer, cfg: &ModelConfig, n: usize) -> Result<()> {
    let ds = Dataset::load(cfg.artifact_dir.join("data"), "synth-math")?;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (label, cache) in [
        ("off", CacheConfig::disabled()),
        ("on (block refresh)", CacheConfig::block_boundary()),
        ("on (refresh every 4)", CacheConfig::with_refresh_interval(4)),
    ] {
        let opts = RunOpts { n, cache, ..Default::default() };
        let row = run_eval(rt, tok, &ds, "static:0.9", &opts)?;
        // analytic FLOPs from the pass mix of a representative decode
        let engine = osdt::decode::Engine::with_cache(rt, cache);
        let layout = tok.layout_prompt(cfg, &ds.examples[0].prompt)?;
        let res = engine.decode(layout, &osdt::policy::StaticThreshold::new(0.9))?;
        let mut st = CacheStats::default();
        st.add_decode(res.full_passes, res.window_passes);
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", row.accuracy * 100.0),
            format!("{:.1}", row.tokens_per_sec),
            format!("{:.1}", row.mean_latency_ms),
            format!("{:.0}%", st.savings(cfg) * 100.0),
        ]);
        csv.push(vec![
            label.to_string(),
            format!("{}", row.accuracy),
            format!("{}", row.tokens_per_sec),
            format!("{}", st.savings(cfg)),
        ]);
    }
    println!("\n=== A3: dual KV cache (synth-math, static:0.9, n={n}) ===");
    println!(
        "{}",
        render_table(
            &["cache", "acc%", "tokens/s", "latency ms", "FLOPs saved"],
            &rows
        )
    );
    write_csv("results/ablation_cache.csv", &["cache", "accuracy", "tokens_per_sec", "flops_saved"], &csv)?;
    Ok(())
}

/// A5: one-shot vs online-adaptive thresholds (the paper's future-work
/// direction). α=0 is exactly OSDT; α=1 tracks only the latest sequence.
/// The paper's cosine≈1 observation predicts adaptation buys ~nothing —
/// this ablation quantifies that.
fn adaptive(rt: &ModelRuntime, tok: &Tokenizer, cfg: &ModelConfig, n: usize) -> Result<()> {
    use osdt::decode::Engine;
    use osdt::eval::EvalStats;
    use osdt::policy::{
        AdaptiveOsdt, Calibrator, DynamicMode, Metric, Policy, StaticThreshold,
    };

    let ds = Dataset::load(cfg.artifact_dir.join("data"), "synth-math")?;
    let engine = Engine::new(rt);
    let mut rows = Vec::new();
    for alpha in [0.0, 0.2, 0.5, 1.0] {
        let layout = tok.layout_prompt(cfg, &ds.examples[0].prompt)?;
        let cal = engine.decode(layout, &StaticThreshold::new(0.9))?;
        let profile = Calibrator::calibrate(&cal.trace, DynamicMode::Block, Metric::Q1);
        let policy = AdaptiveOsdt::new(profile, 0.75, 0.2, alpha);
        let mut stats = EvalStats::default();
        let mut steps = 0usize;
        let t0 = std::time::Instant::now();
        for ex in ds.examples.iter().take(n) {
            let layout = tok.layout_prompt(cfg, &ex.prompt)?;
            let res = engine.decode(layout, &policy)?;
            steps += res.steps;
            policy.observe(&res.trace);
            stats.record(ex, &tok.decode_until_eos(res.gen_tokens(cfg)));
        }
        let wall = t0.elapsed().as_secs_f64();
        rows.push(vec![
            format!("{alpha}"),
            format!("{:.2}", stats.accuracy() * 100.0),
            format!("{:.1}", (n * cfg.gen_len) as f64 / wall),
            format!("{:.1}", steps as f64 / n as f64),
        ]);
    }
    println!("\n=== A5: one-shot (α=0) vs adaptive EMA thresholds (synth-math, n={n}) ===");
    println!(
        "{}",
        render_table(&["alpha", "acc%", "tokens/s", "steps/seq"], &rows)
    );
    Ok(())
}

/// A4: threshold metric μ at fixed κ=0.75, ε=0.1 (block mode, all tasks).
fn metric(rt: &ModelRuntime, tok: &Tokenizer, cfg: &ModelConfig, n: usize) -> Result<()> {
    let mut rows = Vec::new();
    for task in osdt::workload::TASKS {
        let ds = Dataset::load(cfg.artifact_dir.join("data"), task)?;
        for metric in ["mean", "q1", "q2", "q3", "min-whisker"] {
            let spec = format!("osdt:block:{metric}:0.75:0.1");
            let row = run_eval(rt, tok, &ds, &spec, &RunOpts { n, ..Default::default() })?;
            rows.push(vec![
                task.to_string(),
                metric.to_string(),
                format!("{:.2}", row.accuracy * 100.0),
                format!("{:.1}", row.tokens_per_sec),
                format!("{:.1}", row.mean_steps),
            ]);
        }
        rows.push(vec![String::new(); 5]);
    }
    println!("\n=== A4: threshold metric μ (block mode, κ=0.75 ε=0.1, n={n}) ===");
    println!(
        "{}",
        render_table(&["task", "metric", "acc%", "tokens/s", "steps/seq"], &rows)
    );
    Ok(())
}
