//! Fault-injection harness: the serving stack under injected failures.
//!
//! Three scenarios, each asserting the DESIGN.md §9 single-flight and
//! liveness invariants hold *and* that the failure shows up in a dedicated
//! metric (the observability half of the contract — an operator watching
//! `/metrics` must see every one of these):
//!
//! 1. a worker's forward pass dies mid-decode (scheduler step failure);
//! 2. a calibration decode crashes while holding the fleet lease;
//! 3. a calibration lease goes stuck and peers steal it (takeover churn).
//!
//! Failures are injected through [`osdt::sim::Chaos`] — an atomic
//! fail-budget on the simulator's forward passes — so scheduler and
//! coordinator internals are exercised exactly as a real backend error
//! would exercise them.

use std::sync::Arc;
use std::time::Duration;

use osdt::cache::CacheConfig;
use osdt::coordinator::{Coordinator, CoordinatorConfig, Request};
use osdt::model::fixtures::tiny_config;
use osdt::policy::{Acquired, DynamicMode, Metric, ProfileKey};
use osdt::sim::{Chaos, SimModel};

const OSDT_SPEC: &str = "osdt:block:q1:0.75:0.2";

fn key() -> ProfileKey {
    ProfileKey::new("synth-math", DynamicMode::Block, Metric::Q1)
}

fn chaos_coordinator(cfg: CoordinatorConfig) -> (Coordinator, Arc<Chaos>) {
    let chaos = Chaos::new();
    let model = SimModel::math_like(5).with_chaos(chaos.clone());
    let c = Coordinator::start(cfg, tiny_config(), move |_wid| Ok(model.clone()))
        .unwrap();
    (c, chaos)
}

#[test]
fn worker_killed_mid_decode_fails_fast_and_recovers() {
    let (c, chaos) = chaos_coordinator(CoordinatorConfig::default());

    // the next forward pass dies: the scheduler step is poisoned, the
    // request is failed, and the worker rebuilds its scheduler
    chaos.fail_next(1);
    let dead = c.generate("synth-math", "Q: 1+2=?", "static:0.9").unwrap();
    assert!(dead.error.is_some(), "poisoned step must fail the request");
    assert_eq!(chaos.injected(), 1, "exactly one failure injected");
    assert_eq!(c.metrics.counter_value("requests_failed"), 1);
    assert_eq!(
        c.metrics.counter_value("scheduler_step_failures"),
        1,
        "the kill must be visible on its dedicated metric"
    );

    // liveness: the rebuilt scheduler serves the very next request
    let alive = c.generate("synth-math", "Q: 3+4=?", "static:0.9").unwrap();
    assert!(alive.error.is_none(), "{:?}", alive.error);
    assert!(alive.steps > 0);
    assert_eq!(c.metrics.counter_value("requests_completed"), 1);
    c.shutdown();
}

#[test]
fn calibration_crash_mid_lease_releases_for_a_peer() {
    let (c, chaos) = chaos_coordinator(CoordinatorConfig::default());

    // the first OSDT request takes the fleet calibration lease; its
    // calibration decode dies on the armed forward pass
    chaos.fail_next(1);
    let crashed = c.generate("synth-math", "Q: 1+2=?", OSDT_SPEC).unwrap();
    assert!(crashed.error.is_some(), "crashed calibration must fail its request");
    assert!(!crashed.calibrated);
    assert_eq!(chaos.injected(), 1);
    assert_eq!(
        c.registry.metrics().counter_value("leases_abandoned"),
        1,
        "the dropped lease must be visible on its dedicated metric"
    );
    assert_eq!(c.registry.metrics().counter_value("calibrations_completed"), 0);

    // single-flight liveness: the key is free again, so the next request
    // calibrates (it does NOT deadlock behind a ghost lease)
    let next = c.generate("synth-math", "Q: 3+4=?", OSDT_SPEC).unwrap();
    assert!(next.error.is_none(), "{:?}", next.error);
    assert!(next.calibrated, "released key must grant the next lease");
    assert_eq!(c.registry.metrics().counter_value("calibrations_completed"), 1);

    // and the profile is reusable
    let reused = c.generate("synth-math", "Q: 5+6=?", OSDT_SPEC).unwrap();
    assert!(!reused.calibrated);
    assert_eq!(
        c.registry.metrics().counter_value("calibrations_completed"),
        1,
        "single-flight: one completed calibration across the run"
    );
    c.shutdown();
}

#[test]
fn stuck_lease_is_stolen_and_supersedes_the_holder() {
    // shrink the steal patience so the test runs in milliseconds
    let (c, _chaos) = chaos_coordinator(CoordinatorConfig {
        steal_after: Duration::from_millis(150),
        ..CoordinatorConfig::default()
    });

    // impersonate a crashed-but-not-dropped calibrator: take the lease
    // directly and sit on it
    let stuck = match c.registry.acquire(&key()) {
        Acquired::Lease(l) => l,
        Acquired::Ready(..) => panic!("fresh key cannot be ready"),
        Acquired::InFlight => panic!("fresh key cannot be in flight"),
    };

    // requests arriving behind the stuck lease park, then steal
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            c.submit(Request {
                id: 0,
                task: "synth-math".into(),
                prompt: format!("Q: {i}+2=?"),
                policy: OSDT_SPEC.into(),
                slo_ms: None,
            })
        })
        .collect();
    let mut calibrated = 0usize;
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        calibrated += usize::from(r.calibrated);
    }
    assert_eq!(calibrated, 1, "exactly one thief calibrates (single-flight)");
    assert!(
        c.metrics.counter_value("calibrations_awaited") >= 1,
        "parked requests must be counted"
    );
    assert!(
        c.registry.metrics().counter_value("lease_takeovers") >= 1,
        "the steal must be visible on its dedicated metric"
    );
    assert_eq!(c.registry.metrics().counter_value("calibrations_completed"), 1);

    // the original holder finally lets go: its abandon is superseded and
    // must NOT re-open the key or clobber the thief's profile
    drop(stuck);
    assert_eq!(
        c.registry.metrics().counter_value("leases_superseded"),
        1,
        "the stale resolution must be visible on its dedicated metric"
    );
    assert!(c.registry.get(&key()).is_some(), "profile survives the late drop");
    let after = c.generate("synth-math", "Q: 9+9=?", OSDT_SPEC).unwrap();
    assert!(after.error.is_none(), "{:?}", after.error);
    assert!(!after.calibrated, "profile still served after the late drop");
    c.shutdown();
}

#[test]
fn invalidation_churn_never_stalls_serving() {
    // drift-style churn: repeatedly invalidate the profile under load;
    // every request must complete and every cycle recalibrates exactly once
    let (c, _chaos) = chaos_coordinator(CoordinatorConfig::default());
    assert!(c.generate("synth-math", "Q: 0+1=?", OSDT_SPEC).unwrap().calibrated);
    for i in 0..4 {
        assert!(c.registry.invalidate(&key()));
        let r = c
            .generate("synth-math", &format!("Q: {i}+3=?"), OSDT_SPEC)
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.calibrated, "stale profile must recalibrate (cycle {i})");
        let follow = c
            .generate("synth-math", &format!("Q: {i}+4=?"), OSDT_SPEC)
            .unwrap();
        assert!(follow.error.is_none());
        assert!(!follow.calibrated, "fresh profile must be reused (cycle {i})");
    }
    assert_eq!(c.registry.metrics().counter_value("recalibrations"), 4);
    assert_eq!(c.registry.metrics().counter_value("calibrations_completed"), 5);
    c.shutdown();
}
